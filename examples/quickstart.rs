//! Quickstart: schedule a small job mix with SJF-BCO and inspect the
//! realized makespan under the contention model.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use rarsched::cluster::Cluster;
use rarsched::contention::ContentionParams;
use rarsched::sched::{schedule, Policy};
use rarsched::sim::Simulator;
use rarsched::trace::TraceGenerator;

fn main() -> rarsched::Result<()> {
    // A small multi-tenant cluster: 8 servers, random {4,8,16,32}-GPU.
    let cluster = Cluster::random(8, 42);
    println!(
        "cluster: {} servers / {} GPUs (b^e={}, b^i={})",
        cluster.num_servers(),
        cluster.num_gpus(),
        cluster.inter_bw,
        cluster.intra_bw
    );

    // ~16 jobs following the paper's Philly-derived mix.
    let jobs = TraceGenerator::paper_scaled(0.1).generate(42);
    println!("jobs: {}", jobs.len());
    let params = ContentionParams::paper();

    // Schedule with the paper's SJF-BCO, then replay under Eq. 6-9.
    let plan = schedule(Policy::SjfBco, &cluster, &jobs, &params, 10_000)?;
    println!(
        "plan: theta={:?} kappa={:?}, {} spread placements, max span {}",
        plan.theta,
        plan.kappa,
        plan.num_spread(),
        plan.max_span()
    );

    let outcome = Simulator::new(&cluster, &jobs, &params).run(&plan);
    println!("makespan    : {} slots", outcome.makespan);
    println!("avg JCT     : {:.1} slots", outcome.avg_jct);
    println!("utilization : {:.1}%", outcome.gpu_utilization * 100.0);

    // Compare against the random baseline.
    let rand_plan = schedule(Policy::Random, &cluster, &jobs, &params, 10_000)?;
    let rand_outcome = Simulator::new(&cluster, &jobs, &params).run(&rand_plan);
    println!(
        "RAND makespan: {} slots ({:.2}x SJF-BCO)",
        rand_outcome.makespan,
        rand_outcome.makespan as f64 / outcome.makespan as f64
    );
    Ok(())
}
