//! The paper's §1 motivating observation, reproduced in the analytical
//! model: a single spread RAR job vs four identical jobs whose rings
//! share the same inter-server links ([19]: 295 s solo -> 675 s each).
//!
//! ```bash
//! cargo run --release --offline --example contention_demo
//! ```

use rarsched::cluster::{Cluster, JobPlacement, ServerId};
use rarsched::contention::{ContentionParams, ContentionSnapshot};
use rarsched::experiments::{motivation, ExperimentSetup};
use rarsched::jobs::{JobId, JobSpec};

fn main() -> rarsched::Result<()> {
    let params = ContentionParams::paper();
    let cluster = Cluster::uniform(2, 8, 1.0, 25.0);

    // One 4-GPU job spread 2+2 across the two servers.
    let job = {
        let mut j = JobSpec::synthetic(JobId(0), 4);
        j.iterations = 2000;
        j
    };
    let spread = |base: usize| {
        JobPlacement::new(vec![
            cluster.global_gpu(ServerId(0), base),
            cluster.global_gpu(ServerId(0), base + 1),
            cluster.global_gpu(ServerId(1), base),
            cluster.global_gpu(ServerId(1), base + 1),
        ])
    };

    println!("== per-iteration time under increasing contention ==");
    println!("{:<28} {:>10} {:>12}", "co-running spread jobs", "tau (slots)", "iters/slot");
    for p in 1..=6usize {
        let tau = params.tau(&cluster, &job, &spread(0), p);
        println!("{:<28} {:>10.4} {:>12}", p, tau, params.phi(tau));
    }
    let colo = JobPlacement::new((0..4).map(|i| cluster.global_gpu(ServerId(0), i)).collect());
    let tau_colo = params.tau(&cluster, &job, &colo, 0);
    println!("{:<28} {:>10.4} {:>12}", "(co-located, no contention)", tau_colo, params.phi(tau_colo));

    // Eq. 6 on the actual 4-job placement set.
    let placements: Vec<_> =
        (0..4).map(|i| (JobId(i), spread(2 * i))).collect();
    let snap = ContentionSnapshot::build(&cluster, &placements);
    println!("\nEq. 6 contention degree with all four jobs active:");
    for (id, _) in &placements {
        // try_p_j: reporting tolerates jobs absent from the snapshot
        // (completed / not yet admitted) instead of panicking.
        let p = snap.try_p_j(*id).map_or("-".to_string(), |p| p.to_string());
        println!("  p_{id} = {p}");
    }

    // End-to-end JCT comparison (the [19] experiment shape).
    let (solo, contended) = motivation(&ExperimentSetup::paper())?;
    println!("\n== completion time (simulated, Eq. 6-9) ==");
    println!("1 spread job alone     : {solo} slots   (paper testbed: 295 s)");
    println!(
        "4 spread jobs together : {contended} slots   (paper testbed: 675 s)"
    );
    println!(
        "slowdown               : {:.2}x     (paper testbed: 2.29x)",
        contended as f64 / solo as f64
    );
    Ok(())
}
