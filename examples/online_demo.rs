//! Online scheduling demo: the `rarsched online` subcommand's code path
//! as a library example — a Poisson-arrival trace driven through the
//! non-clairvoyant event loop under every online policy, next to the
//! clairvoyant SJF-BCO upper bound.
//!
//! ```bash
//! cargo run --release --offline --example online_demo
//! ```

use rarsched::contention::ContentionParams;
use rarsched::experiments::{online::online_comparison, ExperimentSetup};
use rarsched::online::{
    EventKind, OnlineOptions, OnlinePolicyKind, OnlineScheduler, OnlineSjfBco,
};
use rarsched::trace::TraceGenerator;

fn main() -> rarsched::Result<()> {
    // The smoke setup: ~16 Philly-mix jobs on 8 random servers.
    let setup = ExperimentSetup::smoke();
    let gap = 5.0;

    // 1) The full comparison table (same as `rarsched online --gap 5`).
    //    Default OnlineOptions: θ-admission and migration off.
    let table = online_comparison(
        &setup,
        gap,
        &OnlinePolicyKind::ALL,
        true,
        None,
        OnlineOptions::default(),
    )?;
    println!("{}", table.to_table());

    // 1b) The same stream squeezed into bursts (`--burst 25:100`).
    let bursty = online_comparison(
        &setup,
        gap,
        &[OnlinePolicyKind::SjfBco],
        false,
        Some((25, 100)),
        OnlineOptions::default(),
    )?;
    println!("{}", bursty.to_table());

    // 2) Peek inside one run: the event sequence the loop reacted to.
    let cluster = setup.cluster();
    let params = ContentionParams::paper();
    let jobs = TraceGenerator::paper_scaled(setup.scale).generate_online(setup.seed, gap);
    let out = OnlineScheduler::new(&cluster, &jobs, &params).run(&mut OnlineSjfBco::default());
    println!(
        "ON-SJF-BCO event log: {} arrivals, {} starts, {} completions over {} slots",
        out.events.count(EventKind::Arrival),
        out.events.count(EventKind::Start),
        out.events.count(EventKind::Completion),
        out.outcome.makespan
    );
    for e in out.events.events().iter().take(8) {
        println!("  t={:<5} {:?} {:?}", e.at, e.kind, e.job);
    }
    println!("  ... ({} events total)", out.events.len());

    // 3) Queueing-delay summary — the metric the batch formulation cannot
    //    even express.
    println!(
        "queueing delay: mean {:.1} slots, p95 {} slots; service utilization {:.1}%",
        out.outcome.avg_wait(),
        out.outcome.wait_percentile(95.0),
        out.outcome.service_utilization(cluster.num_gpus()) * 100.0
    );
    Ok(())
}
