//! End-to-end driver: the full three-layer system on a real workload.
//!
//! 1. Generates a small multi-tenant job mix and schedules it with
//!    SJF-BCO (L3 planner).
//! 2. Takes the scheduler's placement for a 4-GPU job and *actually
//!    trains* a transformer LM on synthetic prose: one worker thread per
//!    scheduled GPU, each executing the AOT-compiled JAX+Pallas grad step
//!    (L2/L1) via PJRT, gradients exchanged through the real
//!    ring-all-reduce engine under the bandwidth regulator.
//! 3. Repeats the run with a deliberately spread, contended placement of
//!    two concurrent jobs — the live counterpart of the paper's
//!    contention effect — and reports the loss curves + step times.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example train_e2e
//! # env: E2E_MODEL=small E2E_STEPS=300 for the full demo
//! ```

use rarsched::cluster::{Cluster, JobPlacement, ServerId};
use rarsched::contention::ContentionParams;
use rarsched::coordinator::{train_job, train_jobs_concurrently, TrainJobSpec};
use rarsched::rar::LinkBank;
use rarsched::runtime::default_artifacts_dir;
use rarsched::sched::{schedule, Policy};
use rarsched::trace::TraceGenerator;
use std::sync::Arc;

fn main() -> rarsched::Result<()> {
    let model = std::env::var("E2E_MODEL").unwrap_or_else(|_| "tiny".into());
    let steps: u64 = std::env::var("E2E_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(120);
    let artifacts = default_artifacts_dir();
    println!("== e2e: model '{model}', {steps} steps, artifacts {artifacts:?} ==\n");

    // ---- L3: schedule the batch --------------------------------------
    let cluster = Cluster::uniform(2, 8, 1.0, 25.0);
    // scaled mix, clipped to jobs that fit this 16-GPU demo cluster
    let jobs: Vec<_> = TraceGenerator::paper_scaled(0.05)
        .generate(7)
        .into_iter()
        .filter(|j| j.gpus <= cluster.num_gpus())
        .collect();
    let params = ContentionParams::paper();
    let plan = schedule(Policy::SjfBco, &cluster, &jobs, &params, 100_000)?;
    println!(
        "scheduled {} jobs (theta {:?}, kappa {:?}); taking a 4-GPU placement:",
        plan.entries.len(),
        plan.theta,
        plan.kappa
    );
    let four_gpu = plan
        .entries
        .iter()
        .find(|e| e.placement.num_workers() == 4)
        .expect("trace contains a 4-GPU job");
    for g in four_gpu.placement.gpus() {
        print!(" {g}");
    }
    println!("  (span {})\n", four_gpu.placement.span());

    // ---- live training under the scheduler's placement ----------------
    let links = Arc::new(LinkBank::new(cluster.num_servers(), 150.0e6, 5.0e9));
    let spec = TrainJobSpec {
        model: model.clone(),
        steps,
        corpus_seed: 11,
        artifacts: artifacts.clone(),
    };
    let report = train_job(&spec, &four_gpu.placement, Some(links.clone()))?;
    println!("scheduled placement: loss curve (every 10 steps):");
    print_curve(&report.losses);
    println!(
        "loss {:.3} -> {:.3}; mean step {:.0?}; total {:.1?}\n",
        report.initial_loss(),
        report.final_loss(),
        report.mean_step_time(),
        report.total
    );
    assert!(
        report.final_loss() < report.initial_loss() - 0.5,
        "training must show a real loss decrease"
    );

    // ---- contention experiment: two spread jobs sharing uplinks -------
    println!("contention: 2 concurrent spread jobs sharing both uplinks");
    let spread = |base: usize| {
        JobPlacement::new(vec![
            cluster.global_gpu(ServerId(0), base),
            cluster.global_gpu(ServerId(0), base + 1),
            cluster.global_gpu(ServerId(1), base),
            cluster.global_gpu(ServerId(1), base + 1),
        ])
    };
    let solo_links = Arc::new(LinkBank::new(2, 150.0e6, 5.0e9));
    let short_spec = TrainJobSpec { steps: steps.min(40), ..spec.clone() };
    let solo = train_job(&short_spec, &spread(0), Some(solo_links))?;

    let shared_links = Arc::new(LinkBank::new(2, 150.0e6, 5.0e9));
    let pair = vec![
        (short_spec.clone(), spread(0)),
        (TrainJobSpec { corpus_seed: 12, ..short_spec.clone() }, spread(2)),
    ];
    let both = train_jobs_concurrently(&pair, shared_links.clone())?;
    let solo_ms = solo.mean_step_time().as_secs_f64() * 1e3;
    let cont_ms = both
        .iter()
        .map(|r| r.mean_step_time().as_secs_f64() * 1e3)
        .fold(0.0, f64::max);
    println!("solo spread job   : {solo_ms:.1} ms/step");
    println!(
        "contended (worst) : {cont_ms:.1} ms/step ({:.2}x slower)",
        cont_ms / solo_ms
    );
    println!(
        "uplink telemetry  : s0 {:?}, s1 {:?}",
        shared_links.stats(0),
        shared_links.stats(1)
    );
    Ok(())
}

fn print_curve(losses: &[f32]) {
    for (i, l) in losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == losses.len() {
            println!("  step {i:>4}  loss {l:.4}");
        }
    }
}
