//! Fig. 4 as a runnable example: all five policies over the (scaled)
//! paper trace, with makespan, avg JCT, utilization and contention.
//!
//! ```bash
//! cargo run --release --offline --example policy_compare          # 0.25x trace
//! POLICY_SCALE=1.0 cargo run --release --offline --example policy_compare
//! ```

use rarsched::experiments::{run_policy, ExperimentSetup};
use rarsched::sched::Policy;

fn main() -> rarsched::Result<()> {
    let mut setup = ExperimentSetup::paper();
    setup.scale = std::env::var("POLICY_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let cluster = setup.cluster();
    let jobs = setup.jobs();
    let params = setup.params();
    println!(
        "{} jobs on {} servers / {} GPUs, T = {}\n",
        jobs.len(),
        cluster.num_servers(),
        cluster.num_gpus(),
        setup.horizon
    );
    println!(
        "{:<10} {:>9} {:>10} {:>9} {:>8} {:>8} {:>11}",
        "policy", "makespan", "avg JCT", "p95 JCT", "wait", "util%", "max contend"
    );
    let mut rows = Vec::new();
    for policy in Policy::ALL {
        let s = run_policy(policy, &cluster, &jobs, &params, setup.horizon)?;
        println!(
            "{:<10} {:>9} {:>10.1} {:>9} {:>8.1} {:>8.1} {:>11}",
            s.policy,
            s.makespan,
            s.avg_jct,
            s.p95_jct,
            s.avg_wait,
            s.gpu_utilization * 100.0,
            s.max_contention
        );
        rows.push(s);
    }
    let best = rows.iter().min_by_key(|s| s.makespan).unwrap();
    println!("\nbest makespan: {} ({} slots)", best.policy, best.makespan);
    Ok(())
}
