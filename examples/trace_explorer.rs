//! Trace explorer: generate the Philly-derived trace, print its
//! composition, save/reload it as JSON, and show the per-job τ bounds
//! (ρ̂ estimates) the planners work with.
//!
//! ```bash
//! cargo run --release --offline --example trace_explorer
//! ```

use rarsched::cluster::Cluster;
use rarsched::contention::ContentionParams;
use rarsched::jobs::ModelKind;
use rarsched::sched::Estimator;
use rarsched::trace::{Trace, TraceGenerator};

fn main() -> rarsched::Result<()> {
    let gen = TraceGenerator::paper();
    let trace = gen.generate_trace(42);
    println!("paper trace: {} jobs, total GPU demand {}", trace.jobs.len(), trace.total_gpu_demand());

    // composition by size and by model kind
    println!("\nby GPU count:");
    for size in [1usize, 2, 4, 8, 16, 32] {
        let n = trace.jobs.iter().filter(|j| j.gpus == size).count();
        println!("  {size:>2} GPUs: {n:>3} jobs  {}", "#".repeat(n / 2));
    }
    println!("\nby workload kind:");
    for kind in ModelKind::ALL {
        let n = trace.jobs.iter().filter(|j| j.name.starts_with(kind.name())).count();
        println!("  {:<14} {n:>3} jobs", kind.name());
    }

    // round-trip to disk
    let path = std::env::temp_dir().join("rarsched_trace.json");
    trace.save(&path)?;
    let reloaded = Trace::load(&path)?;
    assert_eq!(reloaded.jobs.len(), trace.jobs.len());
    println!("\nsaved + reloaded {:?} ({} bytes)", path, std::fs::metadata(&path)?.len());

    // what the planner sees: rho-hat bounds per job class
    let cluster = Cluster::paper(42);
    let params = ContentionParams::paper();
    let est = Estimator::new(&cluster, &params);
    println!("\nplanner estimates (first job of each size):");
    println!("{:>5} {:>10} {:>10} {:>10} {:>8}", "GPUs", "rho_lo", "rho_hat", "rho_hi", "u/l");
    for size in [1usize, 2, 4, 8, 16, 32] {
        if let Some(job) = trace.jobs.iter().find(|j| j.gpus == size) {
            let r = est.rho(job);
            println!(
                "{:>5} {:>10.1} {:>10.1} {:>10.1} {:>8.2}",
                size,
                r.rho_lower,
                r.rho_hat,
                r.rho_upper,
                r.rho_upper / r.rho_lower
            );
        }
    }
    println!("\nworst-case estimate ratio phi*u/l = {:.2} (enters Theorem 5)", est.worst_ratio(&trace.jobs));
    Ok(())
}
