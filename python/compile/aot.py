"""AOT export: lower the L2 train step to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and executes via PJRT. HLO
text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Per model preset this writes, under ``--out-dir``:

* ``<model>/train_step.hlo.txt``  — (params..., x, y) -> (loss, params'...)
* ``<model>/grad_step.hlo.txt``   — (params..., x, y) -> (loss, grads...)
* ``<model>/apply_grads.hlo.txt`` — (params..., grads...) -> (params'...)
* ``<model>/params_init.bin``     — f32 LE initial parameters (canonical order)
* ``kernels/matmul_<n>.hlo.txt``  — standalone L1 kernel (runtime benches)
* ``manifest.json``               — shapes, order, file map, numeric checks

Usage: ``python -m compile.aot --out-dir ../artifacts [--models tiny,small]``
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import matmul


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def export_model(cfg: M.ModelConfig, out_dir: pathlib.Path) -> dict:
    """Lower all three entry points for one preset; returns manifest entry."""
    mdir = out_dir / cfg.name
    mdir.mkdir(parents=True, exist_ok=True)
    specs = M.param_specs(cfg)
    p_specs = [_spec(s) for _, s in specs]
    x_spec = _spec((cfg.batch, cfg.seq_len), jnp.int32)
    n = len(p_specs)

    def train_step_flat(*args):
        params, (x, y) = list(args[:n]), args[n:]
        loss, new_params = M.train_step(cfg, params, x, y)
        return (loss, *new_params)

    def grad_step_flat(*args):
        params, (x, y) = list(args[:n]), args[n:]
        loss, grads = M.grad_step(cfg, params, x, y)
        return (loss, *grads)

    def apply_flat(*args):
        params, grads = list(args[:n]), list(args[n:])
        return tuple(M.apply_grads(cfg, params, grads))

    exports = {
        "train_step": (train_step_flat, [*p_specs, x_spec, x_spec]),
        "grad_step": (grad_step_flat, [*p_specs, x_spec, x_spec]),
        "apply_grads": (apply_flat, [*p_specs, *p_specs]),
    }
    files = {}
    for name, (fn, arg_specs) in exports.items():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = mdir / f"{name}.hlo.txt"
        path.write_text(text)
        files[name] = f"{cfg.name}/{name}.hlo.txt"
        print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")

    # --- initial parameters + numeric cross-check -------------------------
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    flat = np.concatenate([np.asarray(p, np.float32).reshape(-1) for p in params])
    (mdir / "params_init.bin").write_bytes(flat.astype("<f4").tobytes())

    bx, by = M.make_batch(cfg, jax.random.PRNGKey(1))
    loss0, grads = M.grad_step(cfg, params, bx, by)
    params1 = M.apply_grads(cfg, params, grads)
    loss1 = M.loss_fn(cfg, params1, bx, by)
    check = {
        "x": np.asarray(bx).reshape(-1).tolist(),
        "y": np.asarray(by).reshape(-1).tolist(),
        "loss_before": float(loss0),
        "loss_after_step": float(loss1),
    }

    return {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
            "lr": cfg.lr,
        },
        "params": [
            {"name": name, "shape": list(shape), "size": int(np.prod(shape))}
            for name, shape in specs
        ],
        "total_params": M.num_params(cfg),
        "artifacts": files,
        "init_file": f"{cfg.name}/params_init.bin",
        "check": check,
    }


def export_matmul_kernel(n: int, out_dir: pathlib.Path) -> dict:
    kdir = out_dir / "kernels"
    kdir.mkdir(parents=True, exist_ok=True)
    spec = _spec((n, n))
    lowered = jax.jit(lambda a, b: (matmul(a, b),)).lower(spec, spec)
    path = kdir / f"matmul_{n}.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    print(f"  wrote {path}")
    return {"file": f"kernels/matmul_{n}.hlo.txt", "m": n, "k": n, "n": n}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tiny,small",
                    help="comma-separated presets (tiny,small,base)")
    ap.add_argument("--matmul-sizes", default="128,256,512")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir).resolve()
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {"models": {}, "kernels": {}}

    for name in [m.strip() for m in args.models.split(",") if m.strip()]:
        cfg = M.ModelConfig.preset(name)
        print(f"exporting model '{name}' ({M.num_params(cfg) / 1e6:.2f} M params)")
        manifest["models"][name] = export_model(cfg, out_dir)

    for n in [int(s) for s in args.matmul_sizes.split(",") if s.strip()]:
        manifest["kernels"][f"matmul_{n}"] = export_matmul_kernel(n, out_dir)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
