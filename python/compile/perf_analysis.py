"""L1/L2 performance analysis for the §Perf pass (build-time tooling).

* L1 — Pallas matmul: VMEM residency + MXU tile utilisation per block
  configuration, swept over the model's actual contraction shapes.
  (interpret=True gives CPU-numpy wallclock only, which is NOT a TPU
  proxy — we optimise structure, per the repo guidelines.)
* L2 — lowered HLO: op histogram of the exported train step; fusion
  count, convolution/dot count, all-reduce-relevant elementwise volume.

Usage: python -m compile.perf_analysis [--model small] [--hlo ../artifacts]
"""

from __future__ import annotations

import argparse
import collections
import pathlib
import re

from . import model as M
from .kernels import vmem_footprint

# TPU v4-ish envelope used for the roofline *ratio* estimate.
VMEM_BYTES = 16 * 1024 * 1024
MXU_FLOPS_PER_CYCLE = 2 * 128 * 128  # one 128x128 MAC array


def l1_report(cfg: M.ModelConfig) -> list[dict]:
    """Sweep block shapes for every distinct matmul in the model."""
    b, s, d, f, v = cfg.batch, cfg.seq_len, cfg.d_model, cfg.d_ff, cfg.vocab
    shapes = {
        "qkv": (b * s, d, 3 * d),
        "attn_out": (b * s, d, d),
        "mlp_w1": (b * s, d, f),
        "mlp_w2": (b * s, f, d),
        "head": (b * s, d, v),
    }
    rows = []
    for name, (m, k, n) in shapes.items():
        best = None
        for bm in (32, 64, 128, 256):
            for bn in (32, 64, 128, 256):
                fp = vmem_footprint(m, k, n, block_m=bm, block_n=bn)
                if fp["vmem_bytes_per_step"] > VMEM_BYTES:
                    continue  # would not fit VMEM with double buffering
                score = (fp["mxu_tile_utilization"], -fp["grid_steps"])
                if best is None or score > best[0]:
                    best = (score, bm, bn, fp)
        _, bm, bn, fp = best
        rows.append({
            "matmul": name,
            "shape": (m, k, n),
            "best_block": (bm, bn),
            "vmem_bytes": fp["vmem_bytes_per_step"],
            "vmem_frac": fp["vmem_bytes_per_step"] / VMEM_BYTES,
            "mxu_util": fp["mxu_tile_utilization"],
            "grid_steps": fp["grid_steps"],
        })
    return rows


def l2_report(hlo_path: pathlib.Path) -> dict:
    """Parse HLO text: op histogram and fusion stats."""
    text = hlo_path.read_text()
    ops = collections.Counter()
    for line in text.splitlines():
        m = re.search(r"=\s*[a-z0-9\[\],\{\} ]+?\s([a-z\-]+)\(", line)
        if m:
            ops[m.group(1)] += 1
    return {
        "file": str(hlo_path),
        "total_ops": sum(ops.values()),
        "dot": ops.get("dot", 0),
        "fusion": ops.get("fusion", 0),
        "broadcast": ops.get("broadcast", 0),
        "transpose": ops.get("transpose", 0),
        "top": ops.most_common(12),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="small")
    ap.add_argument("--hlo", default="../artifacts")
    args = ap.parse_args()

    cfg = M.ModelConfig.preset(args.model)
    print(f"== L1 block-shape sweep ({args.model}: {M.num_params(cfg)/1e6:.2f} M params) ==")
    print(f"{'matmul':<10} {'M,K,N':>18} {'block':>10} {'VMEM':>9} {'MXU util':>9} {'steps':>6}")
    for r in l1_report(cfg):
        m, k, n = r["shape"]
        bm, bn = r["best_block"]
        print(
            f"{r['matmul']:<10} {f'{m},{k},{n}':>18} {f'{bm}x{bn}':>10} "
            f"{r['vmem_frac']*100:>8.1f}% {r['mxu_util']*100:>8.1f}% {r['grid_steps']:>6}"
        )

    root = pathlib.Path(args.hlo)
    for entry in ("grad_step", "train_step"):
        p = root / args.model / f"{entry}.hlo.txt"
        if not p.exists():
            print(f"(skip {p}: not exported)")
            continue
        rep = l2_report(p)
        print(f"\n== L2 HLO stats: {entry} ==")
        print(f"total ops {rep['total_ops']}, dot {rep['dot']}, fusion {rep['fusion']}")
        print("top ops:", ", ".join(f"{k}:{v}" for k, v in rep["top"]))


if __name__ == "__main__":
    main()
