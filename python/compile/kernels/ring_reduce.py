"""L1 Pallas kernel: the RAR share-reduce chunk step.

One step of the ring-all-reduce Share-Reduce phase (paper §3, Fig. 1):
a worker receives a gradient sub-vector from its upstream neighbour and
adds it to its local chunk. The kernel is a blocked elementwise add —
bandwidth-bound, so the tile shape targets the VPU lane width (128) with
a sublane-friendly second dimension.

`ring_allreduce` chains 2(w-1) of these steps in pure JAX exactly as the
ring schedules them; it is used both as a correctness oracle for the Rust
RAR engine and to verify the bandwidth-optimal volume accounting.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# VPU-friendly block: 8 sublanes x 128 lanes.
DEFAULT_BLOCK = 1024


def _chunk_add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def chunk_add(a: jax.Array, b: jax.Array, *, block: int = DEFAULT_BLOCK,
              interpret: bool = True) -> jax.Array:
    """Elementwise ``a + b`` over flat chunks via the Pallas kernel."""
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch: {a.shape} vs {b.shape}")
    flat = a.reshape(-1)
    n = flat.shape[0]
    blk = min(block, n) if n else 1
    pad = (-n) % blk
    ap = jnp.pad(flat, (0, pad))
    bp = jnp.pad(b.reshape(-1), (0, pad))
    out = pl.pallas_call(
        _chunk_add_kernel,
        grid=(ap.shape[0] // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,)),
                  pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(ap.shape, a.dtype),
        interpret=interpret,
    )(ap, bp)
    return out[:n].reshape(a.shape)


def chunk_boundaries(d: int, w: int) -> list[tuple[int, int]]:
    """Split a length-`d` gradient into `w` contiguous chunks (the per-worker
    sub-vectors of §3). Sizes differ by at most one element."""
    base, rem = divmod(d, w)
    bounds = []
    start = 0
    for i in range(w):
        size = base + (1 if i < rem else 0)
        bounds.append((start, start + size))
        start += size
    return bounds


def ring_allreduce(grads: list[jax.Array], *, use_kernel: bool = True) -> list[jax.Array]:
    """Execute the exact 2(w-1)-step RAR schedule over per-worker gradients.

    ``grads[i]`` is worker *i*'s local gradient (all same shape). Returns
    each worker's final fully-reduced gradient — all equal to
    ``sum(grads)``. Chunk arithmetic goes through the Pallas
    :func:`chunk_add` kernel when ``use_kernel`` (the L1 hot path);
    otherwise plain ``+`` (oracle).
    """
    w = len(grads)
    if w == 0:
        raise ValueError("need at least one worker")
    shape = grads[0].shape
    d = int(np.prod(shape)) if shape else 1
    bufs = [g.reshape(-1) for g in grads]
    if w == 1:
        return [bufs[0].reshape(shape)]
    bounds = chunk_boundaries(d, w)
    add = chunk_add if use_kernel else (lambda a, b: a + b)

    # Share-Reduce phase: steps 1..w-1. In step s, worker i sends chunk
    # (i - s + 1) mod w to worker i+1, which accumulates it.
    for s in range(w - 1):
        sends = []
        for i in range(w):
            c = (i - s) % w
            lo, hi = bounds[c]
            sends.append((c, bufs[i][lo:hi]))
        for i in range(w):
            src = (i - 1) % w
            c, payload = sends[src]
            lo, hi = bounds[c]
            reduced = add(bufs[i][lo:hi], payload)
            bufs[i] = bufs[i].at[lo:hi].set(reduced)

    # Share-Only phase: steps w..2w-2. Worker i now owns the fully reduced
    # chunk (i + 1) mod w; circulate copies around the ring.
    for s in range(w - 1):
        sends = []
        for i in range(w):
            c = (i + 1 - s) % w
            lo, hi = bounds[c]
            sends.append((c, bufs[i][lo:hi]))
        for i in range(w):
            src = (i - 1) % w
            c, payload = sends[src]
            lo, hi = bounds[c]
            bufs[i] = bufs[i].at[lo:hi].set(payload)

    return [b.reshape(shape) for b in bufs]


def rar_bytes_per_worker(d: int, w: int, bytes_per_el: int = 4) -> int:
    """Total bytes any worker transmits in one all-reduce:
    ``2 d (w-1)/w`` elements (§3 — asymptotically independent of w)."""
    if w <= 1:
        return 0
    total = 0
    bounds = chunk_boundaries(d, w)
    # each worker sends one chunk per step for 2(w-1) steps; chunk sizes
    # rotate, so sum = 2 * (d - own chunk avg) ~ 2 d (w-1)/w
    for s in range(2 * (w - 1)):
        c = s % w
        lo, hi = bounds[c]
        total += (hi - lo) * bytes_per_el
    return total
