"""L1 Pallas kernel: tiled matrix multiplication.

The paper's DDL jobs spend their compute time in dense layers (FP/BP,
§4.1 2-2); this kernel is the compute hot-spot of the L2 model. It is
authored for the TPU MXU: 128x128 output tiles (the systolic array shape),
a K-strip loop that keeps one (bm, K) strip of `x` and one (K, bn) strip
of `w` resident in VMEM, and f32 accumulation.

On this testbed it must run under ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls); numerics are identical, wallclock is
CPU-numpy. Structural/VMEM analysis lives in :func:`vmem_footprint`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic-array shape: prefer 128x128 output tiles.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: full K-strip contraction.

    x_ref: (bm, K) strip, w_ref: (K, bn) strip, o_ref: (bm, bn) tile.
    The contraction uses ``preferred_element_type=float32`` so bf16 inputs
    still accumulate in f32 (MXU-style mixed precision).
    """
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    """``x @ w`` via the Pallas tile kernel.

    Shapes are padded up to tile multiples and the result sliced back, so
    arbitrary (M, K) x (K, N) inputs are supported.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape
    bm = min(block_m, max(m, 1))
    bn = min(block_n, max(n, 1))
    xp = _pad_to(x, bm, 0)
    wp = _pad_to(w, bn, 1)
    mp, np_ = xp.shape[0], wp.shape[1]

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def matmul_ad(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable wrapper: Pallas kernels carry no automatic VJP, so
    the backward pass is expressed with the same tile kernel
    (``dx = dy @ w.T``, ``dw = x.T @ dy`` — both MXU matmuls)."""
    return matmul(x, w)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(res, dy):
    x, w = res
    return matmul(dy, w.T), matmul(x.T, dy)


matmul_ad.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint(m: int, k: int, n: int, *, block_m: int = DEFAULT_BLOCK_M,
                   block_n: int = DEFAULT_BLOCK_N, bytes_per_el: int = 4) -> dict:
    """Static VMEM/MXU analysis of one grid step (for DESIGN.md §Perf).

    Returns the per-step VMEM residency in bytes and the MXU tile
    utilisation (fraction of the 128x128 array covered by the block).
    """
    bm, bn = min(block_m, m), min(block_n, n)
    vmem = (bm * k + k * bn + bm * bn) * bytes_per_el
    mxu_util = (min(bm, 128) * min(bn, 128)) / (128 * 128)
    flops = 2 * m * k * n
    return {
        "block": (bm, k, bn),
        "vmem_bytes_per_step": vmem,
        "mxu_tile_utilization": mxu_util,
        "total_flops": flops,
        "grid_steps": ((m + bm - 1) // bm) * ((n + bn - 1) // bn),
    }
