"""L1 Pallas kernel: fused SGD parameter update.

``w <- w - lr * g`` as a single blocked kernel, fusing the scale and the
subtract so the parameter tensor is streamed through VMEM exactly once
(two reads + one write per element instead of the unfused two passes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _sgd_kernel(w_ref, g_ref, lr_ref, o_ref):
    # lr arrives as a (1,)-shaped scalar block
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sgd_apply(w: jax.Array, g: jax.Array, lr, *, block: int = DEFAULT_BLOCK,
              interpret: bool = True) -> jax.Array:
    """Fused ``w - lr * g`` with arbitrary (matching) shapes."""
    if w.shape != g.shape:
        raise ValueError(f"shape mismatch: {w.shape} vs {g.shape}")
    flat_w = w.reshape(-1)
    flat_g = g.reshape(-1)
    n = flat_w.shape[0]
    blk = min(block, n) if n else 1
    pad = (-n) % blk
    wp = jnp.pad(flat_w, (0, pad))
    gp = jnp.pad(flat_g, (0, pad))
    lr_arr = jnp.asarray(lr, dtype=w.dtype).reshape(1)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(wp.shape[0] // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(wp.shape, w.dtype),
        interpret=interpret,
    )(wp, gp, lr_arr)
    return out[:n].reshape(w.shape)
