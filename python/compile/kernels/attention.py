"""L1 Pallas kernel: fused causal scaled-dot-product attention.

One grid step processes one (batch, head) pair entirely in VMEM:
``softmax(q k^T / sqrt(d) + causal) v`` with a numerically-stable
row-max softmax — the flash-attention insight restated for the TPU
memory hierarchy (keep the (S, d_h) tiles resident in VMEM/scratch
rather than streaming S×S scores through HBM). For the sequence lengths
this repo trains (S ≤ 256, d_h ≤ 64) the whole head fits comfortably:
S·d_h·3 + S² floats ≤ 0.5 MB « 16 MB VMEM.

The backward pass is provided via ``jax.custom_vjp`` with jnp
recomputation (correct, not memory-optimal; the fused forward is the
hot path this repo exercises).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    # refs are (1, S, d_h) blocks for one (batch, head) pair
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[1], q.dtype))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # causal mask
    idx = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(jdx <= idx, scores, -1e30)
    # stable softmax
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p.astype(v.dtype), v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              interpret: bool = True) -> jax.Array:
    """Fused causal attention over ``(B, H, S, d_h)`` tensors."""
    if q.shape != k.shape or q.shape != v.shape:
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    if q.ndim != 4:
        raise ValueError(f"expected (B, H, S, d_h), got {q.shape}")
    b, h, s, d = q.shape
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    out = pl.pallas_call(
        _attn_kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def _attention_ref(q, k, v):
    """Plain-jnp causal attention (also the VJP recompute path)."""
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


@jax.custom_vjp
def attention_ad(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Differentiable fused attention (backward = jnp recompute)."""
    return attention(q, k, v)


def _fwd(q, k, v):
    return attention(q, k, v), (q, k, v)


def _bwd(res, do):
    q, k, v = res
    _, vjp = jax.vjp(_attention_ref, q, k, v)
    return vjp(do)


attention_ad.defvjp(_fwd, _bwd)


def attention_vmem_bytes(s: int, d_h: int, bytes_per_el: int = 4) -> int:
    """Per-grid-step VMEM residency: q, k, v, o tiles + the score matrix."""
    return (4 * s * d_h + s * s) * bytes_per_el
