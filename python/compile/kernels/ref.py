"""Pure-jnp correctness oracles for every L1 kernel.

These are the ground truth the pytest suite compares the Pallas kernels
against (``assert_allclose``); they are intentionally the most obvious
possible implementations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def chunk_add_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return a + b


def sgd_ref(w: jax.Array, g: jax.Array, lr) -> jax.Array:
    return w - jnp.asarray(lr, dtype=w.dtype) * g


def allreduce_ref(grads: list[jax.Array]) -> list[jax.Array]:
    """Ground truth for ring_allreduce: every worker ends with the sum."""
    total = grads[0]
    for g in grads[1:]:
        total = total + g
    return [total for _ in grads]
