"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .attention import attention, attention_ad, attention_vmem_bytes
from .matmul import matmul, matmul_ad, vmem_footprint
from .ring_reduce import chunk_add, chunk_boundaries, rar_bytes_per_worker, ring_allreduce
from .sgd import sgd_apply

__all__ = [
    "attention",
    "attention_ad",
    "attention_vmem_bytes",
    "matmul",
    "matmul_ad",
    "vmem_footprint",
    "chunk_add",
    "chunk_boundaries",
    "rar_bytes_per_worker",
    "ring_allreduce",
    "sgd_apply",
]
