"""L2: transformer language-model train step in JAX, built on the L1
Pallas kernels.

This is the *workload* the paper's scheduler schedules: an SGD-based DDL
training job (paper §3.1). The model is a standard pre-LN transformer LM
over byte-level tokens; every dense contraction in the MLP blocks goes
through the Pallas tile kernel (``kernels.matmul_ad``), so the kernel
lowers into the same HLO module that the Rust runtime executes.

Three entry points are AOT-exported per model size (see ``aot.py``):

* ``train_step``  — single-worker fused step: loss + grads + SGD update.
* ``grad_step``   — distributed-worker half-step: loss + gradients only;
  the Rust RAR engine all-reduces the gradients between workers.
* ``apply_grads`` — the other half: SGD update from (all-reduced) grads,
  via the fused Pallas SGD kernel.

Parameters travel as a *flat, ordered list* of tensors; the order is
defined by :func:`param_specs` and exported in the artifact manifest so
the Rust side can address them by index.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp

from .kernels import attention_ad, matmul_ad, sgd_apply


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyper-parameters (static; baked into the artifact)."""

    name: str = "tiny"
    vocab: int = 256          # byte-level tokens
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 64
    batch: int = 8
    lr: float = 0.05
    # Use the fused Pallas attention kernel (L1) instead of the jnp
    # einsum path. Both are numerically equivalent (tested); the fused
    # kernel keeps each (S, d_h) head resident in VMEM.
    fused_attention: bool = False

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @staticmethod
    def preset(name: str) -> "ModelConfig":
        presets = {
            # ~0.6 M params — CI / unit tests
            "tiny": ModelConfig(name="tiny"),
            # ~3.2 M params — default e2e training demo
            "small": ModelConfig(
                name="small", d_model=256, n_layers=4, n_heads=8, d_ff=1024,
                seq_len=128, batch=8, lr=0.05,
            ),
            # ~25 M params — the largest CPU-trainable-in-minutes variant
            "base": ModelConfig(
                name="base", d_model=512, n_layers=8, n_heads=8, d_ff=2048,
                seq_len=256, batch=8, lr=0.02,
            ),
        }
        if name not in presets:
            raise ValueError(f"unknown preset '{name}' (tiny|small|base)")
        return presets[name]


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """The flat parameter layout: (name, shape) in canonical order."""
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1_scale", (cfg.d_model,)),
            (p + "ln1_bias", (cfg.d_model,)),
            (p + "attn_qkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "attn_out", (cfg.d_model, cfg.d_model)),
            (p + "ln2_scale", (cfg.d_model,)),
            (p + "ln2_bias", (cfg.d_model,)),
            (p + "mlp_w1", (cfg.d_model, cfg.d_ff)),
            (p + "mlp_b1", (cfg.d_ff,)),
            (p + "mlp_w2", (cfg.d_ff, cfg.d_model)),
            (p + "mlp_b2", (cfg.d_model,)),
        ]
    specs += [
        ("ln_f_scale", (cfg.d_model,)),
        ("ln_f_bias", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
    ]
    return specs


def num_params(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_specs(cfg):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


def init_params(cfg: ModelConfig, key: jax.Array) -> list[jax.Array]:
    """Scaled-normal init in the canonical flat order."""
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_scale",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_bias", "_b1", "_b2")) or "b1" in name or "b2" in name:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            std = 0.02 if "emb" in name else (1.0 / max(fan_in, 1)) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def _layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _dense(x2d: jax.Array, w: jax.Array) -> jax.Array:
    """Dense contraction through the Pallas tile kernel (L1)."""
    return matmul_ad(x2d, w)


def forward(cfg: ModelConfig, params: list[jax.Array], x: jax.Array) -> jax.Array:
    """Logits for token ids ``x: i32[B, S]`` -> ``f32[B, S, V]``."""
    it = iter(params)

    def take(n: int) -> list[jax.Array]:
        return [next(it) for _ in range(n)]

    (tok_emb, pos_emb) = take(2)
    b, s = x.shape
    h = tok_emb[x] + pos_emb[None, :s, :]
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    for _ in range(cfg.n_layers):
        (ln1_s, ln1_b, w_qkv, w_out, ln2_s, ln2_b, w1, b1, w2, b2) = take(10)
        # --- attention ---
        hn = _layer_norm(h, ln1_s, ln1_b)
        qkv = _dense(hn.reshape(b * s, cfg.d_model), w_qkv).reshape(b, s, 3 * cfg.d_model)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        k = k.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        if cfg.fused_attention:
            out = attention_ad(q, k, v)
        else:
            att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(cfg.head_dim))
            att = jnp.where(causal[None, None], att, -1e30)
            att = jax.nn.softmax(att, axis=-1)
            out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        out = out.transpose(0, 2, 1, 3).reshape(b * s, cfg.d_model)
        h = h + _dense(out, w_out).reshape(b, s, cfg.d_model)
        # --- MLP ---
        hn = _layer_norm(h, ln2_s, ln2_b)
        z = _dense(hn.reshape(b * s, cfg.d_model), w1) + b1
        z = jax.nn.gelu(z)
        z = _dense(z, w2) + b2
        h = h + z.reshape(b, s, cfg.d_model)

    (ln_f_s, ln_f_b, head) = take(3)
    h = _layer_norm(h, ln_f_s, ln_f_b)
    logits = _dense(h.reshape(b * s, cfg.d_model), head)
    return logits.reshape(b, s, cfg.vocab)


def loss_fn(cfg: ModelConfig, params: list[jax.Array], x: jax.Array,
            y: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy (`y` = `x` shifted by the caller)."""
    logits = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def grad_step(cfg: ModelConfig, params: list[jax.Array], x: jax.Array,
              y: jax.Array) -> tuple[jax.Array, list[jax.Array]]:
    """Distributed-worker half-step: (loss, gradients)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, x, y))(params)
    return loss, grads


def apply_grads(cfg: ModelConfig, params: list[jax.Array],
                grads: list[jax.Array]) -> list[jax.Array]:
    """SGD update through the fused Pallas kernel."""
    return [sgd_apply(w, g, cfg.lr) for w, g in zip(params, grads)]


def train_step(cfg: ModelConfig, params: list[jax.Array], x: jax.Array,
               y: jax.Array) -> tuple[jax.Array, list[jax.Array]]:
    """Single-worker fused step: (loss, updated params)."""
    loss, grads = grad_step(cfg, params, x, y)
    return loss, apply_grads(cfg, params, grads)


def make_batch(cfg: ModelConfig, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """A synthetic next-token batch (used by python-side tests only; the
    Rust driver feeds real byte-level corpus batches)."""
    data = jax.random.randint(key, (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab)
    return data[:, :-1], data[:, 1:]


def flatten_count(params: Iterable[jax.Array]) -> int:
    return sum(int(p.size) for p in params)
