"""AOT export smoke tests: the HLO text must parse-ready (non-empty,
ENTRY present), the manifest complete, and the init blob the right size."""

import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = M.ModelConfig.preset("tiny")
    entry = aot.export_model(cfg, out)
    manifest = {"models": {"tiny": entry},
                "kernels": {"matmul_64": aot.export_matmul_kernel(64, out)}}
    (out / "manifest.json").write_text(json.dumps(manifest))
    return out, entry


def test_hlo_text_artifacts_exist(export_dir):
    out, entry = export_dir
    for name, rel in entry["artifacts"].items():
        text = (out / rel).read_text()
        assert len(text) > 1000, name
        assert "ENTRY" in text, f"{name}: not HLO text"
        assert "main" in text


def test_manifest_schema(export_dir):
    out, entry = export_dir
    manifest = json.loads((out / "manifest.json").read_text())
    m = manifest["models"]["tiny"]
    assert m["total_params"] == M.num_params(M.ModelConfig.preset("tiny"))
    assert [p["name"] for p in m["params"]][:2] == ["tok_emb", "pos_emb"]
    assert set(m["artifacts"]) == {"train_step", "grad_step", "apply_grads"}
    assert manifest["kernels"]["matmul_64"]["m"] == 64


def test_init_blob_size(export_dir):
    out, entry = export_dir
    blob = (out / entry["init_file"]).read_bytes()
    assert len(blob) == 4 * entry["total_params"]
    arr = np.frombuffer(blob, "<f4")
    assert np.isfinite(arr).all()
    assert arr.std() > 0


def test_check_values_recorded(export_dir):
    _, entry = export_dir
    check = entry["check"]
    cfg = M.ModelConfig.preset("tiny")
    assert len(check["x"]) == cfg.batch * cfg.seq_len
    assert check["loss_before"] > check["loss_after_step"], \
        "one SGD step must reduce loss on the same batch"
    assert abs(check["loss_before"] - np.log(cfg.vocab)) < 0.5


def test_cli_runs_end_to_end(tmp_path):
    """python -m compile.aot with a tiny config must succeed."""
    repo = pathlib.Path(__file__).resolve().parents[1]
    result = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--models", "tiny", "--matmul-sizes", "64"],
        cwd=repo, capture_output=True, text=True, timeout=600,
    )
    assert result.returncode == 0, result.stderr
    assert (tmp_path / "manifest.json").exists()
    assert (tmp_path / "tiny" / "train_step.hlo.txt").exists()
