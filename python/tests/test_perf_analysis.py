"""Perf-analysis tooling sanity: blocks fit VMEM, MXU-optimal tiles are
chosen for MXU-aligned shapes, HLO stats parse real artifacts."""

import pathlib

import pytest

from compile import model as M
from compile.perf_analysis import VMEM_BYTES, l1_report, l2_report


def test_l1_blocks_fit_vmem_and_fill_mxu():
    cfg = M.ModelConfig.preset("small")
    rows = l1_report(cfg)
    assert len(rows) == 5
    for r in rows:
        assert r["vmem_bytes"] <= VMEM_BYTES, r
        # every contraction in the small model is 128-aligned, so the
        # sweep must find a full-MXU tile
        assert r["mxu_util"] == 1.0, r
        assert r["grid_steps"] >= 1


def test_l1_handles_tiny_model():
    rows = l1_report(M.ModelConfig.preset("tiny"))
    assert all(r["vmem_bytes"] <= VMEM_BYTES for r in rows)


def test_l2_parses_exported_hlo():
    root = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    hlo = root / "tiny" / "grad_step.hlo.txt"
    if not hlo.exists():
        pytest.skip("artifacts not built")
    rep = l2_report(hlo)
    assert rep["total_ops"] > 500
    assert rep["dot"] > 10, "pallas matmuls must lower to dot ops"
    assert rep["top"][0][1] > 50
