"""Fused Pallas attention vs the jnp reference: forward, gradients, and
model-level equivalence of the fused_attention config flag."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model as M
from compile.kernels import attention, attention_ad, attention_vmem_bytes
from compile.kernels.attention import _attention_ref


def _qkv(seed, b, h, s, d):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    shape = (b, h, s, d)
    return (jax.random.normal(kq, shape, jnp.float32),
            jax.random.normal(kk, shape, jnp.float32),
            jax.random.normal(kv, shape, jnp.float32))


@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 3), h=st.integers(1, 4),
       s=st.integers(1, 48), d=st.integers(1, 32))
def test_fused_matches_reference(b, h, s, d):
    q, k, v = _qkv(0, b, h, s, d)
    got = attention(q, k, v)
    want = _attention_ref(q, k, v)
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_causality_of_fused_kernel():
    q, k, v = _qkv(1, 1, 2, 16, 8)
    out_full = attention(q, k, v)
    # changing the last key/value must not affect earlier outputs
    k2 = k.at[:, :, -1].add(10.0)
    v2 = v.at[:, :, -1].add(10.0)
    out_perturbed = attention(q, k2, v2)
    assert_allclose(out_full[:, :, :-1], out_perturbed[:, :, :-1], rtol=1e-5, atol=1e-5)


def test_gradients_match_reference():
    q, k, v = _qkv(2, 2, 2, 12, 8)

    def f_fused(q, k, v):
        return jnp.sum(attention_ad(q, k, v) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(_attention_ref(q, k, v) ** 2)

    g_fused = jax.grad(f_fused, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_fused, g_ref):
        assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_shape_validation():
    q, k, v = _qkv(3, 1, 1, 4, 4)
    with pytest.raises(ValueError):
        attention(q, k[:, :, :2], v)
    with pytest.raises(ValueError):
        attention(q[0], k[0], v[0])


def test_model_flag_is_numerically_equivalent():
    cfg = M.ModelConfig.preset("tiny")
    cfg_fused = M.ModelConfig(**{**cfg.__dict__, "fused_attention": True})
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x, y = M.make_batch(cfg, jax.random.PRNGKey(1))
    loss_a = M.loss_fn(cfg, params, x, y)
    loss_b = M.loss_fn(cfg_fused, params, x, y)
    assert float(loss_a) == pytest.approx(float(loss_b), rel=1e-5)
    # one full train step as well (exercises the custom VJP end to end)
    la, pa = M.train_step(cfg, params, x, y)
    lb, pb = M.train_step(cfg_fused, params, x, y)
    assert float(la) == pytest.approx(float(lb), rel=1e-5)
    for a, b in zip(pa, pb):
        assert_allclose(a, b, rtol=5e-4, atol=1e-5)


def test_vmem_budget():
    # the largest preset head still fits VMEM comfortably
    cfg = M.ModelConfig.preset("base")
    assert attention_vmem_bytes(cfg.seq_len, cfg.head_dim) < 16 * 1024 * 1024 // 4
