"""The exact RAR schedule (share-reduce + share-only, paper §3) must be
numerically equivalent to a global sum, and its traffic accounting must
match the paper's bandwidth-optimality expression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import chunk_boundaries, rar_bytes_per_worker, ring_allreduce
from compile.kernels import ref


def _grads(w, d, seed=0):
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, w)
    return [jax.random.normal(k, (d,), jnp.float32) for k in keys]


@settings(max_examples=15, deadline=None)
@given(w=st.integers(min_value=1, max_value=8),
       d=st.integers(min_value=1, max_value=300))
def test_ring_allreduce_equals_sum(w, d):
    grads = _grads(w, d)
    got = ring_allreduce(grads, use_kernel=False)
    want = ref.allreduce_ref(grads)
    for g, r in zip(got, want):
        assert_allclose(g, r, rtol=1e-5, atol=1e-5)


def test_ring_allreduce_with_pallas_kernel():
    grads = _grads(4, 1000, seed=7)
    got = ring_allreduce(grads, use_kernel=True)
    want = ref.allreduce_ref(grads)
    for g, r in zip(got, want):
        assert_allclose(g, r, rtol=1e-5, atol=1e-5)


def test_ring_allreduce_nd_shapes():
    key = jax.random.PRNGKey(3)
    grads = [jax.random.normal(k, (5, 7), jnp.float32)
             for k in jax.random.split(key, 3)]
    got = ring_allreduce(grads, use_kernel=False)
    want = grads[0] + grads[1] + grads[2]
    for g in got:
        assert_allclose(g, want, rtol=1e-5, atol=1e-5)


@given(d=st.integers(min_value=1, max_value=1000),
       w=st.integers(min_value=1, max_value=16))
def test_chunk_boundaries_partition(d, w):
    bounds = chunk_boundaries(d, w)
    assert len(bounds) == w
    assert bounds[0][0] == 0 and bounds[-1][1] == d
    sizes = [hi - lo for lo, hi in bounds]
    assert sum(sizes) == d
    assert max(sizes) - min(sizes) <= 1
    for (a, b), (c, _) in zip(bounds, bounds[1:]):
        assert b == c


def test_bandwidth_optimality_volume():
    # per-worker bytes = 2 d (w-1)/w * 4; asymptotically independent of w
    d = 10_000
    for w in [2, 4, 8, 16]:
        got = rar_bytes_per_worker(d, w)
        want = 2 * d * (w - 1) / w * 4
        assert got == pytest.approx(want, rel=0.01)
    assert rar_bytes_per_worker(d, 1) == 0
    # growth is bounded by 2*d*4
    assert rar_bytes_per_worker(d, 64) < 2 * d * 4


def test_single_worker_identity():
    g = _grads(1, 17)
    out = ring_allreduce(g)
    assert_allclose(out[0], g[0], rtol=0, atol=0)
