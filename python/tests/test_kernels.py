"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is THE
core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import chunk_add, matmul, matmul_ad, sgd_apply, vmem_footprint
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=260)
SMALL_DIMS = st.integers(min_value=1, max_value=96)


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- matmul --
@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS)
def test_matmul_matches_ref_f32(m, k, n):
    x = _rand(0, (m, k), jnp.float32)
    w = _rand(1, (k, n), jnp.float32)
    assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS)
def test_matmul_matches_ref_bf16(m, k, n):
    x = _rand(2, (m, k), jnp.bfloat16)
    w = _rand(3, (k, n), jnp.bfloat16)
    got = matmul(x, w).astype(jnp.float32)
    want = ref.matmul_ref(x, w).astype(jnp.float32)
    assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("block_m,block_n", [(32, 32), (64, 128), (128, 64)])
def test_matmul_block_shapes_equivalent(block_m, block_n):
    x = _rand(4, (100, 70), jnp.float32)
    w = _rand(5, (70, 90), jnp.float32)
    got = matmul(x, w, block_m=block_m, block_n=block_n)
    assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    with pytest.raises(ValueError):
        matmul(x, jnp.zeros((6, 3)))
    with pytest.raises(ValueError):
        matmul(jnp.zeros((4,)), jnp.zeros((4, 2)))


def test_matmul_ad_gradients_match_jnp():
    x = _rand(6, (33, 17), jnp.float32)
    w = _rand(7, (17, 29), jnp.float32)

    def f_kernel(x, w):
        return jnp.sum(matmul_ad(x, w) ** 2)

    def f_ref(x, w):
        return jnp.sum((x @ w) ** 2)

    gx_k, gw_k = jax.grad(f_kernel, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-4)
    assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-4)


def test_vmem_footprint_analysis():
    fp = vmem_footprint(512, 256, 512)
    assert fp["block"] == (128, 256, 128)
    # (128*256 + 256*128 + 128*128) * 4 bytes
    assert fp["vmem_bytes_per_step"] == (128 * 256 * 2 + 128 * 128) * 4
    assert fp["mxu_tile_utilization"] == 1.0
    assert fp["grid_steps"] == 16
    # small matrices under-fill the MXU tile
    assert vmem_footprint(32, 32, 32)["mxu_tile_utilization"] < 0.1


# ------------------------------------------------------------- chunk_add --
@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=1, max_value=5000))
def test_chunk_add_matches_ref(n):
    a = _rand(8, (n,), jnp.float32)
    b = _rand(9, (n,), jnp.float32)
    assert_allclose(chunk_add(a, b), ref.chunk_add_ref(a, b), rtol=1e-6)


def test_chunk_add_nd_shapes():
    a = _rand(10, (7, 13, 3), jnp.float32)
    b = _rand(11, (7, 13, 3), jnp.float32)
    assert_allclose(chunk_add(a, b), a + b, rtol=1e-6)
    with pytest.raises(ValueError):
        chunk_add(a, b[:3])


# ------------------------------------------------------------------- sgd --
@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=1, max_value=4000),
       lr=st.floats(min_value=1e-4, max_value=1.0))
def test_sgd_matches_ref(n, lr):
    w = _rand(12, (n,), jnp.float32)
    g = _rand(13, (n,), jnp.float32)
    assert_allclose(sgd_apply(w, g, lr), ref.sgd_ref(w, g, lr), rtol=1e-5, atol=1e-6)


def test_sgd_2d_and_zero_lr():
    w = _rand(14, (31, 9), jnp.float32)
    g = _rand(15, (31, 9), jnp.float32)
    assert_allclose(sgd_apply(w, g, 0.0), w, rtol=0, atol=0)
    got = sgd_apply(w, g, 0.1)
    assert_allclose(got, w - 0.1 * g, rtol=1e-6)


def test_sgd_shape_mismatch():
    with pytest.raises(ValueError):
        sgd_apply(jnp.zeros((3,)), jnp.zeros((4,)), 0.1)
