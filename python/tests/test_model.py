"""L2 model tests: shapes, training signal, and the equivalence
train_step == grad_step + apply_grads (the invariant that lets the Rust
RAR engine sit between the two halves)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M


CFG = M.ModelConfig.preset("tiny")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    return M.make_batch(CFG, jax.random.PRNGKey(1))


def test_param_specs_order_is_stable(params):
    specs = M.param_specs(CFG)
    assert len(specs) == len(params)
    assert specs[0][0] == "tok_emb"
    assert specs[-1][0] == "head"
    for (name, shape), p in zip(specs, params):
        assert tuple(shape) == p.shape, name
    # canonical count for the tiny preset
    assert M.num_params(CFG) == sum(int(p.size) for p in params)


def test_forward_shapes_and_finiteness(params, batch):
    x, _ = batch
    logits = M.forward(CFG, params, x)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform(params, batch):
    x, y = batch
    loss = M.loss_fn(CFG, params, x, y)
    # near ln(vocab) at init
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_causality(params):
    """Future tokens must not influence earlier logits."""
    x1 = jnp.zeros((1, CFG.seq_len), jnp.int32)
    x2 = x1.at[0, -1].set(7)  # change only the last token
    l1 = M.forward(CFG, params, x1)
    l2 = M.forward(CFG, params, x2)
    assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


def test_loss_decreases_over_steps(params, batch):
    x, y = batch
    p = params
    losses = []
    for _ in range(8):
        loss, p = M.train_step(CFG, p, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, f"no training signal: {losses}"


def test_train_step_equals_grad_plus_apply(params, batch):
    x, y = batch
    loss_a, p_a = M.train_step(CFG, params, x, y)
    loss_b, grads = M.grad_step(CFG, params, x, y)
    p_b = M.apply_grads(CFG, params, grads)
    assert float(loss_a) == pytest.approx(float(loss_b), rel=1e-6)
    for a, b in zip(p_a, p_b):
        assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_data_parallel_grad_average_matches_big_batch(params):
    """Two workers on half-batches, averaged grads == full-batch grads —
    the correctness contract of the RAR data-parallel path."""
    x, y = M.make_batch(CFG, jax.random.PRNGKey(5))
    half = CFG.batch // 2
    _, g_full = M.grad_step(CFG, params, x, y)
    _, g0 = M.grad_step(CFG, params, x[:half], y[:half])
    _, g1 = M.grad_step(CFG, params, x[half:], y[half:])
    for gf, a, b in zip(g_full, g0, g1):
        assert_allclose((a + b) / 2, gf, rtol=2e-4, atol=2e-5)


def test_presets_scale():
    tiny = M.ModelConfig.preset("tiny")
    small = M.ModelConfig.preset("small")
    base = M.ModelConfig.preset("base")
    assert M.num_params(tiny) < M.num_params(small) < M.num_params(base)
    assert M.num_params(base) > 20e6
    with pytest.raises(ValueError):
        M.ModelConfig.preset("huge")
