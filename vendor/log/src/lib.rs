//! Minimal offline shim of the `log` facade API used by `rarsched`.
//!
//! Provides the five level macros, [`Level`] / [`LevelFilter`],
//! [`Metadata`] / [`Record`], the [`Log`] trait and the global logger
//! registry (`set_logger` / `set_max_level` / `max_level`).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Verbosity level of one record, most severe first (matches the real
/// crate's ordering: `Error < Warn < Info < Debug < Trace`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter (`Off` disables everything).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of one record: its level and target (module path).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the pre-formatted arguments.
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: Mutex<Option<&'static dyn Log>> = Mutex::new(None);

/// Install the global logger; fails if one is already set.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let mut slot = LOGGER.lock().unwrap();
    if slot.is_some() {
        return Err(SetLoggerError(()));
    }
    *slot = Some(logger);
    Ok(())
}

/// Set the global maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __dispatch(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level > max_level() {
        return;
    }
    let slot = LOGGER.lock().unwrap();
    if let Some(logger) = *slot {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__dispatch($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    // One combined test: the global max-level is process-wide state, and
    // the libtest harness runs tests concurrently.
    #[test]
    fn max_level_roundtrip_and_dispatch_without_logger() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Trace);
        assert_eq!(max_level(), LevelFilter::Trace);
        crate::info!("no logger installed — must not panic: {}", 42);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
