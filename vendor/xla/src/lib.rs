//! Offline **stub** of the PJRT/XLA binding surface used by `rarsched`.
//!
//! The scheduler, simulator and online subsystem never touch XLA; only the
//! live-training runtime (`rarsched::runtime`, the `train`/`verify`
//! subcommands and the artifact-gated tests) does. Those paths are gated
//! on an artifacts directory produced by `make artifacts`, and skip
//! cleanly when it is absent — so this stub only needs to *type-check*
//! the runtime layer. Every entry point that would require a real PJRT
//! backend returns [`Error::Unavailable`] with a clear message.
//!
//! Swap this crate for real PJRT bindings by changing the `xla` path
//! dependency in `rust/Cargo.toml`.

use std::borrow::Borrow;
use std::path::Path;

/// Error type of the stub. `Unavailable` marks the entry points that need
/// a real backend.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
    Other(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT backend not available in this offline build \
                 (the `xla` dependency is the in-tree stub; see vendor/README.md)"
            ),
            Error::Other(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for Error {}

type XResult<T> = std::result::Result<T, Error>;

/// Element dtypes used by the runtime layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// A host-side literal (stub: carries only shape/dtype bookkeeping).
#[derive(Debug, Clone)]
pub struct Literal {
    dtype: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(values: &[f32]) -> Literal {
        let mut bytes = Vec::with_capacity(values.len() * 4);
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        Literal { dtype: ElementType::F32, dims: vec![values.len() as i64], bytes }
    }

    /// Reshape to new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> XResult<Literal> {
        let want: i64 = dims.iter().product();
        let have: i64 = self.dims.iter().product();
        if want != have {
            return Err(Error::Other(format!(
                "reshape: cannot view {have} elements as {dims:?}"
            )));
        }
        Ok(Literal { dtype: self.dtype, dims: dims.to_vec(), bytes: self.bytes.clone() })
    }

    /// Build a literal from a shape and raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        dtype: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> XResult<Literal> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * 4 {
            return Err(Error::Other(format!(
                "literal: {} bytes for shape {dims:?} (want {})",
                data.len(),
                elems * 4
            )));
        }
        Ok(Literal {
            dtype,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    /// Copy out as a typed vector (stub: f32 only carries real data).
    pub fn to_vec<T: FromLeBytes>(&self) -> XResult<Vec<T>> {
        Ok(self.bytes.chunks_exact(4).map(T::from_le_4).collect())
    }

    /// Destructure a tuple literal. The stub never produces tuples, so
    /// this is only reachable after an `Unavailable` error upstream.
    pub fn to_tuple(&self) -> XResult<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

/// Helper trait for [`Literal::to_vec`] (f32 / i32 payloads).
pub trait FromLeBytes {
    fn from_le_4(b: &[u8]) -> Self;
}

impl FromLeBytes for f32 {
    fn from_le_4(b: &[u8]) -> Self {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl FromLeBytes for i32 {
    fn from_le_4(b: &[u8]) -> Self {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

/// Parsed HLO module (stub: parsing always fails — it would need XLA).
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> XResult<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XResult<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> XResult<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client (stub: construction fails with a clear message).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> XResult<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> XResult<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let sq = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(sq.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn untyped_data_size_checked() {
        let ok = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[2],
            &[1, 0, 0, 0, 2, 0, 0, 0],
        )
        .unwrap();
        assert_eq!(ok.to_vec::<i32>().unwrap(), vec![1, 2]);
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0; 4]).is_err()
        );
    }

    #[test]
    fn backend_paths_report_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("offline"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
