//! Minimal offline shim of the `anyhow` API surface used by `rarsched`.
//!
//! Provides [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros and
//! the [`Context`] extension trait. An error is a message plus a chain of
//! context frames; `{:#}` renders the whole chain ("outermost: cause:
//! ...") like the real crate.

use std::fmt;

/// A dynamic error: the outermost message first, then successively deeper
/// causes (the reverse of how contexts were attached).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach a higher-level context message (becomes the new outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (deepest message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, outermost first
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug mirrors the real crate: message plus causes.
        write!(f, "{}", self.chain.join(": "))
    }
}

// Blanket conversion from any std error (io, parse, ...). `Error` itself
// deliberately does NOT implement `std::error::Error`, exactly like the
// real anyhow, so this impl cannot overlap the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to any
/// `Result` whose error converts into [`Error`] (std errors and `Error`
/// itself).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{anyhow, bail};

    fn parse(s: &str) -> Result<u64> {
        let n: u64 = s.parse()?; // std error converts via `?`
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_chains_render_alternate() {
        let e: Error = parse("x").context("reading config").unwrap_err();
        let plain = format!("{e}");
        let full = format!("{e:#}");
        assert_eq!(plain, "reading config");
        assert!(full.starts_with("reading config: "), "got {full}");
        assert!(full.len() > plain.len());
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag}");
            }
            Err(anyhow!("fell through"))
        }
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
        assert_eq!(f(false).unwrap_err().root_cause(), "fell through");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
