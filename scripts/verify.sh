#!/usr/bin/env bash
# Tier-1 verification pipeline: fmt-check -> release build -> tests ->
# bench smoke. The bench smoke also emits BENCH_topology.json (the
# online_hot_path / per-link tracker numbers) so the perf trajectory is
# recorded across PRs.
#
# Usage: scripts/verify.sh           # from anywhere inside the repo
#   RARSCHED_BENCH_MS=200            # (default here) bench budget per case

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== [1/4] cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    # fmt never gates the build offline, but drift is reported loudly
    cargo fmt --all -- --check || echo "WARN: rustfmt reports drift (non-fatal)"
else
    echo "WARN: rustfmt unavailable in this toolchain; skipping"
fi

echo "== [2/4] cargo build --release =="
cargo build --release --offline

echo "== [3/4] cargo test -q =="
cargo test -q --offline

echo "== [4/4] bench smoke (online_hot_path -> BENCH_topology.json) =="
# cargo runs bench binaries with cwd at the package root (rust/), so pin
# the output path to the repo root explicitly.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_OUT="$PWD/BENCH_topology.json" \
    cargo bench --offline --bench online_hot_path

if [ -f BENCH_topology.json ]; then
    echo "OK: BENCH_topology.json written"
else
    echo "ERROR: bench smoke did not emit BENCH_topology.json" >&2
    exit 1
fi

echo "verify: all stages passed"
