#!/usr/bin/env bash
# Tier-1 verification pipeline: fallback lint -> fmt-check -> release
# build -> tests -> archlint -> clippy -> bench smoke -> trace
# well-formedness -> streaming smoke -> fault-injection smoke ->
# ledger diff smoke.
#
# Stage 1 is scripts/lint.sh — the toolchain-free awk mirror of the top
# archlint rules. It runs BEFORE the cargo-presence check on purpose: a
# container without a Rust toolchain still gets one executable gate.
# Stage 5 is the real analyzer (`rarsched archlint`, rust/src/lint/): it
# must exit clean AND emit the LINT.json artifact (rule counts, allow
# census, RunManifest stamp), which is gated below like the BENCH_*.json
# files. Stage 6 runs the curated [workspace.lints] clippy profile when
# cargo-clippy exists (warn-only surface; archlint is the hard gate).
#
# The bench smoke emits
# BENCH_topology.json (the online_hot_path / per-link tracker numbers),
# BENCH_online_overload.json (the speculative what-if tracker path behind
# θ-admission and migration), BENCH_sim_engine.json (batch-engine
# events/sec + ns/event, snapshot-rebuild vs tracker+dirty-set),
# BENCH_net_alloc.json (progressive-filling allocations/sec +
# MaxMinFair-vs-EffectiveDegree engine events/sec) and BENCH_obs.json
# (observability hook overhead: disarmed vs Null-sink vs Mem-sink
# tracing), BENCH_stream.json (streaming vs materialized engine on the
# same 10^5-job arrival stream, with the sketch-vs-exact equivalence
# block gated below) and BENCH_faults.json (fault-injection overhead:
# no-trace vs empty-trace — asserted bit-identical in-bench and gated on
# the recorded boolean here — plus storm cases with the recovery ledger)
# and BENCH_ledger.json (flight-recorder overhead: disarmed vs armed
# digesting across checkpoint cadences, passivity asserted in-bench and
# gated on the recorded boolean here) so the perf trajectory is recorded
# across PRs. The last four stages emit a real `--trace-out` Chrome-trace
# file gated by `rarsched obs-check` (well-formed JSON, known phases,
# monotone non-negative timestamps), run an `online --stream` smoke
# through the full CLI path, gating on its artifacts and manifest stamp,
# run the fault path end-to-end: `fault-trace` dumps a seeded trace which
# `online --faults @trace.json` replays, gated on the injection actually
# being routed — and close with divergence forensics: two runs that the
# net/ equivalence guarantee pins bit-identical (EffectiveDegree vs
# MaxMinFair on a capacity-mirroring fabric) record `--ledger` digests
# which `rarsched diff` must report as zero divergence.
#
# Failure policy: when cargo is PRESENT, every stage is a hard gate —
# fmt drift, a build error, a test failure, a missing bench artifact or
# a malformed trace all fail the script. The only soft-skip is rustfmt
# being absent from the toolchain (reported loudly; the fmt *check*
# itself is never soft-failed).
#
# Usage: scripts/verify.sh           # from anywhere inside the repo
#   RARSCHED_BENCH_MS=200            # (default here) bench budget per case

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== [1/11] scripts/lint.sh (toolchain-free fallback rules) =="
# Hard gate, and the only one that runs without cargo.
scripts/lint.sh

if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: cargo not found on PATH — tier-1 verification cannot run." >&2
    echo "       (cargo build --release && cargo test -q is the gate; do not ship unverified.)" >&2
    exit 1
fi

echo "== [2/11] cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    # fmt drift is a hard failure (gated step)
    cargo fmt --all -- --check
else
    echo "WARN: rustfmt unavailable in this toolchain; fmt gate skipped"
fi

echo "== [3/11] cargo build --release =="
cargo build --release --offline

echo "== [4/11] cargo test -q =="
cargo test -q --offline

echo "== [5/11] archlint (self-hosted static analysis -> LINT.json) =="
# The analyzer exits non-zero on any unannotated finding; --out writes
# the artifact even on failure so the diagnostics land in both places.
LINT_OUT="$PWD/LINT.json"
./target/release/archlint --out "$LINT_OUT" rust/src
if [ ! -f "$LINT_OUT" ]; then
    echo "ERROR: archlint did not emit $LINT_OUT" >&2
    exit 1
fi
# Belt-and-braces on the artifact itself: a stale or hand-edited file
# with findings (or without its provenance stamp) fails the gate even
# though the analyzer already exited clean.
for field in '"findings_total": *0' '"rules"' '"allows"' '"manifest"'; do
    if ! grep -Eq "$field" "$LINT_OUT"; then
        echo "ERROR: LINT.json missing $field" >&2
        exit 1
    fi
done
echo "OK: LINT.json written and gated"

echo "== [6/11] cargo clippy ([workspace.lints] profile) =="
# Curated warn-level surface (unwrap_used, indexing_slicing, float_cmp,
# iter_over_hash_type, …) — soft-gated on toolchain availability because
# clippy is not baked into every container; archlint above is the hard
# enforcement of the same invariants.
if command -v cargo-clippy >/dev/null 2>&1; then
    cargo clippy --release --offline --all-targets
else
    echo "WARN: cargo-clippy unavailable in this toolchain; clippy stage skipped"
fi

echo "== [7/11] bench smoke (online_hot_path + sim_engine + net_alloc + obs + stream + faults + ledger -> BENCH_*.json) =="
# cargo runs bench binaries with cwd at the package root (rust/), so pin
# the output paths to the repo root explicitly.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_OUT="$PWD/BENCH_topology.json" \
    RARSCHED_BENCH_OVERLOAD_OUT="$PWD/BENCH_online_overload.json" \
    cargo bench --offline --bench online_hot_path

# Engine baseline: snapshot-rebuild vs tracker+dirty-set events/sec and
# ns/event (flat + 2-rack, three cluster sizes) — the perf trajectory of
# the batch simulator finally has a diffable artifact.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_SIM_OUT="$PWD/BENCH_sim_engine.json" \
    cargo bench --offline --bench sim_engine

# Bandwidth-allocation baseline: progressive-filling allocations/sec
# (flat vs rack vs pod), the O(1)-histogram vs O(L)-scan max_contention
# query, and the engine cost of MaxMinFair vs EffectiveDegree.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_NET_OUT="$PWD/BENCH_net_alloc.json" \
    cargo bench --offline --bench net_alloc

# Observability overhead: the passivity invariant's perf half — the
# armed-vs-null hook cost on the 2-rack engine cases (target: null ≤ ~5%
# over fully disarmed; the JSON records the measured percentages).
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_OBS_OUT="$PWD/BENCH_obs.json" \
    cargo bench --offline --bench obs_overhead

# Streaming engine: run_streaming vs materialize-then-run on the same
# 10^5-job Poisson stream. The bench asserts exact aggregate equality and
# the 1/32 sketch bound internally; the JSON records them as gateable
# booleans. (RARSCHED_BENCH_STREAM_FULL=1 adds the 10^6-job x 10^4-server
# acceptance case — too slow for the per-PR smoke.)
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_STREAM_OUT="$PWD/BENCH_stream.json" \
    cargo bench --offline --bench stream

# Fault injection: the empty-trace case is asserted bit-identical to the
# fault-free baseline inside the bench (equivalence by construction),
# and the storm cases record the recovery ledger (kills, recoveries,
# mean recovery wait) for wait-for-home vs migration-armed recovery.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_FAULTS_OUT="$PWD/BENCH_faults.json" \
    cargo bench --offline --bench faults

# Flight recorder: disarmed vs armed run-digest cost on the online loop
# across checkpoint cadences (plus the --ledger-events fingerprint
# ring). The bench asserts the passivity invariant on every armed mode
# before writing the file.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_LEDGER_OUT="$PWD/BENCH_ledger.json" \
    cargo bench --offline --bench ledger

for artifact in BENCH_topology.json BENCH_online_overload.json BENCH_sim_engine.json \
                BENCH_net_alloc.json BENCH_obs.json BENCH_stream.json \
                BENCH_faults.json BENCH_ledger.json; do
    if [ -f "$artifact" ]; then
        echo "OK: $artifact written"
    else
        echo "ERROR: bench smoke did not emit $artifact" >&2
        exit 1
    fi
done

# The stream bench's equivalence block is the cross-engine contract:
# exact aggregates bit-identical, sketch p95 within the 1/32 bound. The
# bench asserts these before writing the file; gate on the recorded
# booleans (and the provenance stamp) anyway so a hand-edited or stale
# artifact cannot pass.
for field in '"sketch_within_bound": *true' '"exact_match": *true' '"manifest"'; do
    if ! grep -Eq "$field" BENCH_stream.json; then
        echo "ERROR: BENCH_stream.json missing $field" >&2
        exit 1
    fi
done
echo "OK: BENCH_stream.json equivalence block gated"

# Same belt-and-braces on the fault bench: the empty fault trace must
# have matched the fault-free baseline bit for bit (asserted in-bench
# before the file is written; gated here against stale artifacts).
for field in '"empty_trace_exact_match": *true' '"manifest"'; do
    if ! grep -Eq "$field" BENCH_faults.json; then
        echo "ERROR: BENCH_faults.json missing $field" >&2
        exit 1
    fi
done
echo "OK: BENCH_faults.json equivalence block gated"

# And on the ledger bench: every armed mode must have matched the
# disarmed reference outcome bit for bit (asserted in-bench before the
# file is written; gated here against stale artifacts).
for field in '"passivity_ok": *true' '"manifest"'; do
    if ! grep -Eq "$field" BENCH_ledger.json; then
        echo "ERROR: BENCH_ledger.json missing $field" >&2
        exit 1
    fi
done
echo "OK: BENCH_ledger.json passivity block gated"

echo "== [8/11] trace export well-formedness (simulate --trace-out -> obs-check) =="
# Emit a real Chrome trace through the full CLI path, then gate on the
# validator: well-formed JSON, known phases, non-negative and per-thread
# monotone timestamps. The sample trace is a throwaway smoke artifact.
TRACE_SAMPLE="$PWD/trace_sample.json"
rm -f "$TRACE_SAMPLE" "$TRACE_SAMPLE.manifest.json"
./target/release/rarsched simulate --policy sjf-bco --scale 0.1 \
    --trace-out "$TRACE_SAMPLE" >/dev/null
if [ ! -f "$TRACE_SAMPLE" ]; then
    echo "ERROR: simulate --trace-out did not emit $TRACE_SAMPLE" >&2
    exit 1
fi
./target/release/rarsched obs-check "$TRACE_SAMPLE"
rm -f "$TRACE_SAMPLE" "$TRACE_SAMPLE.manifest.json"

echo "== [9/11] streaming online smoke (online --stream -> artifacts + manifest) =="
# The O(active)-memory engine through the full CLI path: a lazy 2000-job
# stream on the 0.1-scale fabric, artifacts written by the same streaming
# writers the tests pin byte-identical. Gate on the table artifacts and
# the provenance stamp landing next to them.
STREAM_DIR="$PWD/stream_smoke"
rm -rf "$STREAM_DIR"
./target/release/rarsched online --stream --stream-jobs 2000 --scale 0.1 \
    --gap 1.0 --policies fifo,sjf-bco --out "$STREAM_DIR" >/dev/null
for artifact in online.csv online.json run_manifest.json; do
    if [ ! -f "$STREAM_DIR/$artifact" ]; then
        echo "ERROR: online --stream did not emit $artifact" >&2
        exit 1
    fi
done
if ! grep -q '"seed"' "$STREAM_DIR/run_manifest.json"; then
    echo "ERROR: streaming run_manifest.json missing its seed stamp" >&2
    exit 1
fi
echo "OK: streaming smoke artifacts + manifest stamp"
rm -rf "$STREAM_DIR"

echo "== [10/11] fault-injection smoke (fault-trace dump -> online --faults replay) =="
# The fault path end-to-end through the CLI: dump a seeded trace with the
# standalone subcommand, replay it through `online --faults @file`, and
# gate on (a) the dump being a well-formed non-empty trace and (b) the
# comparison table recording that fault events were actually injected
# (its title carries the "N fault events" suffix only when the merged
# trace is non-empty — a silently inert flag fails here).
FAULT_DIR="$PWD/fault_smoke"
FAULT_TRACE="$PWD/fault_trace_smoke.json"
rm -rf "$FAULT_DIR"
rm -f "$FAULT_TRACE"
./target/release/rarsched fault-trace "server:800:150,seed:3" \
    --servers 8 --horizon 20000 --out "$FAULT_TRACE" >/dev/null
if [ ! -f "$FAULT_TRACE" ]; then
    echo "ERROR: fault-trace did not emit $FAULT_TRACE" >&2
    exit 1
fi
for field in '"events"' '"seed"' 'server-crash'; do
    if ! grep -q "$field" "$FAULT_TRACE"; then
        echo "ERROR: fault_trace_smoke.json missing $field" >&2
        exit 1
    fi
done
./target/release/rarsched online --scale 0.1 --gap 1.0 --policies fifo,sjf-bco \
    --migrate --faults "@$FAULT_TRACE" --out "$FAULT_DIR" >/dev/null
for artifact in online.csv online.json run_manifest.json; do
    if [ ! -f "$FAULT_DIR/$artifact" ]; then
        echo "ERROR: online --faults did not emit $artifact" >&2
        exit 1
    fi
done
if ! grep -q 'fault events' "$FAULT_DIR/online.json"; then
    echo "ERROR: online --faults ran but the table does not record injected fault events" >&2
    exit 1
fi
echo "OK: fault-injection smoke (trace dump + replay + injection recorded)"
rm -rf "$FAULT_DIR"
rm -f "$FAULT_TRACE"

echo "== [11/11] ledger diff smoke (two equivalent runs -> rarsched diff) =="
# Divergence forensics end-to-end through the CLI: record the run-digest
# flight recorder on two runs the net/ equivalence guarantee pins bit
# identical — EffectiveDegree vs MaxMinFair contention on a
# capacity-mirroring rack fabric (tests/net_equivalence.rs) — then
# `rarsched diff` must report zero divergence (exit 0; it exits non-zero
# on the first divergent checkpoint). This is the workflow the diff
# subcommand exists for: when an equivalence ladder breaks, the same two
# commands localize WHERE the runs first part ways.
LEDGER_DIR="$PWD/ledger_smoke"
rm -rf "$LEDGER_DIR"
mkdir -p "$LEDGER_DIR"
./target/release/rarsched online --scale 0.1 --gap 1.0 --policies sjf-bco \
    --no-clairvoyant --migrate --topology rack:4:2.0 --contention degree \
    --ledger "$LEDGER_DIR/degree.json" --ledger-events >/dev/null
./target/release/rarsched online --scale 0.1 --gap 1.0 --policies sjf-bco \
    --no-clairvoyant --migrate --topology rack:4:2.0 --contention maxmin \
    --ledger "$LEDGER_DIR/maxmin.json" --ledger-events >/dev/null
for artifact in degree.json maxmin.json; do
    if [ ! -f "$LEDGER_DIR/$artifact" ]; then
        echo "ERROR: online --ledger did not emit $artifact" >&2
        exit 1
    fi
done
./target/release/rarsched diff "$LEDGER_DIR/degree.json" "$LEDGER_DIR/maxmin.json"
echo "OK: ledger diff smoke (equivalent runs digest identically)"
rm -rf "$LEDGER_DIR"

echo "verify: all stages passed"
