#!/usr/bin/env bash
# Tier-1 verification pipeline: fmt-check -> release build -> tests ->
# bench smoke. The bench smoke emits BENCH_topology.json (the
# online_hot_path / per-link tracker numbers), BENCH_online_overload.json
# (the speculative what-if tracker path behind θ-admission and migration),
# BENCH_sim_engine.json (batch-engine events/sec + ns/event,
# snapshot-rebuild vs tracker+dirty-set) and BENCH_net_alloc.json
# (progressive-filling allocations/sec + MaxMinFair-vs-EffectiveDegree
# engine events/sec) so the perf trajectory is recorded across PRs.
#
# Failure policy: when cargo is PRESENT, every stage is a hard gate —
# fmt drift, a build error, a test failure or a missing bench artifact
# all fail the script. The only soft-skip is rustfmt being absent from
# the toolchain (reported loudly; the fmt *check* itself is never
# soft-failed).
#
# Usage: scripts/verify.sh           # from anywhere inside the repo
#   RARSCHED_BENCH_MS=200            # (default here) bench budget per case

set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: cargo not found on PATH — tier-1 verification cannot run." >&2
    echo "       (cargo build --release && cargo test -q is the gate; do not ship unverified.)" >&2
    exit 1
fi

echo "== [1/4] cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    # fmt drift is a hard failure (gated step)
    cargo fmt --all -- --check
else
    echo "WARN: rustfmt unavailable in this toolchain; fmt gate skipped"
fi

echo "== [2/4] cargo build --release =="
cargo build --release --offline

echo "== [3/4] cargo test -q =="
cargo test -q --offline

echo "== [4/4] bench smoke (online_hot_path + sim_engine -> BENCH_*.json) =="
# cargo runs bench binaries with cwd at the package root (rust/), so pin
# the output paths to the repo root explicitly.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_OUT="$PWD/BENCH_topology.json" \
    RARSCHED_BENCH_OVERLOAD_OUT="$PWD/BENCH_online_overload.json" \
    cargo bench --offline --bench online_hot_path

# Engine baseline: snapshot-rebuild vs tracker+dirty-set events/sec and
# ns/event (flat + 2-rack, three cluster sizes) — the perf trajectory of
# the batch simulator finally has a diffable artifact.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_SIM_OUT="$PWD/BENCH_sim_engine.json" \
    cargo bench --offline --bench sim_engine

# Bandwidth-allocation baseline: progressive-filling allocations/sec
# (flat vs rack vs pod), the O(1)-histogram vs O(L)-scan max_contention
# query, and the engine cost of MaxMinFair vs EffectiveDegree.
RARSCHED_BENCH_MS="${RARSCHED_BENCH_MS:-200}" \
    RARSCHED_BENCH_NET_OUT="$PWD/BENCH_net_alloc.json" \
    cargo bench --offline --bench net_alloc

for artifact in BENCH_topology.json BENCH_online_overload.json BENCH_sim_engine.json \
                BENCH_net_alloc.json; do
    if [ -f "$artifact" ]; then
        echo "OK: $artifact written"
    else
        echo "ERROR: bench smoke did not emit $artifact" >&2
        exit 1
    fi
done

echo "verify: all stages passed"
