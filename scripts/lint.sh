#!/usr/bin/env bash
# scripts/lint.sh — toolchain-free fallback for the top archlint rules.
#
# The real analyzer is `rarsched archlint` (rust/src/lint/): a lexing
# rule engine with comment/string stripping, region tracking and an
# allow-audit. This script mirrors its three highest-signal rules in
# portable awk so a container WITHOUT cargo still has an executable
# lint gate:
#
#   release-panic   — .unwrap()/.expect(/panic!/unreachable!/todo!/
#                     unimplemented! in hot-path modules
#                     (sim/ online/ contention/ net/ topology/ faults/)
#   obs-binding     — `let name = metrics::get(...)` / `let name = obs::…`
#                     / `let name = ledger::…` / `let name = prof::…`
#                     in decision modules (sim/ online/ sched/
#                     contention/ net/ faults/): observability results must not
#                     feed scheduling state (underscore bindings pass)
#   hash-iteration  — iterating a locally-declared HashMap/HashSet
#                     (.iter()/.keys()/.values()/.drain()/`for … in &m`):
#                     hash order is nondeterministic; use BTreeMap or
#                     sort first
#
# Shared exclusions, mirroring the analyzer:
#   * test regions: from a `#[cfg(test)]` line to end-of-file
#   * `debug_assert`/`#[cfg(debug_assertions)]` lines (compiled out of
#     release builds)
#   * lines covered by an `// archlint: allow(<rule>…) reason`
#     annotation — trailing on the same line, standalone on the
#     previous line, or a standalone annotation on a `fn` header which
#     covers the whole body (tracked by brace depth)
#
# The fallback is deliberately cruder than the analyzer (no string
# stripping, no float census); it must stay a SUBSET: anything it flags,
# archlint flags too. Exit 0 = clean, 1 = findings, 2 = usage error.
#
# Usage: scripts/lint.sh [root-dir]    # default rust/src, then src

set -euo pipefail
cd "$(dirname "$0")/.."

ROOT="${1:-}"
if [ -z "$ROOT" ]; then
    if [ -d rust/src ]; then ROOT=rust/src; else ROOT=src; fi
fi
if [ ! -d "$ROOT" ]; then
    echo "lint.sh: no such directory: $ROOT" >&2
    exit 2
fi

AWK_PROG='
# Two passes over the same file: pass 1 builds the HashMap/HashSet name
# census, pass 2 lints. mawk-compatible (no \< word boundaries).

NR == FNR {
    line = $0
    # the census must not see test-only declarations
    if (line ~ /#\[cfg\(test\)\]/) { census_test = 1 }
    if (census_test) { next }
    # census: `name: HashMap<` / `name: HashSet<` declarations and
    # `let [mut] name = HashMap::…` bindings; `use …::HashMap` has no
    # ":" or "=" before the type name so it never matches.
    if (match(line, /[a-z_][a-z0-9_]*[ \t]*:[ \t]*Hash(Map|Set)[ \t]*</)) {
        name = substr(line, RSTART, RLENGTH)
        sub(/[ \t]*:.*/, "", name)
        hash[name] = 1
    }
    if (match(line, /let[ \t]+(mut[ \t]+)?[a-z_][a-z0-9_]*[ \t]*=[ \t]*Hash(Map|Set)/)) {
        name = substr(line, RSTART, RLENGTH)
        sub(/^let[ \t]+/, "", name)
        sub(/^mut[ \t]+/, "", name)
        sub(/[ \t]*=.*/, "", name)
        hash[name] = 1
    }
    next
}

# ---- pass 2: lint ----
{
    raw = $0

    # test region: house style keeps `#[cfg(test)] mod tests` last.
    if (raw ~ /#\[cfg\(test\)\]/) { in_test = 1 }
    if (in_test) { next }

    # allow annotations: trailing covers its own line; standalone covers
    # the next code line (and the whole body when that line is a fn
    # header). Doc comments (///, //!) are prose, not annotations.
    allowed = 0
    if (raw ~ /\/\/[ \t]*archlint:[ \t]*allow\(/ && raw !~ /\/\/[\/!]/) {
        allowed = 1
        if (raw ~ /^[ \t]*\/\//) { pending = 1; next }
    }
    if (pending) { allowed = 1 }

    # strip line comments (crude: breaks on "//" inside strings — fine
    # for a fallback; the analyzer strips properly)
    code = raw
    sub(/\/\/.*/, "", code)
    if (code ~ /^[ \t]*$/) { next }
    # attribute lines between a standalone allow and its target do not
    # consume the pending coverage
    if (code !~ /^[ \t]*#\[/) { pending = 0 }

    # brace-depth bookkeeping for fn-scope coverage
    depth_before = depth
    tmp = code; depth += gsub(/\{/, "", tmp)
    tmp = code; depth -= gsub(/\}/, "", tmp)
    if (fn_cover && depth_before <= fn_cover_depth) { fn_cover = 0 }
    if (allowed && code ~ /(^|[ \t])fn[ \t]/) {
        fn_cover = 1
        fn_cover_depth = depth_before
    }
    if (fn_cover) { allowed = 1 }
    if (allowed) { next }

    # debug-only lines are compiled out of release builds
    if (code ~ /debug_assert|cfg\(debug_assertions\)/) { next }

    # release-panic: hot-path modules only
    if (hot && code ~ /\.unwrap\(\)|\.expect\(|(^|[^a-z_])panic!|unreachable!|(^|[^a-z_])todo!|unimplemented!/) {
        printf "%s:%d: [release-panic] panicking construct on a hot path: %s\n", path, FNR, trim(code)
        findings++
    }

    # obs-binding: decision modules; `let _x =` (inspection) passes
    if (dec && code ~ /let[ \t]+(mut[ \t]+)?[a-zA-Z][a-zA-Z0-9_]*[ \t]*=[ \t]*(metrics::get|obs::|ledger::|prof::)/) {
        printf "%s:%d: [obs-binding] observability result bound in a decision module: %s\n", path, FNR, trim(code)
        findings++
    }

    # hash-iteration: any censused HashMap/HashSet name iterated
    for (name in hash) {
        if (code ~ ("(^|[^A-Za-z0-9_])" name "\\.(iter|iter_mut|keys|values|values_mut|drain|into_iter)\\(") ||
            code ~ ("(^|[ \t])in[ \t]+&(mut[ \t]+)?" name "([^A-Za-z0-9_]|$)")) {
            printf "%s:%d: [hash-iteration] hash-order iteration over `%s`: %s\n", path, FNR, name, trim(code)
            findings++
        }
    }
}

function trim(s) { sub(/^[ \t]+/, "", s); sub(/[ \t]+$/, "", s); return s }

END { exit (findings > 0 ? 1 : 0) }
'

files=0
findings_files=0
status=0
out=""
# find -print | sort keeps the report order stable across filesystems
for f in $(find "$ROOT" -name '*.rs' | sort); do
    files=$((files + 1))
    case "$f" in
        */sim/*|*/online/*|*/contention/*|*/net/*|*/topology/*|*/faults/*) hot=1 ;;
        *) hot=0 ;;
    esac
    case "$f" in
        */sim/*|*/online/*|*/sched/*|*/contention/*|*/net/*|*/faults/*) dec=1 ;;
        *) dec=0 ;;
    esac
    if ! file_out=$(awk -v path="$f" -v hot="$hot" -v dec="$dec" "$AWK_PROG" "$f" "$f"); then
        status=1
        findings_files=$((findings_files + 1))
    fi
    [ -n "$file_out" ] && out="${out}${file_out}
"
done

if [ "$status" -ne 0 ]; then
    printf '%s' "$out"
    echo "lint.sh: findings in $findings_files of $files files — fix or annotate (// archlint: allow(<rule>) reason)" >&2
    exit 1
fi
echo "lint.sh: $files files clean (fallback rules: release-panic, obs-binding, hash-iteration)"
