//! `net/` — bandwidth allocation over the fabric: max-min fair link
//! sharing across heterogeneous capacities.
//!
//! The paper's Eq. 6 models contention as a *ring count* on the bottleneck
//! link, implicitly assuming every inter-server link is identical and
//! every co-located ring degrades equally. Real fabrics are not uniform:
//! a ToR uplink may carry 4× (or ¼×) the capacity of a server uplink, and
//! what a ring actually experiences is a **bandwidth share** of each link
//! it crosses. This module supplies that model:
//!
//! * [`LinkCapacity`] — an absolute per-link capacity (Gbps) plus the
//!   *exact* ratio `reference / capacity` used in share arithmetic;
//! * [`ContentionModel`] — the axis every engine dispatches on:
//!   [`EffectiveDegree`](ContentionModel::EffectiveDegree) (the paper's
//!   `count × oversub`) vs [`MaxMinFair`](ContentionModel::MaxMinFair)
//!   (`count × capacity-ratio`, i.e. the reciprocal of the ring's
//!   bottleneck fair share);
//! * [`progressive_fill`] — the classic max-min **progressive-filling**
//!   (water-filling) allocator over the whole active set: per-ring
//!   max-min rates and per-link residual bandwidth.
//!
//! # The share model and the Eq. 6 equivalence argument
//!
//! Under max-min fair sharing, link `ℓ` of capacity `c_ℓ` crossed by
//! `n_ℓ` rings gives each ring an equal share `c_ℓ / n_ℓ` (no ring is
//! entitled to more until another leaves headroom). A ring's end-to-end
//! rate is gated by its most-contended crossed link, so its **bottleneck
//! fair share** is
//!
//! ```text
//! r_j = min_{ℓ crossed} c_ℓ / n_ℓ  =  c_ref / max_{ℓ crossed} n_ℓ · (c_ref / c_ℓ)
//! ```
//!
//! The maximand `n_ℓ · ratio_ℓ` is exactly the paper's effective degree
//! with the oversubscription factor replaced by the capacity ratio — so
//! when every capacity mirrors the scalar spec (`c_ℓ = c_ref / oversub_ℓ`,
//! ratio stored as the *same float* as the factor), the share bottleneck
//! and the degree bottleneck coincide **bit for bit**, and on a uniform
//! flat fabric (`ratio ≡ 1`) both collapse to Eq. 6's raw count. That is
//! the equivalence `tests/net_equivalence.rs` enforces across all three
//! engine modes and the online loop: every existing figure is the
//! uniform-capacity special case of this subsystem, not a casualty.
//!
//! Where the models genuinely diverge is **heterogeneous absolute
//! capacity** — above all *relief links*. A ToR provisioned at 4× the
//! server uplinks has ratio ¼: three rings aggregated on it consume less
//! headroom than two rings on a server uplink. Degree counting cannot
//! express a factor below 1 (`oversub ≥ 1` by construction), so it
//! bottlenecks on the crowded fat link; the share model correctly keeps
//! the bottleneck at the skinny uplink. The `hetero_sweep` experiment
//! (`figures --fig hetero`) quantifies the makespan gap.
//!
//! # Why the engines rate rings at the bottleneck share
//!
//! Full progressive filling can hand a ring **more** than its bottleneck
//! fair share: a neighbor frozen early at a hotter link stops claiming
//! its equal split, and the filler redistributes the leftover. (Concrete
//! instance, all capacities `c`: rings A = {ℓ₀}, B = {ℓ₀, ℓ₁}, C,D = {ℓ₁}.
//! ℓ₁ saturates first at level c/3 freezing B, C, D; A then water-fills to
//! 2c/3 — strictly above its c/2 equal split on ℓ₀.) That redistribution
//! is *non-local*: one admission can ripple rates across links the
//! newcomer never crosses, which would both break the exact Eq. 6 collapse
//! above and invalidate the link-local dirty-set rule the incremental
//! engines rely on. The engines therefore rate every ring at its
//! bottleneck fair share — the max-min **guarantee** (progressive filling
//! never allocates less; property-tested below) and the exact Eq. 6
//! generalization — while [`progressive_fill`] computes the full
//! water-filled rates and per-link residuals for reports, admission
//! diagnostics and the `net_alloc` bench. A ring's modeled rate then
//! depends only on its own crossed links' counts, so the dirty-set
//! invalidation rule "re-rate iff a crossed link's residual moved" stays
//! `O(touched × members)` per event.

use crate::cluster::JobPlacement;
use crate::jobs::JobId;
use crate::topology::{LinkId, Topology};
use crate::Result;
use anyhow::bail;

/// Reference link speed (Gbps) when a spec gives only oversubscription
/// factors: 10 GbE, the inter-server fabric of the paper's testbed [19].
pub const DEFAULT_UPLINK_GBPS: f64 = 10.0;

/// How the engines evaluate a ring's contention at a fabric link.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ContentionModel {
    /// The paper's Eq. 6 generalization: effective degree
    /// `count × oversub` at the worst crossed link, with `oversub ≥ 1` a
    /// dimensionless factor. The default; ignores absolute capacities.
    #[default]
    EffectiveDegree,
    /// Max-min fair bandwidth shares: each link's absolute capacity is
    /// split equally among the rings crossing it, a ring is gated by its
    /// bottleneck share, and the effective degree becomes
    /// `count × (c_ref / c_ℓ)` — bit-identical to `EffectiveDegree`
    /// whenever capacities mirror the oversubscription spec, strictly
    /// more expressive under heterogeneous (esp. relief) capacities.
    MaxMinFair,
}

impl ContentionModel {
    pub fn name(self) -> &'static str {
        match self {
            ContentionModel::EffectiveDegree => "degree",
            ContentionModel::MaxMinFair => "maxmin",
        }
    }
}

impl std::fmt::Display for ContentionModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for ContentionModel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "degree" | "effective-degree" | "eq6" => Ok(ContentionModel::EffectiveDegree),
            "maxmin" | "max-min" | "maxmin-fair" | "max-min-fair" => {
                Ok(ContentionModel::MaxMinFair)
            }
            other => bail!("unknown contention model '{other}' (expected degree|maxmin)"),
        }
    }
}

/// Absolute capacity of one fabric link.
///
/// `ratio` is the share multiplier `reference_gbps / gbps` **stored
/// exactly as specified** rather than recomputed by division: a link
/// derived from a scalar oversubscription factor `o` carries
/// `ratio = o` (the very same float), which is what makes the
/// [`MaxMinFair`](ContentionModel::MaxMinFair) bottleneck bit-identical
/// to the degree model on oversub-specified fabrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkCapacity {
    /// Absolute capacity in Gbps (display / allocation units).
    pub gbps: f64,
    /// Exact share multiplier `reference_gbps / gbps` (1.0 for a
    /// reference-speed server uplink; > 1 for a skinny link; < 1 for a
    /// relief link fatter than the reference).
    pub ratio: f64,
}

impl LinkCapacity {
    /// A reference-speed link (ratio exactly 1.0).
    pub fn reference(ref_gbps: f64) -> Self {
        LinkCapacity { gbps: ref_gbps, ratio: 1.0 }
    }

    /// A link specified by an oversubscription factor `o ≥ 1`: capacity
    /// `ref / o`, ratio exactly `o`.
    pub fn from_oversub(ref_gbps: f64, oversub: f64) -> Self {
        debug_assert!(oversub >= 1.0);
        LinkCapacity { gbps: ref_gbps / oversub, ratio: oversub }
    }

    /// A link specified by its absolute speed: ratio `ref / gbps`
    /// (may be < 1 — a relief link).
    pub fn from_gbps(ref_gbps: f64, gbps: f64) -> Self {
        debug_assert!(gbps > 0.0);
        LinkCapacity { gbps, ratio: ref_gbps / gbps }
    }
}

/// Result of one progressive-filling pass over the active set.
///
/// Rates and residuals are in the same Gbps units as [`LinkCapacity`];
/// ring order follows the iteration order handed to [`progressive_fill`].
#[derive(Debug, Clone, Default)]
pub struct Allocation {
    /// Ring ids, in input order.
    jobs: Vec<JobId>,
    /// Max-min rate per ring (input order). Co-located rings cross no
    /// inter-server link and report `f64::INFINITY` (not link-limited).
    rates: Vec<f64>,
    /// Bottleneck fair share per ring (input order) — the lower bound the
    /// engines rate at; `rates[i] >= shares[i]` always.
    shares: Vec<f64>,
    /// Residual capacity per link after the fill, clamped at 0.
    residual: Vec<f64>,
    /// Filling rounds executed (links saturated).
    pub rounds: usize,
}

impl Allocation {
    /// Max-min rate of one ring, if it was part of the fill.
    // archlint: allow(release-panic) position() over jobs yields an index valid for the parallel rates vec
    pub fn rate_of(&self, job: JobId) -> Option<f64> {
        self.jobs.iter().position(|&j| j == job).map(|i| self.rates[i])
    }

    /// Bottleneck fair share of one ring, if it was part of the fill.
    // archlint: allow(release-panic) position() over jobs yields an index valid for the parallel shares vec
    pub fn share_of(&self, job: JobId) -> Option<f64> {
        self.jobs.iter().position(|&j| j == job).map(|i| self.shares[i])
    }

    /// Residual (unallocated) bandwidth of one link after the fill.
    pub fn residual_gbps(&self, l: LinkId) -> f64 {
        self.residual[l.0]
    }

    /// `(job, max-min rate, bottleneck share)` triples in input order.
    pub fn rings(&self) -> impl Iterator<Item = (JobId, f64, f64)> + '_ {
        self.jobs
            .iter()
            .zip(&self.rates)
            .zip(&self.shares)
            .map(|((&j, &r), &s)| (j, r, s))
    }

    /// Headroom progressive filling reclaims beyond the engines'
    /// bottleneck-share rates, summed over all rings (Gbps).
    pub fn reclaimed_gbps(&self) -> f64 {
        self.rates
            .iter()
            .zip(&self.shares)
            .filter(|(r, _)| r.is_finite())
            .map(|(r, s)| r - s)
            .sum()
    }

    pub fn num_rings(&self) -> usize {
        self.jobs.len()
    }
}

/// Per-link **residual bandwidth** (Gbps) under the engines'
/// bottleneck-share rates: each spread ring consumes its share
/// `c_ref / degree` on every link it crosses (`counts` are the live
/// per-link ring counts the bottlenecks are read against). The single
/// source of truth for the share-rate ledger — the tracker's and the
/// snapshot's residual views both delegate here, so a future change to
/// the rate model (e.g. weighted max-min) lands in one place.
/// `O(Σ span)` over the rings; clamps FP slack at 0.
pub fn residual_ledger<'p>(
    topo: &Topology,
    rings: impl Iterator<Item = (JobId, &'p JobPlacement)>,
    counts: &[usize],
) -> Vec<f64> {
    let mut residual: Vec<f64> =
        (0..topo.num_links()).map(|l| topo.link_gbps(LinkId(l))).collect();
    for (_, pl) in rings {
        let bn = topo.bottleneck(pl, counts);
        if bn.link.is_some() {
            let rate = topo.reference_gbps() / bn.effective();
            topo.for_each_crossed(pl, |l| residual[l.0] -= rate);
        }
    }
    for r in &mut residual {
        if *r < 0.0 {
            *r = 0.0;
        }
    }
    residual
}

/// Reusable buffers for [`progressive_fill`] — one instance replayed
/// across events/candidates allocates nothing once warmed up.
#[derive(Debug, Clone, Default)]
pub struct AllocScratch {
    /// Crossed links per ring (flat arena + per-ring ranges).
    arena: Vec<usize>,
    spans: Vec<(usize, usize)>,
    /// Unfrozen-crosser count per link.
    unfrozen: Vec<usize>,
    frozen: Vec<bool>,
}

/// Max-min fair **progressive filling** over the topology: every link's
/// capacity is split equally among its unfrozen crossing rings; the link
/// with the lowest fair level saturates first, freezing its rings at that
/// level; their demand is deducted along every link they cross and the
/// fill repeats on the residuals until every ring is frozen.
///
/// `O(rounds × L + Σ span)` with `rounds ≤` the number of rings; all
/// buffers come from `scratch` and the returned [`Allocation`]'s vectors
/// are freshly filled (callers may retain it).
// archlint: allow(release-panic) arena spans and per-link vecs are built in this fn; every index derives from them
pub fn progressive_fill<'p>(
    topo: &Topology,
    rings: impl Iterator<Item = (JobId, &'p JobPlacement)>,
    scratch: &mut AllocScratch,
) -> Allocation {
    use crate::obs::metrics;
    let _span = crate::obs::trace::span("net.progressive_fill", "net");
    let cap_before = scratch.arena.capacity()
        + scratch.spans.capacity()
        + scratch.unfrozen.capacity()
        + scratch.frozen.capacity();
    let num_links = topo.num_links();
    scratch.arena.clear();
    scratch.spans.clear();
    scratch.unfrozen.clear();
    scratch.unfrozen.resize(num_links, 0);
    let mut out = Allocation {
        jobs: Vec::new(),
        rates: Vec::new(),
        shares: Vec::new(),
        residual: (0..num_links).map(|l| topo.link_gbps(LinkId(l))).collect(),
        rounds: 0,
    };
    for (job, pl) in rings {
        let start = scratch.arena.len();
        {
            let arena = &mut scratch.arena;
            let unfrozen = &mut scratch.unfrozen;
            topo.for_each_crossed(pl, |l| {
                arena.push(l.0);
                unfrozen[l.0] += 1;
            });
        }
        scratch.spans.push((start, scratch.arena.len()));
        out.jobs.push(job);
    }
    let n = out.jobs.len();
    out.rates.resize(n, f64::INFINITY);
    out.shares.resize(n, f64::INFINITY);
    scratch.frozen.clear();
    scratch.frozen.resize(n, false);

    // Bottleneck fair shares against the *original* counts — the engines'
    // rate model and the filler's per-ring floor.
    for i in 0..n {
        let (s, e) = scratch.spans[i];
        for &l in &scratch.arena[s..e] {
            let share = topo.link_gbps(LinkId(l)) / scratch.unfrozen[l] as f64;
            if share < out.shares[i] {
                out.shares[i] = share;
            }
        }
        if s == e {
            scratch.frozen[i] = true; // co-located: not link-limited
        }
    }

    let mut remaining = scratch.frozen.iter().filter(|f| !**f).count();
    while remaining > 0 {
        // the unsaturated link with the lowest fair level; ties by id
        let mut best: Option<(f64, usize)> = None;
        for l in 0..num_links {
            if scratch.unfrozen[l] > 0 {
                let level = out.residual[l] / scratch.unfrozen[l] as f64;
                if best.map_or(true, |(b, _)| level < b) {
                    best = Some((level, l));
                }
            }
        }
        let Some((level, sat)) = best else { break };
        out.rounds += 1;
        // freeze every unfrozen ring crossing the saturated link at the
        // fair level, deducting its rate along all of its links
        for i in 0..n {
            if scratch.frozen[i] {
                continue;
            }
            let (s, e) = scratch.spans[i];
            if !scratch.arena[s..e].contains(&sat) {
                continue;
            }
            scratch.frozen[i] = true;
            out.rates[i] = level;
            remaining -= 1;
            for &l in &scratch.arena[s..e] {
                out.residual[l] -= level;
                scratch.unfrozen[l] -= 1;
            }
        }
    }
    for r in &mut out.residual {
        if *r < 0.0 {
            *r = 0.0; // FP slack from repeated subtraction
        }
    }
    let cap_after = scratch.arena.capacity()
        + scratch.spans.capacity()
        + scratch.unfrozen.capacity()
        + scratch.frozen.capacity();
    metrics::incr(if cap_after > cap_before {
        metrics::Counter::ScratchRealloc
    } else {
        metrics::Counter::ScratchReuse
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ServerId};

    fn mk(c: &Cluster, pairs: &[(usize, usize)]) -> JobPlacement {
        JobPlacement::new(pairs.iter().map(|&(s, i)| c.global_gpu(ServerId(s), i)).collect())
    }

    fn fill(c: &Cluster, rings: &[(JobId, JobPlacement)]) -> Allocation {
        let mut scratch = AllocScratch::default();
        progressive_fill(c.topology(), rings.iter().map(|(j, p)| (*j, p)), &mut scratch)
    }

    #[test]
    fn model_parse_roundtrip() {
        for m in [ContentionModel::EffectiveDegree, ContentionModel::MaxMinFair] {
            assert_eq!(m.name().parse::<ContentionModel>().unwrap(), m);
        }
        assert_eq!("max-min".parse::<ContentionModel>().unwrap(), ContentionModel::MaxMinFair);
        assert!("fairshare".parse::<ContentionModel>().is_err());
        assert_eq!(ContentionModel::default(), ContentionModel::EffectiveDegree);
    }

    #[test]
    fn capacity_forms_keep_exact_ratios() {
        let r = LinkCapacity::reference(10.0);
        assert_eq!((r.gbps, r.ratio), (10.0, 1.0));
        let o = LinkCapacity::from_oversub(10.0, 4.0);
        assert_eq!(o.ratio, 4.0, "ratio is the factor itself, not a re-division");
        assert_eq!(o.gbps, 2.5);
        let g = LinkCapacity::from_gbps(10.0, 40.0);
        assert_eq!(g.ratio, 0.25, "relief link: ratio < 1");
    }

    #[test]
    fn lone_spread_ring_gets_the_whole_uplink() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let rings = vec![(JobId(0), mk(&c, &[(0, 0), (1, 0)]))];
        let a = fill(&c, &rings);
        let gbps = c.topology().link_gbps(LinkId(0));
        assert_eq!(a.rate_of(JobId(0)), Some(gbps));
        assert_eq!(a.share_of(JobId(0)), Some(gbps));
        assert_eq!(a.residual_gbps(LinkId(0)), 0.0, "saturated by its only ring");
    }

    #[test]
    fn colocated_rings_are_not_link_limited() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let rings = vec![(JobId(0), mk(&c, &[(0, 0), (0, 1)]))];
        let a = fill(&c, &rings);
        assert_eq!(a.rate_of(JobId(0)), Some(f64::INFINITY));
        assert_eq!(a.rounds, 0);
        let gbps = c.topology().link_gbps(LinkId(0));
        assert_eq!(a.residual_gbps(LinkId(0)), gbps, "nothing consumed");
    }

    #[test]
    fn equal_split_on_one_shared_uplink() {
        let c = Cluster::uniform(3, 4, 1.0, 25.0);
        // two rings sharing server 0's uplink
        let rings = vec![
            (JobId(0), mk(&c, &[(0, 0), (1, 0)])),
            (JobId(1), mk(&c, &[(0, 1), (2, 0)])),
        ];
        let a = fill(&c, &rings);
        let gbps = c.topology().link_gbps(LinkId(0));
        assert_eq!(a.rate_of(JobId(0)), Some(gbps / 2.0));
        assert_eq!(a.rate_of(JobId(1)), Some(gbps / 2.0));
        assert_eq!(a.residual_gbps(LinkId(0)), 0.0);
        // the non-shared uplinks keep the other half
        assert_eq!(a.residual_gbps(LinkId(1)), gbps / 2.0);
    }

    #[test]
    fn water_filling_reclaims_beyond_the_equal_split() {
        // The module-doc instance: A = {s0 uplink}, B = {s0, s1}, C and D
        // = {s1}. Link s1 saturates at level c/3 freezing B, C, D; A then
        // fills to 2c/3 > its c/2 equal split on s0.
        let c = Cluster::uniform(6, 8, 1.0, 25.0);
        let rings = vec![
            (JobId(0), mk(&c, &[(0, 0), (2, 0)])), // A: uplinks s0, s2
            (JobId(1), mk(&c, &[(0, 1), (1, 0)])), // B: uplinks s0, s1
            (JobId(2), mk(&c, &[(1, 1), (3, 0)])), // C: uplinks s1, s3
            (JobId(3), mk(&c, &[(1, 2), (4, 0)])), // D: uplinks s1, s4
        ];
        let a = fill(&c, &rings);
        let cbw = c.topology().link_gbps(LinkId(0));
        let third = cbw / 3.0;
        for id in [1, 2, 3] {
            assert_eq!(a.rate_of(JobId(id)), Some(third), "ring {id} frozen at s1's level");
        }
        let rate_a = a.rate_of(JobId(0)).unwrap();
        assert!((rate_a - (cbw - third)).abs() < 1e-12, "A reclaims to 2c/3, got {rate_a}");
        assert_eq!(a.share_of(JobId(0)), Some(cbw / 2.0), "A's equal split is c/2");
        assert!(a.reclaimed_gbps() > 0.0);
        assert!(a.residual_gbps(LinkId(1)) < 1e-12, "s1 saturated");
    }

    #[test]
    fn rates_dominate_bottleneck_shares_and_conserve_capacity() {
        use crate::util::proptest_lite::check;
        use crate::util::Rng;
        check("water-fill >= equal split; links conserve", 60, |rng: &mut Rng| {
            let c = match rng.gen_usize(0, 2) {
                0 => Cluster::uniform(rng.gen_usize(3, 8), 4, 1.0, 25.0),
                1 => Cluster::uniform(8, 4, 1.0, 25.0)
                    .with_topology(crate::topology::Topology::racks(8, 2, 2.0)),
                _ => Cluster::uniform(8, 4, 1.0, 25.0).with_topology(
                    crate::topology::Topology::pods(8, 2, 2, 2.0, 4.0),
                ),
            };
            let mut free: Vec<_> = c.all_gpus().collect();
            rng.shuffle(&mut free);
            let mut rings = Vec::new();
            let mut id = 0;
            while free.len() >= 2 && id < 10 {
                let k = rng.gen_usize(2, free.len().min(5));
                rings.push((JobId(id), JobPlacement::new(free.drain(..k).collect())));
                id += 1;
            }
            let a = fill(&c, &rings);
            let topo = c.topology();
            for (j, rate, share) in a.rings() {
                if rate.is_finite() {
                    assert!(rate >= share - 1e-9, "{j}: rate {rate} below share {share}");
                }
            }
            // conservation: per link, allocated = capacity − residual ≥ 0
            for l in 0..topo.num_links() {
                let res = a.residual_gbps(LinkId(l));
                assert!(res >= 0.0 && res <= topo.link_gbps(LinkId(l)) + 1e-9);
            }
            // every spread ring frozen in ≤ #rings rounds
            assert!(a.rounds <= rings.len());
        });
    }

    #[test]
    fn scratch_reuse_matches_fresh_fill() {
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        let set_a = vec![
            (JobId(0), mk(&c, &[(0, 0), (1, 0)])),
            (JobId(1), mk(&c, &[(0, 1), (2, 0)])),
        ];
        let set_b = vec![(JobId(2), mk(&c, &[(2, 1), (3, 0)]))];
        let mut scratch = AllocScratch::default();
        for set in [&set_a, &set_b, &set_a] {
            let reused =
                progressive_fill(c.topology(), set.iter().map(|(j, p)| (*j, p)), &mut scratch);
            let fresh = fill(&c, set);
            assert_eq!(reused.rates, fresh.rates);
            assert_eq!(reused.residual, fresh.residual);
            assert_eq!(reused.rounds, fresh.rounds);
        }
    }
}
