//! Figure-style reports: aligned console tables, CSV and JSON emitters,
//! matching the rows/series the paper's Figs. 4–7 plot.
//!
//! Emission is **push-style**: [`FigureReport::write_csv`] /
//! [`FigureReport::write_json`] (and the [`MetricTable`] twins) stream
//! row by row into any [`io::Write`] through
//! [`JsonEmitter`](crate::util::json::JsonEmitter), so a report written
//! to disk never buffers more than one row. The `to_*` string forms are
//! thin wrappers over the same writers — byte-identical by construction
//! (the JSON writers emit object keys in the sorted order the historical
//! [`Json`](crate::util::Json) tree emitter produced, so existing
//! artifacts do not change by a single byte; pinned by tests below).

use super::PolicySummary;
use crate::util::json::JsonEmitter;
use crate::util::Json;
use std::io;

/// One (x, y…) row of a figure sweep — e.g. (κ, makespan) for Fig. 5.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    /// Sweep variable value (κ, λ, #servers…) or policy name for Fig. 4.
    pub x: String,
    pub makespan: u64,
    pub avg_jct: f64,
}

/// A reproducible figure: title, axis label and rows.
#[derive(Debug, Clone)]
pub struct FigureReport {
    pub figure: String,
    pub x_label: String,
    pub rows: Vec<ComparisonRow>,
}

impl FigureReport {
    pub fn new(figure: impl Into<String>, x_label: impl Into<String>) -> Self {
        FigureReport { figure: figure.into(), x_label: x_label.into(), rows: Vec::new() }
    }

    pub fn push(&mut self, x: impl Into<String>, makespan: u64, avg_jct: f64) {
        self.rows.push(ComparisonRow { x: x.into(), makespan, avg_jct });
    }

    pub fn push_summary(&mut self, s: &PolicySummary) {
        self.rows.push(ComparisonRow {
            x: s.policy.clone(),
            makespan: s.makespan,
            avg_jct: s.avg_jct,
        });
    }

    /// Render an aligned console table.
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.figure));
        let w = self
            .rows
            .iter()
            .map(|r| r.x.len())
            .chain(std::iter::once(self.x_label.len()))
            .max()
            .unwrap_or(8)
            + 2;
        out.push_str(&format!(
            "{:<w$} {:>12} {:>12}\n",
            self.x_label,
            "makespan",
            "avg JCT",
            w = w
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:<w$} {:>12} {:>12.1}\n",
                r.x,
                r.makespan,
                r.avg_jct,
                w = w
            ));
        }
        out
    }

    /// Stream CSV (header + rows) into `out`, one row at a time.
    pub fn write_csv<W: io::Write>(&self, mut out: W) -> io::Result<()> {
        writeln!(out, "{},makespan,avg_jct", self.x_label)?;
        for r in &self.rows {
            writeln!(out, "{},{},{:.3}", r.x, r.makespan, r.avg_jct)?;
        }
        Ok(())
    }

    /// Render CSV as a string (buffers [`write_csv`](Self::write_csv)).
    pub fn to_csv(&self) -> String {
        let mut buf = Vec::new();
        self.write_csv(&mut buf).expect("writing to a Vec cannot fail");
        String::from_utf8(buf).expect("CSV emission is UTF-8")
    }

    /// Stream the JSON report into `out`: the envelope opens, each row is
    /// pushed as it is visited, the envelope closes. Keys are emitted in
    /// sorted order — exactly the bytes the historical tree emitter
    /// (`BTreeMap`-backed [`Json`]) produced.
    pub fn write_json<W: io::Write>(&self, out: W) -> io::Result<()> {
        let mut e = JsonEmitter::pretty(out);
        e.begin_obj()?;
        e.key("figure")?;
        e.str(&self.figure)?;
        e.key("rows")?;
        e.begin_arr()?;
        for r in &self.rows {
            e.begin_obj()?;
            e.key("avg_jct")?;
            e.num(r.avg_jct)?;
            e.key("makespan")?;
            e.num(r.makespan as f64)?;
            e.key("x")?;
            e.str(&r.x)?;
            e.end_obj()?;
        }
        e.end_arr()?;
        e.key("x_label")?;
        e.str(&self.x_label)?;
        e.end_obj()?;
        e.finish()?;
        Ok(())
    }

    /// Render JSON as a string (buffers [`write_json`](Self::write_json)).
    pub fn to_json(&self) -> crate::Result<String> {
        let mut buf = Vec::new();
        self.write_json(&mut buf)?;
        Ok(String::from_utf8(buf).expect("JSON emission is UTF-8"))
    }

    /// Parse a report back from [`to_json`](Self::to_json) output.
    pub fn from_json(s: &str) -> crate::Result<Self> {
        use crate::util::Json;
        let v = Json::parse(s)?;
        let rows = v
            .req("rows")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(ComparisonRow {
                    x: r.req("x")?.as_str()?.to_string(),
                    makespan: r.req("makespan")?.as_u64()?,
                    avg_jct: r.req("avg_jct")?.as_f64()?,
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(FigureReport {
            figure: v.req("figure")?.as_str()?.to_string(),
            x_label: v.req("x_label")?.as_str()?.to_string(),
            rows,
        })
    }

    /// Stream the CSV straight to disk through a buffered writer — no
    /// whole-report string is ever built.
    pub fn save_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut out = io::BufWriter::new(std::fs::File::create(path)?);
        self.write_csv(&mut out)?;
        io::Write::flush(&mut out)?;
        Ok(())
    }

    /// Stream the JSON straight to disk through a buffered writer —
    /// byte-identical to `std::fs::write(path, self.to_json()?)` without
    /// ever holding the whole document.
    pub fn save_json(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut out = io::BufWriter::new(std::fs::File::create(path)?);
        self.write_json(&mut out)?;
        io::Write::flush(&mut out)?;
        Ok(())
    }

    /// Best (minimum-makespan) row.
    pub fn best(&self) -> Option<&ComparisonRow> {
        self.rows.iter().min_by_key(|r| r.makespan)
    }
}

/// A free-form metric table: one labelled row per run, an arbitrary set
/// of numeric columns. Used by the online subcommand / experiments, whose
/// rows carry more than the (makespan, avg JCT) pair of the paper figures
/// (queueing delay percentiles, utilization, ...).
#[derive(Debug, Clone)]
pub struct MetricTable {
    pub title: String,
    pub label: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl MetricTable {
    pub fn new(
        title: impl Into<String>,
        label: impl Into<String>,
        columns: &[&str],
    ) -> Self {
        MetricTable {
            title: title.into(),
            label: label.into(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; `values.len()` must equal the column count.
    pub fn push(&mut self, label: impl Into<String>, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row width != column count");
        self.rows.push((label.into(), values));
    }

    /// Look up a row's value by labels.
    pub fn get(&self, row: &str, column: &str) -> Option<f64> {
        let c = self.columns.iter().position(|x| x == column)?;
        let (_, values) = self.rows.iter().find(|(l, _)| l == row)?;
        values.get(c).copied()
    }

    /// Render an aligned console table.
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(self.label.len()))
            .max()
            .unwrap_or(8)
            + 2;
        out.push_str(&format!("{:<w$}", self.label, w = w));
        for c in &self.columns {
            out.push_str(&format!(" {:>12}", c));
        }
        out.push('\n');
        for (label, values) in &self.rows {
            out.push_str(&format!("{:<w$}", label, w = w));
            for v in values {
                // integers print clean, fractions keep 3 decimals
                if (v.fract()).abs() < 1e-9 && v.abs() < 1e15 {
                    out.push_str(&format!(" {:>12}", *v as i64));
                } else {
                    out.push_str(&format!(" {:>12.3}", v));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Stream CSV (header + rows) into `out`, one row at a time.
    pub fn write_csv<W: io::Write>(&self, mut out: W) -> io::Result<()> {
        write!(out, "{}", self.label)?;
        for c in &self.columns {
            write!(out, ",{c}")?;
        }
        writeln!(out)?;
        for (label, values) in &self.rows {
            write!(out, "{label}")?;
            for v in values {
                write!(out, ",{v:.4}")?;
            }
            writeln!(out)?;
        }
        Ok(())
    }

    /// Render CSV as a string (buffers [`write_csv`](Self::write_csv)).
    pub fn to_csv(&self) -> String {
        let mut buf = Vec::new();
        self.write_csv(&mut buf).expect("writing to a Vec cannot fail");
        String::from_utf8(buf).expect("CSV emission is UTF-8")
    }

    /// Stream the JSON table into `out`, row by row. Each row still
    /// passes through one single-row [`Json`] object so the historical
    /// `BTreeMap` key order (and its duplicate-key last-wins semantics,
    /// should a column ever collide with the row label) is preserved
    /// byte for byte — only the row under emission is ever materialized.
    pub fn write_json<W: io::Write>(&self, out: W) -> io::Result<()> {
        let mut e = JsonEmitter::pretty(out);
        e.begin_obj()?;
        e.key("rows")?;
        e.begin_arr()?;
        for (label, values) in &self.rows {
            let mut fields = vec![(self.label.as_str(), Json::Str(label.clone()))];
            fields.extend(
                self.columns.iter().zip(values).map(|(c, v)| (c.as_str(), Json::Num(*v))),
            );
            e.value(&Json::obj(fields))?;
        }
        e.end_arr()?;
        e.key("title")?;
        e.str(&self.title)?;
        e.end_obj()?;
        e.finish()?;
        Ok(())
    }

    /// Render JSON as a string (buffers [`write_json`](Self::write_json)).
    pub fn to_json(&self) -> crate::Result<String> {
        let mut buf = Vec::new();
        self.write_json(&mut buf)?;
        Ok(String::from_utf8(buf).expect("JSON emission is UTF-8"))
    }

    /// Stream the CSV straight to disk through a buffered writer.
    pub fn save_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut out = io::BufWriter::new(std::fs::File::create(path)?);
        self.write_csv(&mut out)?;
        io::Write::flush(&mut out)?;
        Ok(())
    }

    /// Stream the JSON straight to disk through a buffered writer —
    /// byte-identical to `std::fs::write(path, self.to_json()?)` without
    /// ever holding the whole document.
    pub fn save_json(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut out = io::BufWriter::new(std::fs::File::create(path)?);
        self.write_json(&mut out)?;
        io::Write::flush(&mut out)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> FigureReport {
        let mut f = FigureReport::new("Fig. 4", "policy");
        f.push("SJF-BCO", 700, 320.0);
        f.push("FF", 920, 410.0);
        f.push("RAND", 1100, 520.0);
        f
    }

    #[test]
    fn table_contains_all_rows() {
        let t = report().to_table();
        assert!(t.contains("SJF-BCO"));
        assert!(t.contains("920"));
        assert!(t.contains("makespan"));
    }

    #[test]
    fn csv_shape() {
        let csv = report().to_csv();
        let lines: Vec<_> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0], "policy,makespan,avg_jct");
        assert!(lines[1].starts_with("SJF-BCO,700,"));
    }

    #[test]
    fn best_is_min_makespan() {
        assert_eq!(report().best().unwrap().x, "SJF-BCO");
    }

    #[test]
    fn json_roundtrip() {
        let f = report();
        let s = f.to_json().unwrap();
        let back = FigureReport::from_json(&s).unwrap();
        assert_eq!(back.rows.len(), 3);
        assert_eq!(back.rows[0].x, "SJF-BCO");
        assert_eq!(back.figure, f.figure);
    }

    fn metric_table() -> MetricTable {
        let mut t = MetricTable::new(
            "online — gap 5",
            "policy",
            &["makespan", "avg_jct", "avg_wait", "p95_wait", "util"],
        );
        t.push("ON-SJF-BCO", vec![700.0, 320.5, 12.0, 40.0, 0.81]);
        t.push("FIFO", vec![950.0, 410.0, 55.5, 130.0, 0.64]);
        t
    }

    #[test]
    fn metric_table_renders_and_queries() {
        let t = metric_table();
        let table = t.to_table();
        assert!(table.contains("ON-SJF-BCO"));
        assert!(table.contains("p95_wait"));
        assert!(table.contains("700"), "integer-valued cells print clean");
        let csv = t.to_csv();
        let lines: Vec<_> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "policy,makespan,avg_jct,avg_wait,p95_wait,util");
        assert!(lines[1].starts_with("ON-SJF-BCO,700.0000,"));
        assert_eq!(t.get("FIFO", "avg_wait"), Some(55.5));
        assert_eq!(t.get("FIFO", "nope"), None);
        assert_eq!(t.get("nope", "util"), None);
        assert!(t.to_json().unwrap().contains("\"p95_wait\""));
    }

    #[test]
    #[should_panic]
    fn metric_table_rejects_ragged_rows() {
        let mut t = MetricTable::new("x", "policy", &["a", "b"]);
        t.push("row", vec![1.0]);
    }

    #[test]
    fn streaming_writers_match_historical_tree_bytes() {
        // The row-streaming writers must reproduce the buffer-everything
        // tree emission byte for byte — artifacts on disk do not change.
        let f = report();
        let tree = Json::obj(vec![
            ("figure", Json::Str(f.figure.clone())),
            ("x_label", Json::Str(f.x_label.clone())),
            (
                "rows",
                Json::arr(
                    f.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("x", Json::Str(r.x.clone())),
                                ("makespan", Json::Num(r.makespan as f64)),
                                ("avg_jct", Json::Num(r.avg_jct)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_pretty();
        assert_eq!(f.to_json().unwrap(), tree);

        let t = metric_table();
        let tree = Json::obj(vec![
            ("title", Json::Str(t.title.clone())),
            (
                "rows",
                Json::arr(
                    t.rows
                        .iter()
                        .map(|(label, values)| {
                            let mut fields =
                                vec![(t.label.as_str(), Json::Str(label.clone()))];
                            fields.extend(
                                t.columns
                                    .iter()
                                    .zip(values)
                                    .map(|(c, v)| (c.as_str(), Json::Num(*v))),
                            );
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
        .to_pretty();
        assert_eq!(t.to_json().unwrap(), tree);
    }

    #[test]
    fn write_and_to_forms_agree_and_save_csv_streams() {
        let f = report();
        let mut csv = Vec::new();
        f.write_csv(&mut csv).unwrap();
        assert_eq!(String::from_utf8(csv).unwrap(), f.to_csv());
        let mut json = Vec::new();
        f.write_json(&mut json).unwrap();
        assert_eq!(String::from_utf8(json).unwrap(), f.to_json().unwrap());

        let t = metric_table();
        let mut csv = Vec::new();
        t.write_csv(&mut csv).unwrap();
        assert_eq!(String::from_utf8(csv).unwrap(), t.to_csv());
        let mut json = Vec::new();
        t.write_json(&mut json).unwrap();
        assert_eq!(String::from_utf8(json).unwrap(), t.to_json().unwrap());

        // save_csv's buffered streaming path produces the same file bytes
        let dir = crate::util::temp_dir("report-stream").unwrap();
        let fp = dir.join("fig.csv");
        f.save_csv(&fp).unwrap();
        assert_eq!(std::fs::read_to_string(&fp).unwrap(), f.to_csv());
        let tp = dir.join("table.csv");
        t.save_csv(&tp).unwrap();
        assert_eq!(std::fs::read_to_string(&tp).unwrap(), t.to_csv());

        // ...and save_json's, against the buffered to_json form
        let fj = dir.join("fig.json");
        f.save_json(&fj).unwrap();
        assert_eq!(std::fs::read_to_string(&fj).unwrap(), f.to_json().unwrap());
        let tj = dir.join("table.json");
        t.save_json(&tj).unwrap();
        assert_eq!(std::fs::read_to_string(&tj).unwrap(), t.to_json().unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
