//! Result aggregation and reporting: figure-style tables, CSV/JSON export.

mod report;
mod sketch;

pub use report::{ComparisonRow, FigureReport, MetricTable};
pub use sketch::StreamSketch;

use crate::sim::SimOutcome;

/// Summary statistics of a single policy run — one row of Fig. 4.
#[derive(Debug, Clone)]
pub struct PolicySummary {
    pub policy: String,
    pub makespan: u64,
    pub avg_jct: f64,
    pub p95_jct: u64,
    pub avg_wait: f64,
    /// 95th-percentile queueing delay (arrival → start).
    pub p95_wait: u64,
    pub gpu_utilization: f64,
    pub max_contention: usize,
    pub est_makespan: f64,
    pub truncated: bool,
}

impl PolicySummary {
    pub fn from_outcome(policy: &str, est_makespan: f64, out: &SimOutcome) -> Self {
        PolicySummary {
            policy: policy.to_string(),
            makespan: out.makespan,
            avg_jct: out.avg_jct,
            p95_jct: out.jct_percentile(95.0),
            avg_wait: out.avg_wait(),
            p95_wait: out.wait_percentile(95.0),
            gpu_utilization: out.gpu_utilization,
            max_contention: out.records.iter().map(|r| r.max_p).max().unwrap_or(0),
            est_makespan,
            truncated: out.truncated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::JobRecord;
    use crate::jobs::JobId;

    #[test]
    fn summary_from_outcome() {
        let out = SimOutcome {
            makespan: 100,
            avg_jct: 60.0,
            gpu_utilization: 0.7,
            records: vec![JobRecord {
                job: JobId(0),
                arrival: 0,
                start: 0,
                finish: 100,
                span: 2,
                workers: 4,
                max_p: 3,
                mean_tau: 0.02,
                iterations_done: 1000,
                migrations: 0,
            }],
            slots_simulated: 100,
            periods: 1,
            truncated: false,
        };
        let s = PolicySummary::from_outcome("FF", 90.0, &out);
        assert_eq!(s.makespan, 100);
        assert_eq!(s.max_contention, 3);
        assert_eq!(s.p95_jct, 100);
        assert_eq!(s.p95_wait, 0);
    }
}
