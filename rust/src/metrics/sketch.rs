//! Streaming percentile sketch: a fixed-slot log-bucket histogram.
//!
//! The streaming online loop cannot afford the store-all JCT/wait vectors
//! of [`crate::sim::SimOutcome`] — a million completions would cost
//! O(total) memory for a percentile that is read once at the end. This
//! sketch folds each observation into one of 2048 fixed `u64` slots
//! (16 KiB, allocated once at construction) and answers nearest-rank
//! percentile queries from the bucket counts.
//!
//! ## Bucket layout
//!
//! * **Linear region**: values `0..=255` get one slot each — *exact*.
//!   Slot-quantised waits and JCTs of short jobs live here.
//! * **Log region**: a value `v ≥ 256` with highest set bit `o`
//!   (`o = 63 − leading_zeros(v)`, so `o ∈ 8..=63`) lands in one of 32
//!   sub-buckets of octave `o`, selected by the 5 bits below the top bit.
//!   Bucket width is `2^(o−5)`, i.e. at most `v/32`.
//!
//! ## Error bound (documented contract, gated by `scripts/verify.sh`)
//!
//! [`StreamSketch::percentile`] applies the **same nearest-rank rule** as
//! the exact reference ([`crate::sim::Percentiles`]):
//! `rank = round(p/100 · (n−1))`. Bucketing is monotone, so the selected
//! bucket is exactly the bucket containing the exact answer, and the
//! reported value (the bucket's inclusive upper bound, clamped to the
//! observed max) satisfies
//!
//! ```text
//! exact ≤ sketch ≤ exact + exact/32      (integer division; equality
//!                                         i.e. sketch == exact below 256)
//! ```
//!
//! This ≤ 1/32 (3.125 %) one-sided relative error is asserted by the
//! property test below against the exact reference on random runs, and
//! re-checked end-to-end by `benches/stream.rs` (streaming vs
//! materialized on shared sizes).
//!
//! Count / sum / min / max / mean are tracked exactly (u128 sum — no
//! float accumulation order to worry about), so streaming aggregate
//! metrics are bit-identical to the collect-all path, not approximations;
//! only percentiles carry the bucket error. This is the middle rung of
//! the collect-all-vs-streaming equivalence ladder (see `crate::online`).

/// Number of exact one-per-value slots (values `0..=LINEAR-1`).
const LINEAR: u64 = 256;
/// Sub-buckets per octave in the log region (2^5).
const SUB: usize = 32;
/// Bits of sub-bucket resolution below the top bit.
const SUB_BITS: u32 = 5;
/// Octaves 8..=63 inclusive.
const OCTAVES: usize = 56;
/// Total slot count: 256 linear + 56 × 32 log.
const SLOTS: usize = LINEAR as usize + OCTAVES * SUB;

/// Deterministic fixed-memory percentile sketch over `u64` observations.
#[derive(Debug, Clone)]
pub struct StreamSketch {
    slots: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for StreamSketch {
    fn default() -> Self {
        Self::new()
    }
}

/// Slot index of a value (monotone non-decreasing in `v`).
fn bucket_index(v: u64) -> usize {
    if v < LINEAR {
        v as usize
    } else {
        let o = 63 - v.leading_zeros(); // 8..=63
        let sub = ((v >> (o - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        LINEAR as usize + (o as usize - 8) * SUB + sub
    }
}

/// Inclusive upper bound of a slot — the sketch's representative value.
/// Every member of the bucket is ≤ this and > this − width.
fn bucket_upper(idx: usize) -> u64 {
    if idx < LINEAR as usize {
        idx as u64
    } else {
        let k = idx - LINEAR as usize;
        let o = (8 + k / SUB) as u32;
        let sub = (k % SUB) as u64;
        let lo = (1u64 << o) + (sub << (o - SUB_BITS));
        lo + (1u64 << (o - SUB_BITS)) - 1
    }
}

impl StreamSketch {
    /// All 2048 slots are allocated here, once; `insert` never allocates.
    pub fn new() -> Self {
        StreamSketch { slots: vec![0; SLOTS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Fold one observation in. O(1), allocation-free.
    pub fn insert(&mut self, v: u64) {
        self.slots[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Merge another sketch's observations into this one (same layout by
    /// construction). Useful for combining per-shard sinks.
    pub fn merge(&mut self, other: &StreamSketch) {
        for (a, b) in self.slots.iter_mut().zip(&other.slots) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all observations (u128: no overflow, no float order).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Exact minimum observation (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }

    /// Exact maximum observation (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Nearest-rank percentile, `p ∈ [0, 100]`; 0 when empty. Same rank
    /// rule as the exact [`crate::sim::Percentiles`] reference; the
    /// result is the containing bucket's upper bound clamped to the
    /// observed `[min, max]`, hence ≥ exact and within `exact/32` of it
    /// (exact below 256 — see the module docs for the proof sketch).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let rank = rank.min(self.count - 1);
        let mut seen = 0u64;
        for (idx, &c) in self.slots.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bucket_upper(idx).clamp(self.min, self.max);
            }
        }
        self.max // unreachable: seen reaches count > rank
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Percentiles;
    use crate::util::proptest_lite::check;

    #[test]
    fn layout_covers_u64_without_gaps() {
        // bucket_index is monotone and bucket_upper inverts it: for a
        // spread of magnitudes, v lands in a bucket whose upper bound is
        // >= v and within v/32 of it.
        for shift in 0..64 {
            for delta in [0u64, 1, 2, 3] {
                let v = (1u64 << shift).wrapping_add(delta);
                let idx = bucket_index(v);
                assert!(idx < SLOTS, "v={v} idx={idx}");
                let upper = bucket_upper(idx);
                assert!(upper >= v, "v={v} upper={upper}");
                assert!(upper - v <= v / 32, "v={v} upper={upper}");
            }
        }
        assert_eq!(bucket_index(u64::MAX), SLOTS - 1);
        assert_eq!(bucket_upper(SLOTS - 1), u64::MAX);
    }

    #[test]
    fn bucket_index_is_monotone_at_boundaries() {
        // crossing every octave and sub-bucket boundary never decreases
        let mut prev = 0;
        for o in 8..24 {
            for sub in 0..SUB as u64 {
                let v = (1u64 << o) + (sub << (o - SUB_BITS as usize));
                let idx = bucket_index(v);
                assert!(idx >= prev, "v={v}");
                prev = idx;
            }
        }
    }

    #[test]
    fn exact_in_linear_region() {
        let mut sk = StreamSketch::new();
        let vals = [0u64, 1, 5, 17, 42, 99, 200, 255];
        for &v in &vals {
            sk.insert(v);
        }
        let exact = Percentiles::from_values(vals.to_vec());
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(sk.percentile(p), exact.percentile(p), "p={p}");
        }
        assert_eq!(sk.min(), 0);
        assert_eq!(sk.max(), 255);
        assert_eq!(sk.sum(), vals.iter().map(|&v| v as u128).sum());
    }

    #[test]
    fn empty_sketch_is_safe() {
        let sk = StreamSketch::new();
        assert_eq!(sk.percentile(50.0), 0);
        assert_eq!(sk.min(), 0);
        assert_eq!(sk.max(), 0);
        assert_eq!(sk.mean(), 0.0);
        assert!(sk.is_empty());
    }

    #[test]
    fn merge_equals_single_sketch() {
        let mut a = StreamSketch::new();
        let mut b = StreamSketch::new();
        let mut whole = StreamSketch::new();
        for v in 0..1000u64 {
            let x = v * v * 7 + 13;
            if v % 2 == 0 { a.insert(x) } else { b.insert(x) }
            whole.insert(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum(), whole.sum());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(a.percentile(p), whole.percentile(p));
        }
    }

    #[test]
    fn prop_sketch_tracks_exact_nearest_rank() {
        // The documented contract: exact <= sketch <= exact + exact/32,
        // for arbitrary magnitude mixes and percentiles. This is the
        // property the verify.sh streaming smoke re-checks end to end.
        check("sketch_vs_exact_nearest_rank", 64, |rng| {
            let n = rng.gen_usize(1, 400);
            let mut vals = Vec::with_capacity(n);
            let mut sk = StreamSketch::new();
            for _ in 0..n {
                // span the linear region and several octaves
                let magnitude = rng.gen_range(5);
                let v = match magnitude {
                    0 => rng.gen_u64(0, 255),
                    1 => rng.gen_u64(256, 4096),
                    2 => rng.gen_u64(4096, 1 << 20),
                    3 => rng.gen_u64(1 << 20, 1 << 40),
                    _ => rng.gen_u64(1 << 40, u64::MAX),
                };
                vals.push(v);
                sk.insert(v);
            }
            let exact = Percentiles::from_values(vals.clone());
            for p in [0.0, 1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
                let e = exact.percentile(p);
                let s = sk.percentile(p);
                assert!(e <= s, "p={p}: exact {e} > sketch {s}");
                assert!(s - e <= e / 32, "p={p}: sketch {s} off exact {e} by > 1/32");
                if e < LINEAR {
                    assert_eq!(s, e, "p={p}: linear region must be exact");
                }
            }
            assert_eq!(sk.count() as usize, n);
            assert_eq!(sk.sum(), vals.iter().map(|&v| v as u128).sum::<u128>());
            assert_eq!(sk.min(), *vals.iter().min().unwrap());
            assert_eq!(sk.max(), *vals.iter().max().unwrap());
        });
    }
}
