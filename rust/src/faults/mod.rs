//! Deterministic **fault injection**: component failures as first-class
//! timestamped events.
//!
//! The paper's model (and the rest of this stack) assumes servers, GPUs
//! and links never fail; a production-scale cluster sees component
//! failure as the steady state. This module supplies the fault side of
//! that gap:
//!
//! * [`FaultEvent`] — one timestamped fault ([`FaultAction`]: a server
//!   crash or recovery, a permanent single-GPU failure, a link degraded
//!   to a fraction of its capacity or restored). The online event loop
//!   merges these into its schedule alongside arrivals and completions —
//!   **failures are first-class events**, never a side channel (the
//!   ROADMAP invariant).
//! * [`FaultTrace`] — a sorted, serialisable stream of fault events
//!   (JSON round-trip mirrors [`Trace`](crate::trace::Trace)), dumped by
//!   the `fault-trace` CLI subcommand and consumed via
//!   `online --faults`.
//! * [`FaultSpec`] — the seeded generator: per-server crash/recover
//!   alternating renewals (exponential up/down times around
//!   MTBF / MTTR), per-GPU one-shot permanent failures, per-link
//!   degrade/restore renewals. Components are visited in id order on one
//!   seeded [`Rng`](crate::util::rng::Rng), so a spec + cluster + horizon
//!   reproduces the exact same trace everywhere.
//!
//! The recovery half lives in the [`online`](crate::online) loop: a
//! crash kills the resident gangs (the jobs keep their checkpointed
//! progress per the existing `restart_slots` model and enter a recovery
//! queue), and link degradation flows through the tracker's
//! [`Topology::multiplier`](crate::topology::Topology::multiplier) choke
//! point plus the link-keyed
//! [`DirtySet`](crate::contention::DirtySet) invalidation rule — no new
//! contention seam. An **empty** trace is the inert state: the loop
//! skips every fault branch and reproduces the fault-free schedule bit
//! for bit (`tests/fault_equivalence.rs`).

use crate::cluster::Cluster;
use crate::util::rng::Rng;
use crate::util::Json;
use crate::Result;
use anyhow::bail;

/// What failed (or healed). Components are identified by their dense
/// ids against the cluster the trace was generated for — a server index,
/// a (server, local-gpu) pair, or a [`LinkId`](crate::topology::LinkId)
/// index — kept as plain integers so traces serialise without a cluster
/// in hand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// The whole server goes down: every resident worker is killed and
    /// its GPUs leave the schedulable pool until recovery.
    ServerCrash { server: usize },
    /// The server returns; its GPUs (minus any individually failed ones)
    /// rejoin the pool.
    ServerRecover { server: usize },
    /// One GPU fails **permanently** (no per-GPU recovery): the resident
    /// gang, if any, is killed.
    GpuFail { server: usize, gpu: usize },
    /// The link's capacity drops to `factor` (0 < factor < 1) of its
    /// pristine value — a capacity change flowing through the
    /// `Topology::multiplier` choke point.
    LinkDegrade { link: usize, factor: f64 },
    /// The link returns to its pristine capacity (bit-identical
    /// multipliers to the never-degraded fabric).
    LinkRestore { link: usize },
}

impl FaultAction {
    /// Stable kind string for serialisation and reports.
    pub fn kind(&self) -> &'static str {
        match self {
            FaultAction::ServerCrash { .. } => "server-crash",
            FaultAction::ServerRecover { .. } => "server-recover",
            FaultAction::GpuFail { .. } => "gpu-fail",
            FaultAction::LinkDegrade { .. } => "link-degrade",
            FaultAction::LinkRestore { .. } => "link-restore",
        }
    }
}

/// One timestamped fault, merged into the online event loop alongside
/// arrivals and completions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Slot at which the fault takes effect.
    pub at: u64,
    pub action: FaultAction,
}

/// A sorted, serialisable stream of fault events (the fault-side twin of
/// a workload [`Trace`](crate::trace::Trace)).
#[derive(Debug, Clone, Default)]
pub struct FaultTrace {
    pub seed: u64,
    /// The generator spec (or a free-form note for hand-built traces).
    pub description: String,
    /// Events in non-decreasing `at` order.
    pub events: Vec<FaultEvent>,
}

impl FaultTrace {
    /// The inert trace: no events, and the online loop skips every fault
    /// branch (bit-identical to a fault-free run).
    pub fn empty() -> Self {
        FaultTrace::default()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Sort events by time (stable — generation order breaks ties), the
    /// invariant the event loop's merge relies on.
    pub fn normalize(&mut self) {
        self.events.sort_by_key(|e| e.at);
    }

    pub fn to_json(&self) -> Result<String> {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("at", Json::Num(e.at as f64)),
                    ("kind", Json::Str(e.action.kind().to_string())),
                ];
                match e.action {
                    FaultAction::ServerCrash { server }
                    | FaultAction::ServerRecover { server } => {
                        fields.push(("server", Json::Num(server as f64)));
                    }
                    FaultAction::GpuFail { server, gpu } => {
                        fields.push(("server", Json::Num(server as f64)));
                        fields.push(("gpu", Json::Num(gpu as f64)));
                    }
                    FaultAction::LinkDegrade { link, factor } => {
                        fields.push(("link", Json::Num(link as f64)));
                        fields.push(("factor", Json::Num(factor)));
                    }
                    FaultAction::LinkRestore { link } => {
                        fields.push(("link", Json::Num(link as f64)));
                    }
                }
                Json::obj(fields)
            })
            .collect();
        let v = Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("description", Json::Str(self.description.clone())),
            ("events", Json::arr(events)),
        ]);
        Ok(v.to_pretty())
    }

    pub fn from_json(s: &str) -> Result<Self> {
        let v = Json::parse(s)?;
        let mut events = Vec::new();
        for e in v.req("events")?.as_arr()? {
            let at = e.req("at")?.as_u64()?;
            let kind = e.req("kind")?.as_str()?.to_string();
            let action = match kind.as_str() {
                "server-crash" => FaultAction::ServerCrash {
                    server: e.req("server")?.as_u64()? as usize,
                },
                "server-recover" => FaultAction::ServerRecover {
                    server: e.req("server")?.as_u64()? as usize,
                },
                "gpu-fail" => FaultAction::GpuFail {
                    server: e.req("server")?.as_u64()? as usize,
                    gpu: e.req("gpu")?.as_u64()? as usize,
                },
                "link-degrade" => FaultAction::LinkDegrade {
                    link: e.req("link")?.as_u64()? as usize,
                    factor: e.req("factor")?.as_f64()?,
                },
                "link-restore" => FaultAction::LinkRestore {
                    link: e.req("link")?.as_u64()? as usize,
                },
                other => bail!("unknown fault kind '{other}'"),
            };
            events.push(FaultEvent { at, action });
        }
        let mut t = FaultTrace {
            seed: v.req("seed")?.as_u64()?,
            description: v.req("description")?.as_str()?.to_string(),
            events,
        };
        t.normalize();
        Ok(t)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json()?)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }
}

/// Seeded fault-trace generator. Every class defaults to **disabled**
/// (`mtbf = 0`), so the default spec generates the inert empty trace —
/// the same absence-is-disabled rule the config layer uses everywhere.
///
/// CLI / config string form (comma-separated clauses, each enabling one
/// class):
///
/// ```text
/// server:<mtbf>:<mttr>          per-server crash/recover renewal
/// gpu:<mtbf>                    per-GPU one-shot permanent failure
/// link:<mtbf>:<mttr>[:<frac>]   per-link degrade/restore renewal
///                               (degraded to <frac> of capacity, 0.5)
/// seed:<u64>                    generator seed (default: the run seed)
/// ```
///
/// e.g. `server:2000:200,link:1500:300:0.25,seed:7`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Generator seed; `None` inherits the run seed at resolution time.
    pub seed: Option<u64>,
    /// Mean up-time (slots) between crashes per server; 0 disables.
    pub server_mtbf: f64,
    /// Mean down-time (slots) per server outage.
    pub server_mttr: f64,
    /// Mean time (slots) to one permanent failure per GPU; 0 disables.
    pub gpu_mtbf: f64,
    /// Mean healthy time (slots) between degradations per link; 0 disables.
    pub link_mtbf: f64,
    /// Mean degraded time (slots) per link incident.
    pub link_mttr: f64,
    /// Fraction of pristine capacity a degraded link retains (0, 1).
    pub degrade_to: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: None,
            server_mtbf: 0.0,
            server_mttr: 0.0,
            gpu_mtbf: 0.0,
            link_mtbf: 0.0,
            link_mttr: 0.0,
            degrade_to: 0.5,
        }
    }
}

/// One exponential inter-event draw in whole slots (≥ 1; saturates the
/// way [`slots_until_done`](crate::sim::kernel::slots_until_done) does
/// so a huge mean cannot wrap the u64 cast).
fn exp_slots(rng: &mut Rng, mean: f64) -> u64 {
    let u = rng.gen_f64();
    let draw = -(1.0 - u).ln() * mean;
    if !draw.is_finite() || draw >= u64::MAX as f64 {
        return u64::MAX;
    }
    let slots = draw.ceil();
    if slots < 1.0 {
        1
    } else {
        slots as u64
    }
}

impl FaultSpec {
    /// Is any fault class enabled?
    pub fn is_active(&self) -> bool {
        self.server_mtbf > 0.0 || self.gpu_mtbf > 0.0 || self.link_mtbf > 0.0
    }

    /// Resolve the generator seed against the run seed.
    pub fn resolved_seed(&self, run_seed: u64) -> u64 {
        // decorrelate the fault stream from the workload stream drawn off
        // the same run seed (an xor'd constant, not a second RNG)
        self.seed.unwrap_or(run_seed ^ 0xFA17_57A2)
    }

    /// Generate the deterministic fault trace for one cluster over
    /// `[0, horizon)`: components in id order, one seeded RNG, stable
    /// final sort — same spec + cluster + horizon ⇒ same trace, byte for
    /// byte.
    pub fn generate(&self, cluster: &Cluster, horizon: u64, run_seed: u64) -> FaultTrace {
        let seed = self.resolved_seed(run_seed);
        let mut rng = Rng::seed_from_u64(seed);
        let mut events: Vec<FaultEvent> = Vec::new();
        if self.server_mtbf > 0.0 && self.server_mttr > 0.0 {
            for server in 0..cluster.num_servers() {
                let mut t: u64 = 0;
                loop {
                    t = t.saturating_add(exp_slots(&mut rng, self.server_mtbf));
                    if t >= horizon {
                        break;
                    }
                    events.push(FaultEvent {
                        at: t,
                        action: FaultAction::ServerCrash { server },
                    });
                    t = t.saturating_add(exp_slots(&mut rng, self.server_mttr));
                    if t >= horizon {
                        break;
                    }
                    events.push(FaultEvent {
                        at: t,
                        action: FaultAction::ServerRecover { server },
                    });
                }
            }
        }
        if self.gpu_mtbf > 0.0 {
            for s in cluster.server_ids() {
                for gpu in 0..cluster.capacity(s) {
                    let at = exp_slots(&mut rng, self.gpu_mtbf);
                    if at < horizon {
                        events.push(FaultEvent {
                            at,
                            action: FaultAction::GpuFail { server: s.0, gpu },
                        });
                    }
                }
            }
        }
        if self.link_mtbf > 0.0 && self.link_mttr > 0.0 {
            for link in 0..cluster.topology().num_links() {
                let mut t: u64 = 0;
                loop {
                    t = t.saturating_add(exp_slots(&mut rng, self.link_mtbf));
                    if t >= horizon {
                        break;
                    }
                    events.push(FaultEvent {
                        at: t,
                        action: FaultAction::LinkDegrade { link, factor: self.degrade_to },
                    });
                    t = t.saturating_add(exp_slots(&mut rng, self.link_mttr));
                    if t >= horizon {
                        break;
                    }
                    events.push(FaultEvent {
                        at: t,
                        action: FaultAction::LinkRestore { link },
                    });
                }
            }
        }
        let mut trace =
            FaultTrace { seed, description: self.to_string(), events };
        trace.normalize();
        trace
    }
}

impl std::fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut parts: Vec<String> = Vec::new();
        if self.server_mtbf > 0.0 {
            parts.push(format!("server:{}:{}", self.server_mtbf, self.server_mttr));
        }
        if self.gpu_mtbf > 0.0 {
            parts.push(format!("gpu:{}", self.gpu_mtbf));
        }
        if self.link_mtbf > 0.0 {
            parts.push(format!(
                "link:{}:{}:{}",
                self.link_mtbf, self.link_mttr, self.degrade_to
            ));
        }
        if let Some(seed) = self.seed {
            parts.push(format!("seed:{seed}"));
        }
        if parts.is_empty() {
            f.write_str("none")
        } else {
            f.write_str(&parts.join(","))
        }
    }
}

fn parse_mean(s: &str, what: &str) -> Result<f64> {
    let v: f64 = s.parse().map_err(|_| anyhow::anyhow!("bad {what} '{s}'"))?;
    if !(v > 0.0) || !v.is_finite() {
        bail!("{what} must be a positive number of slots, got {s}");
    }
    Ok(v)
}

impl std::str::FromStr for FaultSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut spec = FaultSpec::default();
        if s.trim().is_empty() || s.eq_ignore_ascii_case("none") {
            return Ok(spec);
        }
        for clause in s.split(',') {
            let parts: Vec<&str> = clause.trim().split(':').collect();
            match parts.as_slice() {
                ["server", mtbf, mttr] => {
                    spec.server_mtbf = parse_mean(mtbf, "server MTBF")?;
                    spec.server_mttr = parse_mean(mttr, "server MTTR")?;
                }
                ["gpu", mtbf] => {
                    spec.gpu_mtbf = parse_mean(mtbf, "gpu MTBF")?;
                }
                ["link", mtbf, mttr] | ["link", mtbf, mttr, _] => {
                    spec.link_mtbf = parse_mean(mtbf, "link MTBF")?;
                    spec.link_mttr = parse_mean(mttr, "link MTTR")?;
                    if let ["link", _, _, frac] = parts.as_slice() {
                        let v: f64 = frac
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad degrade fraction '{frac}'"))?;
                        if !(v > 0.0 && v < 1.0) {
                            bail!("degrade fraction must be in (0, 1), got {frac}");
                        }
                        spec.degrade_to = v;
                    }
                }
                ["seed", seed] => {
                    spec.seed = Some(
                        seed.parse()
                            .map_err(|_| anyhow::anyhow!("bad fault seed '{seed}'"))?,
                    );
                }
                _ => bail!(
                    "bad fault clause '{clause}' (expected server:<mtbf>:<mttr>, \
                     gpu:<mtbf>, link:<mtbf>:<mttr>[:<frac>] or seed:<u64>)"
                ),
            }
        }
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> Cluster {
        Cluster::uniform(4, 4, 1.0, 25.0)
    }

    #[test]
    fn default_spec_is_inert() {
        let spec = FaultSpec::default();
        assert!(!spec.is_active());
        let trace = spec.generate(&cluster(), 10_000, 42);
        assert!(trace.is_empty());
        assert_eq!(spec.to_string(), "none");
        assert_eq!("none".parse::<FaultSpec>().unwrap(), spec);
    }

    #[test]
    fn spec_string_roundtrip() {
        let spec: FaultSpec = "server:2000:200,gpu:90000,link:1500:300:0.25,seed:7"
            .parse()
            .unwrap();
        assert_eq!(spec.server_mtbf, 2000.0);
        assert_eq!(spec.server_mttr, 200.0);
        assert_eq!(spec.gpu_mtbf, 90000.0);
        assert_eq!(spec.link_mtbf, 1500.0);
        assert_eq!(spec.link_mttr, 300.0);
        assert_eq!(spec.degrade_to, 0.25);
        assert_eq!(spec.seed, Some(7));
        let back: FaultSpec = spec.to_string().parse().unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!("server:0:10".parse::<FaultSpec>().is_err(), "zero MTBF");
        assert!("server:100".parse::<FaultSpec>().is_err(), "missing MTTR");
        assert!("link:100:10:1.5".parse::<FaultSpec>().is_err(), "fraction > 1");
        assert!("link:100:10:0".parse::<FaultSpec>().is_err(), "fraction 0");
        assert!("quux:1".parse::<FaultSpec>().is_err(), "unknown clause");
        assert!("seed:x".parse::<FaultSpec>().is_err(), "bad seed");
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        let spec: FaultSpec = "server:500:50,link:400:80:0.5,seed:3".parse().unwrap();
        let c = cluster();
        let a = spec.generate(&c, 5_000, 42);
        let b = spec.generate(&c, 5_000, 42);
        assert_eq!(a.events, b.events, "same spec+cluster+horizon ⇒ same trace");
        assert!(!a.is_empty(), "active spec over a long horizon produces events");
        assert!(
            a.events.windows(2).all(|w| w[0].at <= w[1].at),
            "events are time-sorted"
        );
        assert!(a.events.iter().all(|e| e.at < 5_000), "horizon bounds every event");
        // crash/recover alternate per server
        for s in 0..c.num_servers() {
            let mut down = false;
            for e in &a.events {
                match e.action {
                    FaultAction::ServerCrash { server } if server == s => {
                        assert!(!down, "double crash on server {s}");
                        down = true;
                    }
                    FaultAction::ServerRecover { server } if server == s => {
                        assert!(down, "recover before crash on server {s}");
                        down = false;
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn explicit_seed_overrides_the_run_seed() {
        let spec: FaultSpec = "server:500:50,seed:9".parse().unwrap();
        let c = cluster();
        assert_eq!(spec.generate(&c, 5_000, 1).events, spec.generate(&c, 5_000, 2).events);
        let inherit: FaultSpec = "server:500:50".parse().unwrap();
        assert_ne!(
            inherit.generate(&c, 5_000, 1).events,
            inherit.generate(&c, 5_000, 2).events,
            "without seed: the run seed drives the stream"
        );
        assert_ne!(
            inherit.resolved_seed(1),
            1,
            "fault stream decorrelates from the workload stream"
        );
    }

    #[test]
    fn gpu_failures_are_one_shot_per_gpu() {
        let spec: FaultSpec = "gpu:1000,seed:5".parse().unwrap();
        let c = cluster();
        let trace = spec.generate(&c, 1_000_000_000, 0);
        // horizon far beyond the mean: every GPU fails exactly once
        assert_eq!(trace.len(), c.num_gpus());
        let mut seen = std::collections::BTreeSet::new();
        for e in &trace.events {
            match e.action {
                FaultAction::GpuFail { server, gpu } => {
                    assert!(seen.insert((server, gpu)), "duplicate GPU failure");
                }
                _ => panic!("unexpected action"),
            }
        }
    }

    #[test]
    fn json_roundtrip_preserves_every_action() {
        let mut trace = FaultTrace {
            seed: 11,
            description: "hand-built".to_string(),
            events: vec![
                FaultEvent { at: 5, action: FaultAction::ServerCrash { server: 1 } },
                FaultEvent { at: 9, action: FaultAction::GpuFail { server: 0, gpu: 3 } },
                FaultEvent {
                    at: 12,
                    action: FaultAction::LinkDegrade { link: 2, factor: 0.25 },
                },
                FaultEvent { at: 20, action: FaultAction::LinkRestore { link: 2 } },
                FaultEvent { at: 30, action: FaultAction::ServerRecover { server: 1 } },
            ],
        };
        trace.normalize();
        let s = trace.to_json().unwrap();
        let back = FaultTrace::from_json(&s).unwrap();
        assert_eq!(back.seed, 11);
        assert_eq!(back.description, "hand-built");
        assert_eq!(back.events, trace.events);
        assert!(FaultTrace::from_json("{\"seed\":0}").is_err(), "missing fields error");
    }

    #[test]
    fn file_roundtrip() {
        let spec: FaultSpec = "server:300:30,seed:2".parse().unwrap();
        let trace = spec.generate(&cluster(), 2_000, 0);
        let dir = crate::util::temp_dir("rarsched-faults").unwrap();
        let p = dir.join("faults.json");
        trace.save(&p).unwrap();
        let back = FaultTrace::load(&p).unwrap();
        assert_eq!(back.events, trace.events);
        assert_eq!(back.description, spec.to_string());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
