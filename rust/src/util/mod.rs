//! In-tree substrates: deterministic RNG, JSON, TOML-subset config,
//! logging, micro-bench statistics and a tiny property-testing harness.
//! (The build is fully offline; see Cargo.toml.)

pub mod alloc;
pub mod bench;
pub mod json;
pub mod logger;
pub mod par;
pub mod proptest_lite;
pub mod rng;
pub mod toml_lite;

pub use json::Json;
pub use rng::Rng;
pub use toml_lite::{TomlDoc, TomlValue};

/// Create a unique temporary directory under the system temp dir.
/// The caller owns cleanup (tests usually leave it to the OS).
pub fn temp_dir(prefix: &str) -> crate::Result<std::path::PathBuf> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let pid = std::process::id();
    let dir = std::env::temp_dir().join(format!("{prefix}-{pid}-{n}"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    #[test]
    fn temp_dirs_are_unique() {
        let a = super::temp_dir("rarsched-test").unwrap();
        let b = super::temp_dir("rarsched-test").unwrap();
        assert_ne!(a, b);
        assert!(a.exists() && b.exists());
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }
}
