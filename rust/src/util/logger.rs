//! Minimal `log` facade backend (no env_logger offline): level from
//! `RARSCHED_LOG` (error|warn|info|debug|trace, default info), messages to
//! stderr with a monotonic timestamp and the emitting thread's name (or
//! numeric id for unnamed threads — `par_map` workers would otherwise be
//! indistinguishable).

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let thread = std::thread::current();
        let who = match thread.name() {
            Some(name) => name.to_string(),
            // unnamed (e.g. par_map workers): fall back to the numeric id
            None => format!("{:?}", thread.id()).replace("ThreadId", "tid"),
        };
        eprintln!(
            "[{:>8.3}s {} {} {}] {}",
            t.as_secs_f64(),
            lvl,
            who,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent: subsequent calls are no-ops).
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let level = match std::env::var("RARSCHED_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            Ok("off") => LevelFilter::Off,
            _ => LevelFilter::Info,
        };
        let logger = Box::leak(Box::new(StderrLogger { start: Instant::now() }));
        if log::set_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
