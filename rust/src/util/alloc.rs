//! Heap-allocation counting for steady-state memory tests.
//!
//! [`CountingAlloc`] wraps the system allocator and tallies every
//! `alloc`/`realloc` call (lock-free relaxed atomics — the counter is a
//! tally, not a synchronization point). It is **not** installed by this
//! crate: a test binary that wants allocation accounting opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: rarsched::util::alloc::CountingAlloc = rarsched::util::alloc::CountingAlloc::new();
//! ```
//!
//! and reads [`CountingAlloc::allocations`] around the region under test
//! (see `tests/alloc_steady_state.rs`, which pins the streaming engine's
//! zero-allocation completion steady state). Library and production
//! binaries keep the plain system allocator — zero overhead unless a
//! test asks for the tally.

// The one sanctioned `unsafe` island in the workspace: implementing
// `GlobalAlloc` is inherently unsafe, and the impl only forwards to
// `System` plus relaxed atomic tallies. The workspace-level
// `unsafe_code = "deny"` ([workspace.lints.rust]) is overridden here.
#![allow(unsafe_code)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// A [`GlobalAlloc`] that defers to [`System`] and counts the calls that
/// can mint new heap memory (`alloc`, `alloc_zeroed`, `realloc`). Frees
/// are not counted: the steady-state invariant under test is "no *new*
/// allocations", and a drop of pre-existing memory does not violate it.
pub struct CountingAlloc {
    allocations: AtomicU64,
}

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc { allocations: AtomicU64::new(0) }
    }

    /// Total allocation calls since process start (monotone; never
    /// reset). Callers diff two readings to charge a region.
    pub fn allocations(&self) -> u64 {
        self.allocations.load(Ordering::Relaxed)
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.allocations.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.allocations.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.allocations.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Not installed as the global allocator here (the library test binary
    // keeps System); exercised directly through the GlobalAlloc vtable.
    #[test]
    fn counts_allocs_and_reallocs_but_not_frees() {
        let a = CountingAlloc::new();
        assert_eq!(a.allocations(), 0);
        let layout = Layout::from_size_align(64, 8).unwrap();
        unsafe {
            let p = a.alloc(layout);
            assert!(!p.is_null());
            assert_eq!(a.allocations(), 1);
            let p = a.realloc(p, layout, 128);
            assert!(!p.is_null());
            assert_eq!(a.allocations(), 2);
            let grown = Layout::from_size_align(128, 8).unwrap();
            a.dealloc(p, grown);
            assert_eq!(a.allocations(), 2, "dealloc is not an allocation");
            let z = a.alloc_zeroed(layout);
            assert!(!z.is_null());
            assert_eq!(a.allocations(), 3);
            assert!(std::slice::from_raw_parts(z, 64).iter().all(|&b| b == 0));
            a.dealloc(z, layout);
        }
    }
}
