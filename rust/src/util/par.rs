//! Scoped-thread parallel map for embarrassingly parallel sweeps.
//!
//! The figure experiments (`fig4`–`fig7`, `topology_sweep`,
//! `overload_sweep`, `online_sweep`) evaluate independent
//! (seed, κ, λ, oversubscription, policy) points — each point is a pure
//! function of its inputs, so they fan out across cores with
//! `std::thread::scope` (no dependencies; the build is offline) while
//! the output stays **deterministic**: results land in input order by
//! construction, regardless of worker count or interleaving.
//!
//! Worker count: `RARSCHED_THREADS` if set (1 forces the sequential
//! path), else [`std::thread::available_parallelism`], always capped by
//! the item count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker-thread count for [`par_map`]: `RARSCHED_THREADS` override, else
/// the machine's available parallelism (min 1).
pub fn threads() -> usize {
    if let Ok(v) = std::env::var("RARSCHED_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item, fanning across up to [`threads`] workers.
/// Returns results in input order (deterministic). A single worker (or a
/// single item) degenerates to a plain sequential map with no thread
/// spawn at all.
pub fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    use crate::obs::{metrics, trace};
    let workers = threads().min(items.len());
    if workers <= 1 {
        if !items.is_empty() {
            metrics::note_worker_tasks("par-seq", items.len() as u64);
        }
        return items.into_iter().map(f).collect();
    }
    // Index-tagged work stealing: an atomic cursor hands out items, each
    // result is parked in its input slot — ordering is positional, never
    // temporal.
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let done: Vec<Mutex<Option<R>>> = (0..work.len()).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    metrics::add(metrics::Counter::ParMapWorkers, workers as u64);
    std::thread::scope(|scope| {
        let (work, done, next, f) = (&work, &done, &next, &f);
        for w in 0..workers {
            let label = format!("par-worker-{w}");
            // named workers: log lines and trace rows stay attributable
            std::thread::Builder::new()
                .name(label.clone())
                .spawn_scoped(scope, move || {
                    let mut tasks = 0u64;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= work.len() {
                            break;
                        }
                        let item = work[i]
                            .lock()
                            .expect("work slot poisoned")
                            .take()
                            .expect("work item handed out twice");
                        let span = trace::span("par.task", "par").arg("item", i as f64);
                        let result = f(item);
                        drop(span);
                        *done[i].lock().expect("result slot poisoned") = Some(result);
                        tasks += 1;
                    }
                    // per-thread accumulator, merged once at worker exit
                    metrics::note_worker_tasks(&label, tasks);
                })
                .expect("failed to spawn par_map worker");
        }
    });
    done.into_iter()
        .map(|m| {
            m.into_inner()
                .expect("result slot poisoned")
                .expect("worker exited without storing its result")
        })
        .collect()
}

/// [`par_map`] over fallible points: runs every item, then returns the
/// first error in *input* order (deterministic error selection too).
pub fn par_try_map<T, R, F>(items: Vec<T>, f: F) -> crate::Result<Vec<R>>
where
    T: Send,
    R: Send,
    F: Fn(T) -> crate::Result<R> + Sync,
{
    par_map(items, f).into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let out = par_map((0..100).collect::<Vec<_>>(), |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let calls = AtomicUsize::new(0);
        let out = par_map((0..57).collect::<Vec<_>>(), |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 57);
        assert_eq!(calls.load(Ordering::Relaxed), 57);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        assert!(par_map(Vec::<u32>::new(), |i| i).is_empty());
        assert_eq!(par_map(vec![7u32], |i| i + 1), vec![8]);
    }

    #[test]
    fn try_map_surfaces_the_first_error_in_input_order() {
        let r = par_try_map((0..16).collect::<Vec<_>>(), |i| {
            if i % 5 == 4 {
                Err(anyhow::anyhow!("boom {i}"))
            } else {
                Ok(i)
            }
        });
        assert_eq!(r.unwrap_err().to_string(), "boom 4");
        let ok = par_try_map(vec![1, 2, 3], |i| crate::Result::Ok(i * 10)).unwrap();
        assert_eq!(ok, vec![10, 20, 30]);
    }
}
