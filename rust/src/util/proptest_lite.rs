//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a property over `n` seeded random cases; on failure it
//! reports the failing seed so the case can be replayed exactly:
//!
//! ```no_run
//! use rarsched::util::proptest_lite::check;
//! check("sum commutes", 200, |rng| {
//!     let (a, b) = (rng.gen_u64(0, 100), rng.gen_u64(0, 100));
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Replay one case with `RARSCHED_PROP_SEED=<seed>`.

use super::rng::Rng;

/// Number of cases, overridable via `RARSCHED_PROP_CASES`.
pub fn default_cases(requested: u64) -> u64 {
    std::env::var("RARSCHED_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(requested)
}

/// Run `property` over `cases` seeded RNGs. Panics (with the seed) on the
/// first failing case.
pub fn check(name: &str, cases: u64, mut property: impl FnMut(&mut Rng)) {
    if let Ok(seed) = std::env::var("RARSCHED_PROP_SEED") {
        let seed: u64 = seed.parse().expect("RARSCHED_PROP_SEED must be a u64");
        let mut rng = Rng::seed_from_u64(seed);
        property(&mut rng);
        return;
    }
    let cases = default_cases(cases);
    for case in 0..cases {
        // decorrelate consecutive case seeds
        let seed = case.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xD1B5_4A32_D192_ED03;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from_u64(seed);
            property(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} \
                 (replay with RARSCHED_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |_| count += 1);
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("fails-late", 20, |rng| {
                // fail on roughly half the cases
                assert!(rng.gen_f64() < 0.5, "unlucky draw");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("RARSCHED_PROP_SEED="), "got: {msg}");
    }
}
