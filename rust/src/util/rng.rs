//! Deterministic, dependency-free pseudo-random number generation.
//!
//! Implements SplitMix64 (for seeding) and xoshiro256++ (for streams) —
//! both public-domain algorithms by Blackman & Vigna. All randomness in
//! the crate (trace generation, random clusters, the RAND policy) flows
//! through [`Rng`] with an explicit seed, so every experiment is exactly
//! reproducible from its config.

/// SplitMix64 step — used to expand a 64-bit seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's unbiased method.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.gen_range((hi - lo + 1) as u64) as usize
    }

    /// Uniform u64 in `[lo, hi]` (inclusive).
    pub fn gen_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)` with 53-bit precision.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.gen_range(items.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(Rng::seed_from_u64(42).next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Rng::seed_from_u64(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.gen_range(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Rng::seed_from_u64(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn inclusive_ranges() {
        let mut rng = Rng::seed_from_u64(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = rng.gen_usize(5, 8);
            assert!((5..=8).contains(&v));
            lo_seen |= v == 5;
            hi_seen |= v == 8;
        }
        assert!(lo_seen && hi_seen);
        assert_eq!(rng.gen_u64(9, 9), 9);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn choose_returns_member() {
        let mut rng = Rng::seed_from_u64(5);
        let items = ["a", "b", "c"];
        for _ in 0..20 {
            assert!(items.contains(rng.choose(&items)));
        }
    }
}
