//! A TOML subset reader/writer for experiment configs (offline build —
//! no external toml crate). Supports: `[section]` headers, `key = value`
//! with string / bool / integer / float / array-of-integer values, `#`
//! comments, and blank lines. Nested tables beyond one level are not
//! needed by the config schema.

use crate::Result;
use anyhow::bail;
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    IntArray(Vec<i64>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let i = self.as_i64()?;
        if i < 0 {
            bail!("expected unsigned integer, got {i}");
        }
        Ok(i as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    /// Float accessor that also accepts integers (TOML writers often emit
    /// `1` for `1.0`).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_int_array(&self) -> Result<&[i64]> {
        match self {
            TomlValue::IntArray(a) => Ok(a),
            _ => bail!("expected integer array, got {self:?}"),
        }
    }
}

/// A parsed document: `doc[section][key] = value`. Top-level keys live in
/// the `""` section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(input: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        for (lineno, raw) in input.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: malformed section header '{raw}'", lineno + 1);
                };
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            let key = key.trim().to_string();
            let value = parse_value(value.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            doc.sections.entry(current.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn set(&mut self, section: &str, key: &str, value: TomlValue) {
        self.sections.entry(section.to_string()).or_default().insert(key.to_string(), value);
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }

    /// Serialise back to TOML text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        // top-level first
        if let Some(top) = self.sections.get("") {
            for (k, v) in top {
                out.push_str(&format!("{k} = {}\n", emit_value(v)));
            }
        }
        for (name, table) in &self.sections {
            if name.is_empty() {
                continue;
            }
            out.push_str(&format!("\n[{name}]\n"));
            for (k, v) in table {
                out.push_str(&format!("{k} = {}\n", emit_value(v)));
            }
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"') {
        let Some(inner) = inner.strip_suffix('"') else {
            bail!("unterminated string {s}");
        };
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            bail!("unterminated array {s}");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::IntArray(Vec::new()));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            items.push(part.trim().parse::<i64>()?);
        }
        return Ok(TomlValue::IntArray(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{s}'")
}

fn emit_value(v: &TomlValue) -> String {
    match v {
        TomlValue::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        TomlValue::Bool(b) => b.to_string(),
        TomlValue::Int(i) => i.to_string(),
        TomlValue::Float(f) => {
            if f.fract() == 0.0 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        TomlValue::IntArray(a) => {
            let items: Vec<String> = a.iter().map(|i| i.to_string()).collect();
            format!("[{}]", items.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            seed = 42              # top-level
            [cluster]
            servers = 20
            inter_bw = 1.0
            capacities = [4, 8, 16]
            name = "philly # scaled"
            random = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "seed").unwrap().as_i64().unwrap(), 42);
        assert_eq!(doc.get("cluster", "servers").unwrap().as_usize().unwrap(), 20);
        assert_eq!(doc.get("cluster", "inter_bw").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(doc.get("cluster", "capacities").unwrap().as_int_array().unwrap(), &[4, 8, 16]);
        assert_eq!(doc.get("cluster", "name").unwrap().as_str().unwrap(), "philly # scaled");
        assert!(doc.get("cluster", "random").unwrap().as_bool().unwrap());
        assert!(doc.get("cluster", "missing").is_none());
    }

    #[test]
    fn roundtrip() {
        let mut doc = TomlDoc::default();
        doc.set("", "seed", TomlValue::Int(7));
        doc.set("model", "alpha", TomlValue::Float(0.2));
        doc.set("model", "tag", TomlValue::Str("a\"b".into()));
        doc.set("cluster", "caps", TomlValue::IntArray(vec![4, 8]));
        let text = doc.to_string();
        let back = TomlDoc::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn int_accepted_as_float() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn errors() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("x = @").is_err());
        assert!(TomlDoc::parse("a = [1, b]").is_err());
    }

    #[test]
    fn empty_array_and_negative() {
        let doc = TomlDoc::parse("a = []\nb = -5").unwrap();
        assert!(doc.get("", "a").unwrap().as_int_array().unwrap().is_empty());
        assert_eq!(doc.get("", "b").unwrap().as_i64().unwrap(), -5);
        assert!(doc.get("", "b").unwrap().as_u64().is_err());
    }
}
