//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Usage in a `harness = false` bench target:
//!
//! ```no_run
//! use rarsched::util::bench::Bench;
//! let mut b = Bench::new("fig4");
//! b.run("sjf-bco/plan", || { /* workload */ });
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed over adaptively-chosen iterations
//! until the total runtime budget is met; mean, stddev, and min are
//! reported.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl CaseResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// A collection of benchmark cases with a shared time budget per case.
pub struct Bench {
    pub suite: String,
    pub budget: Duration,
    pub warmup: Duration,
    results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // keep default budgets modest: bench targets double as figure
        // generators and run in CI
        let budget_ms: u64 = std::env::var("RARSCHED_BENCH_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1500);
        Bench {
            suite: suite.to_string(),
            budget: Duration::from_millis(budget_ms),
            warmup: Duration::from_millis(budget_ms / 5),
            results: Vec::new(),
        }
    }

    /// Time `f`, discarding its output. Returns the case result.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &CaseResult {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed() / warm_iters.max(1) as u32;
        let target_iters = if per_iter.is_zero() {
            1000
        } else {
            (self.budget.as_nanos() / per_iter.as_nanos().max(1)).clamp(5, 100_000) as u64
        };

        let mut samples = Vec::with_capacity(target_iters as usize);
        for _ in 0..target_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        let n = samples.len() as f64;
        let mean_s = samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / n;
        let var = samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>()
            / n.max(1.0);
        let result = CaseResult {
            name: name.to_string(),
            iters: target_iters,
            mean: Duration::from_secs_f64(mean_s),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: samples.iter().min().copied().unwrap_or_default(),
        };
        println!(
            "{}/{:<40} {:>12.3} ms/iter (±{:.3} ms, min {:.3} ms, n={})",
            self.suite,
            result.name,
            result.mean_ms(),
            result.stddev.as_secs_f64() * 1e3,
            result.min.as_secs_f64() * 1e3,
            result.iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print a closing summary (and return the results).
    pub fn report(&self) -> &[CaseResult] {
        println!("-- {}: {} case(s) --", self.suite, self.results.len());
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("RARSCHED_BENCH_MS", "20");
        let mut b = Bench::new("selftest");
        let r = b.run("sum", || (0..1000u64).sum::<u64>());
        assert!(r.iters >= 5);
        assert!(r.mean > Duration::ZERO);
        assert!(r.min <= r.mean);
        assert_eq!(b.report().len(), 1);
    }
}
