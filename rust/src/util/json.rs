//! Minimal JSON value model, push-style emitter and recursive-descent
//! parser.
//!
//! The crate serialises traces, plans and reports to JSON for
//! reproducibility; with the build fully offline we implement the small
//! JSON subset we need in-tree (objects, arrays, strings, numbers, bools,
//! null; UTF-8 input; `\uXXXX` escapes on parse).
//!
//! Emission is **push-style** (SAX spirit): [`JsonEmitter`] streams
//! begin/end container markers, keys and scalars straight into any
//! [`io::Write`], so row-producing paths (`figures`, `--json`,
//! windows CSV/JSON) can emit as they go instead of accumulating a
//! `Vec<Row>` or a buffer-everything string. The tree API is a thin
//! layer on top: [`Json::to_string`]/[`Json::to_pretty`] walk the value
//! through the same emitter, so tree-built and push-built output are
//! byte-identical **by construction** (property-tested below).
//!
//! Formatting contract (unchanged from the historical buffer-everything
//! writer, so existing artifacts stay byte-identical): pretty mode uses
//! 2-space indent, a newline+indent before every element and before a
//! closer only when the container is non-empty, `": "` after keys
//! (compact: `":"`); numbers with zero fraction and magnitude < 9·10¹⁵
//! print as integers; non-finite numbers print as `null` (JSON has no
//! NaN/Infinity — the old writer emitted invalid JSON here; no artifact
//! ever contained one, the explain path uses −1.0 sentinels precisely to
//! keep its JSON finite).

use crate::Result;
use anyhow::bail;
use std::collections::BTreeMap;
use std::io::{self, Write as _};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-stable key order (BTreeMap for determinism).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the missing key name (for parsers).
    pub fn req(&self, key: &str) -> Result<&Json> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing JSON key '{key}'"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected unsigned integer, got {f}");
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    // ---- emit --------------------------------------------------------------
    /// Compact serialisation (streams through [`JsonEmitter`]).
    pub fn to_string(&self) -> String {
        self.render(None)
    }

    /// Pretty serialisation (2-space indent, via [`JsonEmitter`]).
    pub fn to_pretty(&self) -> String {
        self.render(Some(2))
    }

    fn render(&self, indent: Option<usize>) -> String {
        let mut buf = Vec::new();
        let mut e = JsonEmitter::with_indent(&mut buf, indent);
        e.value(self).expect("writing to a Vec cannot fail");
        e.finish().expect("value emission balances its containers");
        // the emitter only ever writes UTF-8 (escapes + str slices)
        String::from_utf8(buf).expect("emitter output is UTF-8")
    }

    // ---- parse -------------------------------------------------------------
    pub fn parse(input: &str) -> Result<Json> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }
}

// ---- push-style emitter -------------------------------------------------

/// One open container on the emitter stack.
#[derive(Debug, Clone, Copy)]
struct Frame {
    is_obj: bool,
    /// Elements written so far (object: keys; array: values) — drives
    /// comma placement and the non-empty-closer newline.
    count: usize,
}

/// Push-style JSON emitter over any [`io::Write`] sink.
///
/// ```text
/// begin_obj → key → (scalar | begin_* … end_*) → … → end_obj
/// ```
///
/// State is one small `Vec<Frame>` (container kind + element count per
/// open level), so arbitrarily deep output never recurses and rows can
/// stream to a file as they are produced. Misuse (a value where a key is
/// required, `end_obj` closing an array, a dangling key) panics — these
/// are programmer errors, not data errors, and every call site is
/// deterministic.
#[derive(Debug)]
pub struct JsonEmitter<W: io::Write> {
    out: W,
    indent: Option<usize>,
    stack: Vec<Frame>,
    /// Inside an object, a key has been written and its value is pending.
    has_key: bool,
}

impl<W: io::Write> JsonEmitter<W> {
    /// Compact emitter (no whitespace).
    pub fn compact(out: W) -> Self {
        Self::with_indent(out, None)
    }

    /// Pretty emitter (2-space indent — the crate's artifact format).
    pub fn pretty(out: W) -> Self {
        Self::with_indent(out, Some(2))
    }

    pub fn with_indent(out: W, indent: Option<usize>) -> Self {
        JsonEmitter { out, indent, stack: Vec::new(), has_key: false }
    }

    /// Current container depth (0 at top level).
    pub fn depth(&self) -> usize {
        self.stack.len()
    }

    /// Check balance and hand the sink back (does **not** flush a
    /// `BufWriter` — callers owning one flush it themselves).
    pub fn finish(self) -> io::Result<W> {
        if !self.stack.is_empty() || self.has_key {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "JsonEmitter finished with open containers or a dangling key",
            ));
        }
        Ok(self.out)
    }

    fn newline_indent(&mut self, depth: usize) -> io::Result<()> {
        if let Some(w) = self.indent {
            self.out.write_all(b"\n")?;
            for _ in 0..w * depth {
                self.out.write_all(b" ")?;
            }
        }
        Ok(())
    }

    /// Comma/indent bookkeeping before a value in the current context.
    fn prepare_value(&mut self) -> io::Result<()> {
        match self.stack.last_mut() {
            None => {}
            Some(f) if f.is_obj => {
                assert!(self.has_key, "object value requires a preceding key()");
                self.has_key = false;
            }
            Some(f) => {
                if f.count > 0 {
                    self.out.write_all(b",")?;
                }
                f.count += 1;
                let depth = self.stack.len();
                self.newline_indent(depth)?;
            }
        }
        Ok(())
    }

    /// Write an object key (must be directly inside an object).
    pub fn key(&mut self, k: &str) -> io::Result<()> {
        assert!(!self.has_key, "two keys in a row");
        let f = self.stack.last_mut().expect("key() outside any container");
        assert!(f.is_obj, "key() inside an array");
        if f.count > 0 {
            self.out.write_all(b",")?;
        }
        f.count += 1;
        let depth = self.stack.len();
        self.newline_indent(depth)?;
        write_escaped(&mut self.out, k)?;
        self.out.write_all(if self.indent.is_some() { b": " } else { b":" })?;
        self.has_key = true;
        Ok(())
    }

    pub fn begin_obj(&mut self) -> io::Result<()> {
        self.prepare_value()?;
        self.out.write_all(b"{")?;
        self.stack.push(Frame { is_obj: true, count: 0 });
        Ok(())
    }

    pub fn end_obj(&mut self) -> io::Result<()> {
        assert!(!self.has_key, "end_obj() with a dangling key");
        let f = self.stack.pop().expect("end_obj() at top level");
        assert!(f.is_obj, "end_obj() closing an array");
        if f.count > 0 {
            let depth = self.stack.len();
            self.newline_indent(depth)?;
        }
        self.out.write_all(b"}")
    }

    pub fn begin_arr(&mut self) -> io::Result<()> {
        self.prepare_value()?;
        self.out.write_all(b"[")?;
        self.stack.push(Frame { is_obj: false, count: 0 });
        Ok(())
    }

    pub fn end_arr(&mut self) -> io::Result<()> {
        let f = self.stack.pop().expect("end_arr() at top level");
        assert!(!f.is_obj, "end_arr() closing an object");
        if f.count > 0 {
            let depth = self.stack.len();
            self.newline_indent(depth)?;
        }
        self.out.write_all(b"]")
    }

    pub fn null(&mut self) -> io::Result<()> {
        self.prepare_value()?;
        self.out.write_all(b"null")
    }

    pub fn bool(&mut self, b: bool) -> io::Result<()> {
        self.prepare_value()?;
        self.out.write_all(if b { b"true" } else { b"false" })
    }

    /// Number with the crate's formatting contract (integral `f64` below
    /// 9·10¹⁵ prints as an integer; non-finite prints as `null`).
    pub fn num(&mut self, n: f64) -> io::Result<()> {
        self.prepare_value()?;
        write_num(&mut self.out, n)
    }

    /// Unsigned integer, printed exactly (use for counters that may
    /// exceed the f64-exact range; identical bytes to `num` below 2⁵³).
    pub fn uint(&mut self, n: u64) -> io::Result<()> {
        self.prepare_value()?;
        write!(self.out, "{n}")
    }

    pub fn str(&mut self, s: &str) -> io::Result<()> {
        self.prepare_value()?;
        write_escaped(&mut self.out, s)
    }

    /// Splice pre-serialised JSON verbatim as one value (caller
    /// guarantees well-formedness; indentation inside is the caller's).
    pub fn raw(&mut self, json: &str) -> io::Result<()> {
        self.prepare_value()?;
        self.out.write_all(json.as_bytes())
    }

    /// Emit a whole [`Json`] tree through the push interface — the
    /// bridge that keeps tree-built and push-built output byte-identical.
    pub fn value(&mut self, v: &Json) -> io::Result<()> {
        match v {
            Json::Null => self.null(),
            Json::Bool(b) => self.bool(*b),
            Json::Num(n) => self.num(*n),
            Json::Str(s) => self.str(s),
            Json::Arr(items) => {
                self.begin_arr()?;
                for item in items {
                    self.value(item)?;
                }
                self.end_arr()
            }
            Json::Obj(map) => {
                self.begin_obj()?;
                for (k, val) in map {
                    self.key(k)?;
                    self.value(val)?;
                }
                self.end_obj()
            }
        }
    }
}

fn write_num<W: io::Write>(out: &mut W, n: f64) -> io::Result<()> {
    if !n.is_finite() {
        // JSON has no NaN/Infinity; null is the documented policy
        out.write_all(b"null")
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        write!(out, "{}", n as i64)
    } else {
        write!(out, "{n}")
    }
}

fn write_escaped<W: io::Write>(out: &mut W, s: &str) -> io::Result<()> {
    out.write_all(b"\"")?;
    for ch in s.chars() {
        match ch {
            '"' => out.write_all(b"\\\"")?,
            '\\' => out.write_all(b"\\\\")?,
            '\n' => out.write_all(b"\\n")?,
            '\r' => out.write_all(b"\\r")?,
            '\t' => out.write_all(b"\\t")?,
            c if (c as u32) < 0x20 => {
                write!(out, "\\u{:04x}", c as u32)?;
            }
            c => {
                let mut buf = [0u8; 4];
                out.write_all(c.encode_utf8(&mut buf).as_bytes())?;
            }
        }
    }
    out.write_all(b"\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => bail!("invalid number '{s}' at byte {start}"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' got {other:?} at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}' got {other:?} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::Str("job-1".into())),
            ("gpus", Json::Num(8.0)),
            ("spread", Json::Bool(true)),
            ("taus", Json::arr(vec![Json::Num(0.01), Json::Num(0.05)])),
            ("none", Json::Null),
        ]);
        for s in [v.to_string(), v.to_pretty()] {
            let back = Json::parse(&s).unwrap();
            assert_eq!(back, v, "roundtrip failed for {s}");
        }
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#"{"s": "a\n\"b\"Aé"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\n\"b\"Aé");
        // emit-then-parse of control chars
        let v2 = Json::Str("tab\there\u{0001}".into());
        assert_eq!(Json::parse(&v2.to_string()).unwrap(), v2);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(Json::parse("42").unwrap().as_u64().unwrap(), 42);
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
    }

    #[test]
    fn error_cases() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"a": [1, 2], "b": true}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.req("b").unwrap().as_bool().unwrap());
        assert!(v.req("missing").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
    }

    #[test]
    fn nested_roundtrip() {
        let s = r#"{"jobs":[{"id":0,"g":4},{"id":1,"g":8}],"meta":{"seed":7}}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(
            v.req("jobs").unwrap().as_arr().unwrap()[1]
                .req("g")
                .unwrap()
                .as_u64()
                .unwrap(),
            8
        );
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    // ---- push-emitter edge cases ---------------------------------------

    /// Walk a tree through the *public* push API only — the independent
    /// reimplementation the byte-identity property compares against.
    fn push_walk<W: std::io::Write>(e: &mut JsonEmitter<W>, v: &Json) {
        match v {
            Json::Null => e.null().unwrap(),
            Json::Bool(b) => e.bool(*b).unwrap(),
            Json::Num(n) => e.num(*n).unwrap(),
            Json::Str(s) => e.str(s).unwrap(),
            Json::Arr(items) => {
                e.begin_arr().unwrap();
                for item in items {
                    push_walk(e, item);
                }
                e.end_arr().unwrap();
            }
            Json::Obj(map) => {
                e.begin_obj().unwrap();
                for (k, val) in map {
                    e.key(k).unwrap();
                    push_walk(e, val);
                }
                e.end_obj().unwrap();
            }
        }
    }

    fn random_json(rng: &mut crate::util::Rng, depth: usize) -> Json {
        let pick = if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_range(2) == 0),
            2 => {
                // integral and fractional, positive and negative
                let n = match rng.gen_range(3) {
                    0 => rng.gen_u64(0, 1 << 50) as f64,
                    1 => -(rng.gen_u64(0, 9000) as f64),
                    _ => rng.gen_f64_range(-1e6, 1e6),
                };
                Json::Num(n)
            }
            3 => {
                let tricky = ["", "a\"b", "back\\slash", "line\nfeed", "tab\there",
                    "ctrl\u{0001}\u{001f}", "unicode é 😀 ¥", "\r"];
                Json::Str(tricky[rng.gen_range(tricky.len() as u64) as usize].into())
            }
            4 => {
                let n = rng.gen_range(4) as usize;
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    items.push(random_json(rng, depth - 1));
                }
                Json::Arr(items)
            }
            _ => {
                let keys = ["k", "key two", "κλειδί", "with\"quote", "e"];
                let n = rng.gen_usize(1, keys.len());
                let mut pairs = Vec::with_capacity(n);
                for k in &keys[..n] {
                    pairs.push((*k, random_json(rng, depth - 1)));
                }
                Json::obj(pairs)
            }
        }
    }

    #[test]
    fn prop_push_emitter_matches_tree_emitter_bytes() {
        crate::util::proptest_lite::check("push_vs_tree_bytes", 64, |rng| {
            let v = random_json(rng, 3);
            for indent in [None, Some(2)] {
                let mut pushed = Vec::new();
                let mut e = JsonEmitter::with_indent(&mut pushed, indent);
                push_walk(&mut e, &v);
                e.finish().unwrap();
                let tree =
                    if indent.is_some() { v.to_pretty() } else { v.to_string() };
                assert_eq!(String::from_utf8(pushed).unwrap(), tree);
            }
        });
    }

    #[test]
    fn prop_emit_parse_roundtrip() {
        // finite trees survive emit → parse with the existing reader
        crate::util::proptest_lite::check("emit_parse_roundtrip", 64, |rng| {
            let v = random_json(rng, 3);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
            assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
        });
    }

    #[test]
    fn escaping_edge_cases() {
        let cases = [
            ("quote\"inside", r#""quote\"inside""#),
            ("back\\slash", r#""back\\slash""#),
            ("nl\n cr\r tab\t", "\"nl\\n cr\\r tab\\t\""),
            ("\u{0001}\u{001f}", "\"\\u0001\\u001f\""),
            ("é😀", "\"é😀\""),
            ("", "\"\""),
        ];
        for (raw, expect) in cases {
            let v = Json::Str(raw.into());
            assert_eq!(v.to_string(), expect);
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v, "roundtrip {raw:?}");
        }
        // DEL (0x7f) is not a JSON control char: passes through raw
        assert_eq!(Json::Str("\u{7f}".into()).to_string(), "\"\u{7f}\"");
    }

    #[test]
    fn non_finite_numbers_emit_null() {
        // documented policy: JSON has no NaN/Infinity
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let mut buf = Vec::new();
        let mut e = JsonEmitter::compact(&mut buf);
        e.begin_arr().unwrap();
        e.num(f64::NAN).unwrap();
        e.num(1.5).unwrap();
        e.end_arr().unwrap();
        e.finish().unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), "[null,1.5]");
    }

    #[test]
    fn deep_nesting_is_iterative() {
        // 10k-deep array: the push emitter keeps one Frame per level and
        // never recurses, so this must not blow the stack
        let mut buf = Vec::new();
        let mut e = JsonEmitter::compact(&mut buf);
        const DEPTH: usize = 10_000;
        for _ in 0..DEPTH {
            e.begin_arr().unwrap();
        }
        e.num(1.0).unwrap();
        for _ in 0..DEPTH {
            e.end_arr().unwrap();
        }
        e.finish().unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert_eq!(s.len(), 2 * DEPTH + 1);
        assert!(s.starts_with("[[[") && s.ends_with("]]]"));
        // a modest depth still round-trips through the recursive parser
        let mut modest = String::new();
        for _ in 0..128 {
            modest.push('[');
        }
        modest.push('7');
        for _ in 0..128 {
            modest.push(']');
        }
        assert!(Json::parse(&modest).is_ok());
    }

    #[test]
    fn emitter_streams_rows_and_raw_splices() {
        // the shape the streaming report paths use: an object with an
        // array of row objects, plus a pre-rendered manifest spliced raw
        let mut buf = Vec::new();
        let mut e = JsonEmitter::pretty(&mut buf);
        e.begin_obj().unwrap();
        e.key("rows").unwrap();
        e.begin_arr().unwrap();
        for i in 0..3u64 {
            e.begin_obj().unwrap();
            e.key("id").unwrap();
            e.uint(i).unwrap();
            e.key("score").unwrap();
            e.num(i as f64 + 0.5).unwrap();
            e.end_obj().unwrap();
        }
        e.end_arr().unwrap();
        e.key("manifest").unwrap();
        e.raw(&Json::obj(vec![("seed", Json::Num(7.0))]).to_string()).unwrap();
        e.end_obj().unwrap();
        e.finish().unwrap();
        let s = String::from_utf8(buf).unwrap();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.req("rows").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            back.req("manifest").unwrap().req("seed").unwrap().as_u64().unwrap(),
            7
        );
        // matches the tree emitter byte for byte
        let tree = Json::obj(vec![
            (
                "rows",
                Json::arr(
                    (0..3)
                        .map(|i| {
                            Json::obj(vec![
                                ("id", Json::Num(i as f64)),
                                ("score", Json::Num(i as f64 + 0.5)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("manifest", Json::obj(vec![("seed", Json::Num(7.0))])),
        ]);
        assert_eq!(s, tree.to_pretty());
    }

    #[test]
    fn empty_containers_have_no_inner_newline() {
        // formatting contract: closer newline only when non-empty
        assert_eq!(Json::arr(vec![]).to_pretty(), "[]");
        assert_eq!(Json::obj(vec![]).to_pretty(), "{}");
        assert_eq!(
            Json::obj(vec![("a", Json::arr(vec![]))]).to_pretty(),
            "{\n  \"a\": []\n}"
        );
    }

    #[test]
    fn unbalanced_finish_is_an_error() {
        let mut buf = Vec::new();
        let mut e = JsonEmitter::compact(&mut buf);
        e.begin_obj().unwrap();
        assert!(e.finish().is_err());
    }
}
