//! Minimal JSON value model, emitter and recursive-descent parser.
//!
//! The crate serialises traces, plans and reports to JSON for
//! reproducibility; with the build fully offline we implement the small
//! JSON subset we need in-tree (objects, arrays, strings, numbers, bools,
//! null; UTF-8 input; `\uXXXX` escapes on parse).

use crate::Result;
use anyhow::bail;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-stable key order (BTreeMap for determinism).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the missing key name (for parsers).
    pub fn req(&self, key: &str) -> Result<&Json> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing JSON key '{key}'"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected unsigned integer, got {f}");
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    // ---- emit --------------------------------------------------------------
    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialisation (2-space indent).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- parse -------------------------------------------------------------
    pub fn parse(input: &str) -> Result<Json> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => bail!("invalid number '{s}' at byte {start}"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' got {other:?} at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}' got {other:?} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::Str("job-1".into())),
            ("gpus", Json::Num(8.0)),
            ("spread", Json::Bool(true)),
            ("taus", Json::arr(vec![Json::Num(0.01), Json::Num(0.05)])),
            ("none", Json::Null),
        ]);
        for s in [v.to_string(), v.to_pretty()] {
            let back = Json::parse(&s).unwrap();
            assert_eq!(back, v, "roundtrip failed for {s}");
        }
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#"{"s": "a\n\"b\"Aé"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\n\"b\"Aé");
        // emit-then-parse of control chars
        let v2 = Json::Str("tab\there\u{0001}".into());
        assert_eq!(Json::parse(&v2.to_string()).unwrap(), v2);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
        assert_eq!(Json::parse("42").unwrap().as_u64().unwrap(), 42);
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
    }

    #[test]
    fn error_cases() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"a": [1, 2], "b": true}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.req("b").unwrap().as_bool().unwrap());
        assert!(v.req("missing").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
    }

    #[test]
    fn nested_roundtrip() {
        let s = r#"{"jobs":[{"id":0,"g":4},{"id":1,"g":8}],"meta":{"seed":7}}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(
            v.req("jobs").unwrap().as_arr().unwrap()[1]
                .req("g")
                .unwrap()
                .as_u64()
                .unwrap(),
            8
        );
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
