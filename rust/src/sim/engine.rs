//! The time-slotted simulation engine.

use super::kernel::{self, RatePoint};
use super::{JobRecord, SimOutcome};
use crate::cluster::{Cluster, ClusterState, JobPlacement};
use crate::contention::{ContentionParams, ContentionSnapshot};
use crate::jobs::{JobId, JobSpec};
use crate::sched::Plan;
use std::collections::HashMap;

/// Engine options.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Safety horizon: stop after this many slots even if jobs remain
    /// (guards against mis-calibrated τ ≥ 1 where `φ = ⌊1/τ⌋ = 0`).
    pub max_slots: u64,
    /// When `φ_j[t]` floors to zero, fall back to fractional progress
    /// `1/τ` instead of stalling forever. Off by default (paper-faithful).
    pub fractional_progress: bool,
    /// Event-driven fast path (§Perf): between admissions/completions the
    /// active set — and therefore every `p_j`, `τ_j`, `φ_j` — is constant,
    /// so the engine jumps straight to the next event instead of ticking
    /// slot by slot. Produces *identical* results to the slot-by-slot
    /// reference (asserted by `fast_path_matches_reference`); disable only
    /// for cross-checking.
    pub event_driven: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { max_slots: 1_000_000, fractional_progress: false, event_driven: true }
    }
}

/// Replays a [`Plan`] against the analytical model, slot by slot.
pub struct Simulator<'a> {
    cluster: &'a Cluster,
    specs: HashMap<JobId, &'a JobSpec>,
    params: &'a ContentionParams,
    options: SimOptions,
}

struct ActiveJob<'a, 'p> {
    job: JobId,
    spec: &'a JobSpec,
    placement: &'p JobPlacement,
    start: u64,
    progress: f64,
    tau_sum: f64,
    tau_slots: u64,
    max_p: usize,
}

impl<'a> Simulator<'a> {
    pub fn new(cluster: &'a Cluster, jobs: &'a [JobSpec], params: &'a ContentionParams) -> Self {
        Simulator {
            cluster,
            specs: jobs.iter().map(|j| (j.id, j)).collect(),
            params,
            options: SimOptions::default(),
        }
    }

    pub fn with_options(mut self, options: SimOptions) -> Self {
        self.options = options;
        self
    }

    /// Run the plan to completion (or the safety horizon) and report the
    /// realized makespan / JCTs under live contention.
    pub fn run<'p>(&self, plan: &'p Plan) -> SimOutcome {
        let mut state = ClusterState::new(self.cluster);
        let mut pending: std::collections::VecDeque<usize> = (0..plan.entries.len()).collect();
        let mut active: Vec<ActiveJob<'a, 'p>> = Vec::new();
        // Borrow placements from the plan; they must outlive active jobs.
        let entries = &plan.entries;
        let mut records: Vec<JobRecord> = Vec::with_capacity(entries.len());
        let mut busy_gpu_slots: u64 = 0;
        let mut t: u64 = 0;

        while (!pending.is_empty() || !active.is_empty()) && t < self.options.max_slots {
            // 1) Admission: walk the queue in dispatch order; start every
            //    job whose gang of GPUs is entirely free. Earlier entries
            //    win contested GPUs (we allocate as we scan).
            let mut admitted_any = true;
            while admitted_any {
                admitted_any = false;
                let mut i = 0;
                while i < pending.len() {
                    let idx = pending[i];
                    let e = &entries[idx];
                    let placement: &JobPlacement = &e.placement;
                    // online extension: a job cannot start before arrival
                    if self.specs[&e.job].arrival > t {
                        i += 1;
                        continue;
                    }
                    if placement.gpus().iter().all(|g| state.is_free(*g)) {
                        state.allocate(e.job, placement);
                        let spec = self.specs[&e.job];
                        active.push(ActiveJob {
                            job: e.job,
                            spec,
                            placement: &entries[idx].placement,
                            start: t,
                            progress: 0.0,
                            tau_sum: 0.0,
                            tau_slots: 0,
                            max_p: 0,
                        });
                        pending.remove(i);
                        admitted_any = true;
                    } else {
                        i += 1;
                    }
                }
            }

            if active.is_empty() {
                // nothing runnable yet (all pending jobs have future
                // arrivals); advance to the next arrival.
                if self.options.event_driven {
                    let next_arrival = pending
                        .iter()
                        .map(|&idx| self.specs[&entries[idx].job].arrival)
                        .filter(|&a| a > t)
                        .min();
                    t = next_arrival.unwrap_or(t + 1).min(self.options.max_slots);
                } else {
                    t += 1;
                }
                continue;
            }

            // 2) Contention snapshot (generalized Eq. 6 over the active
            //    set, per fabric link) — constant until the next admission
            //    or completion event.
            let refs: Vec<(JobId, &JobPlacement)> =
                active.iter().map(|a| (a.job, a.placement)).collect();
            let snap = ContentionSnapshot::build_ref(self.cluster, &refs);

            // Per-job rates for this period (shared kernel arithmetic),
            // each taken at the job's bottleneck link.
            let rates: Vec<RatePoint> = active
                .iter()
                .map(|a| {
                    kernel::rate_point(
                        self.params,
                        self.cluster,
                        a.spec,
                        a.placement,
                        snap.bottleneck(a.job),
                        self.options.fractional_progress,
                    )
                })
                .collect();

            // 3) Period length dt: 1 slot (reference mode), or jump to the
            //    next completion/arrival (event-driven fast path).
            let dt = if !self.options.event_driven {
                1
            } else {
                let mut dt = u64::MAX;
                for (a, r) in active.iter().zip(&rates) {
                    let remaining = a.spec.iterations as f64 - a.progress;
                    // stalled jobs yield u64::MAX, bounded below by max_slots
                    dt = dt.min(kernel::slots_until_done(remaining, r.inc));
                }
                // the next future arrival can unlock an admission
                let next_arrival = pending
                    .iter()
                    .map(|&idx| self.specs[&entries[idx].job].arrival)
                    .filter(|&a| a > t)
                    .min();
                if let Some(na) = next_arrival {
                    dt = dt.min(na - t);
                }
                dt.min(self.options.max_slots - t).max(1)
            };

            // 4) Progress every active job by dt periods of φ_j.
            for (a, r) in active.iter_mut().zip(&rates) {
                a.progress += r.inc * dt as f64;
                a.tau_sum += r.tau * dt as f64;
                a.tau_slots += dt;
                a.max_p = a.max_p.max(r.p);
                busy_gpu_slots += a.placement.num_workers() as u64 * dt;
            }
            t += dt;

            // 5) Completions at the end of the period.
            let mut i = 0;
            while i < active.len() {
                if active[i].progress >= active[i].spec.iterations as f64 {
                    let a = active.swap_remove(i);
                    state.release(a.job, a.placement);
                    records.push(JobRecord {
                        job: a.job,
                        arrival: a.spec.arrival,
                        start: a.start,
                        finish: t,
                        span: a.placement.span(),
                        workers: a.placement.num_workers(),
                        max_p: a.max_p,
                        mean_tau: a.tau_sum / a.tau_slots.max(1) as f64,
                        iterations_done: a.spec.iterations,
                        migrations: 0,
                    });
                } else {
                    i += 1;
                }
            }
        }

        let truncated = !pending.is_empty() || !active.is_empty();
        // Record unfinished jobs (truncation) with what they achieved.
        for a in active {
            records.push(JobRecord {
                job: a.job,
                arrival: a.spec.arrival,
                start: a.start,
                finish: t,
                span: a.placement.span(),
                workers: a.placement.num_workers(),
                max_p: a.max_p,
                mean_tau: a.tau_sum / a.tau_slots.max(1) as f64,
                iterations_done: a.progress as u64,
                migrations: 0,
            });
        }
        records.sort_by_key(|r| r.job);

        let makespan = records.iter().map(|r| r.finish).max().unwrap_or(0);
        let avg_jct = if records.is_empty() {
            0.0
        } else {
            records.iter().map(|r| r.jct() as f64).sum::<f64>() / records.len() as f64
        };
        let gpu_utilization = if makespan == 0 {
            0.0
        } else {
            busy_gpu_slots as f64 / (makespan * self.cluster.num_gpus() as u64) as f64
        };
        SimOutcome {
            makespan,
            avg_jct,
            gpu_utilization,
            records,
            slots_simulated: t,
            truncated,
        }
    }
}
