//! The time-slotted simulation engine.
//!
//! # Single-tracker architecture (§Perf)
//!
//! Since the incremental-simulation unification the engine runs on the
//! same [`ContentionTracker`] as the online event loop: **one tracker is
//! carried across every event period** of a run, admissions and
//! completions apply `O(path)` per-link count deltas, and no
//! `ContentionSnapshot` is rebuilt on the hot path. Cached
//! [`RatePoint`]s are invalidated by a link-keyed
//! [`DirtySet`](crate::contention::DirtySet):
//!
//! * an admit/complete changes the ring count of exactly the links the
//!   churned job crosses (the *touched* set);
//! * a job's bottleneck — `max count × oversub` over its crossed links —
//!   can only change when one of *its* crossed links is touched, so only
//!   jobs whose crossed-link set intersects the touched set are re-rated;
//!   every other cached rate is provably still exact.
//!
//! All engine buffers (the tracker's counts, the dirty-set's reverse
//! index, the active table) live in a [`SimScratch`] that
//! [`run_with`](Simulator::run_with) reuses across runs — the planners'
//! candidate-scoring loop ([`PlanScorer`](super::PlanScorer)) replays
//! hundreds of candidate plans without reallocating.
//!
//! The pre-unification engine — a full snapshot rebuild (`O(Σ span)` +
//! allocations) every period — is retained as
//! [`ContentionMode::SnapshotRebuild`] and the slot-by-slot loop as
//! `event_driven: false`; `tests/sim_engine_equivalence.rs` proves all
//! three modes produce bit-identical [`SimOutcome`]s, and
//! `benches/sim_engine.rs` records the throughput gap in
//! `BENCH_sim_engine.json`.

use super::kernel::{self, RatePoint};
use super::{JobRecord, SimOutcome};
use crate::cluster::{Cluster, ClusterState, JobPlacement};
use crate::contention::{ContentionParams, ContentionSnapshot, DirtySet};
use crate::jobs::{JobId, JobSpec};
use crate::online::ContentionTracker;
use crate::sched::Plan;
use std::collections::HashMap;

/// How the engine evaluates per-period contention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContentionMode {
    /// Reference path: rebuild a [`ContentionSnapshot`] every event
    /// period — `O(Σ_j span_j)` per period (buffer-reusing since the
    /// unification, but still a full recount). Kept for cross-checking
    /// and the engine bench.
    SnapshotRebuild,
    /// Persistent [`ContentionTracker`] + link-keyed dirty set: `O(path)`
    /// deltas per event, rates recomputed only for jobs whose bottleneck
    /// link counts actually changed. Bit-identical to the reference
    /// (property-tested); the default.
    TrackerDirtySet,
}

/// Engine options.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Safety horizon: stop after this many slots even if jobs remain
    /// (guards against mis-calibrated τ ≥ 1 where `φ = ⌊1/τ⌋ = 0`).
    pub max_slots: u64,
    /// When `φ_j[t]` floors to zero, fall back to fractional progress
    /// `1/τ` instead of stalling forever. Off by default (paper-faithful).
    pub fractional_progress: bool,
    /// Event-driven fast path (§Perf): between admissions/completions the
    /// active set — and therefore every `p_j`, `τ_j`, `φ_j` — is constant,
    /// so the engine jumps straight to the next event instead of ticking
    /// slot by slot. Produces *identical* results to the slot-by-slot
    /// reference (asserted by `fast_path_matches_reference`); disable only
    /// for cross-checking.
    pub event_driven: bool,
    /// Contention evaluation strategy (see [`ContentionMode`]).
    pub contention: ContentionMode,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            max_slots: 1_000_000,
            fractional_progress: false,
            event_driven: true,
            contention: ContentionMode::TrackerDirtySet,
        }
    }
}

/// Reusable engine state: the persistent tracker, the dirty-set reverse
/// index, the retained snapshot (reference mode) and the job → active-slot
/// index. Create once per (cluster, workload) and pass to
/// [`Simulator::run_with`] to score many plans without reallocating —
/// see [`PlanScorer`](super::PlanScorer).
#[derive(Debug, Clone)]
pub struct SimScratch {
    tracker: ContentionTracker,
    dirty: DirtySet,
    snapshot: ContentionSnapshot,
    /// `active_idx[job.0]` = index into the live `active` table, or
    /// `usize::MAX` when the job is not running.
    active_idx: Vec<usize>,
}

impl SimScratch {
    pub fn new(cluster: &Cluster) -> Self {
        SimScratch {
            tracker: ContentionTracker::new(cluster),
            dirty: DirtySet::new(cluster.topology().num_links()),
            snapshot: ContentionSnapshot::empty(cluster),
            active_idx: Vec::new(),
        }
    }

    /// Clear for a fresh run (buffers retained); `max_job_id` bounds the
    /// dense job-id space of the plan about to be replayed.
    fn reset(&mut self, max_job_id: usize) {
        self.tracker.reset();
        self.dirty.reset();
        self.active_idx.clear();
        self.active_idx.resize(max_job_id, usize::MAX);
    }
}

/// Replays a [`Plan`] against the analytical model, slot by slot.
pub struct Simulator<'a> {
    cluster: &'a Cluster,
    specs: HashMap<JobId, &'a JobSpec>,
    params: &'a ContentionParams,
    options: SimOptions,
}

struct ActiveJob<'a, 'p> {
    job: JobId,
    spec: &'a JobSpec,
    placement: &'p JobPlacement,
    start: u64,
    progress: f64,
    tau_sum: f64,
    tau_slots: u64,
    max_p: usize,
    /// Cached operating point for the current period — recomputed only
    /// when the dirty-set invalidates it (tracker mode) or every period
    /// (snapshot mode).
    rate: RatePoint,
}

impl<'a> Simulator<'a> {
    pub fn new(cluster: &'a Cluster, jobs: &'a [JobSpec], params: &'a ContentionParams) -> Self {
        Simulator {
            cluster,
            specs: jobs.iter().map(|j| (j.id, j)).collect(),
            params,
            options: SimOptions::default(),
        }
    }

    pub fn with_options(mut self, options: SimOptions) -> Self {
        self.options = options;
        self
    }

    /// Run the plan to completion (or the safety horizon) and report the
    /// realized makespan / JCTs under live contention.
    pub fn run<'p>(&self, plan: &'p Plan) -> SimOutcome {
        let mut scratch = SimScratch::new(self.cluster);
        self.run_with(&mut scratch, plan)
    }

    /// [`run`](Self::run) with caller-owned [`SimScratch`]: every engine
    /// buffer is reused across calls, so replaying many candidate plans
    /// (the planners' bisection loops) allocates only the output records.
    // archlint: allow(release-panic) event loop walks dense scratch vecs and a specs map keyed by the plan's own entries
    pub fn run_with<'p>(&self, scratch: &mut SimScratch, plan: &'p Plan) -> SimOutcome {
        use crate::obs::{ledger, metrics, timeline, trace};
        let use_tracker = self.options.contention == ContentionMode::TrackerDirtySet;
        let entries = &plan.entries;
        let _run_span = trace::span("sim.run", "sim").arg("jobs", entries.len() as f64);
        let topo = self.cluster.topology();
        let max_id = entries.iter().map(|e| e.job.0 + 1).max().unwrap_or(0);
        scratch.reset(max_id);
        let SimScratch { tracker, dirty, snapshot, active_idx } = scratch;

        let mut state = ClusterState::new(self.cluster);
        // Two-stage dispatch queue (§Perf — the old single `VecDeque` was
        // rescanned in full, future arrivals included, with an O(queue)
        // `remove` per admission):
        //
        // * `by_arrival` — all entries in (arrival, dispatch) order with a
        //   cursor; not-yet-arrived jobs are never scanned, and the
        //   next-future-arrival query is O(1) amortized;
        // * `pending`   — arrived-but-waiting entries in dispatch order,
        //   merged on arrival and compacted in place on admission, so one
        //   event period admits in O(admitted + blocked).
        let mut by_arrival: Vec<usize> = (0..entries.len()).collect();
        by_arrival.sort_by_key(|&i| (self.specs[&entries[i].job].arrival, i));
        let mut arr_cursor = 0usize;
        let mut pending: Vec<usize> = Vec::new();
        let mut newly: Vec<usize> = Vec::new();
        let mut merge_buf: Vec<usize> = Vec::new();
        let next_arrival = |cursor: usize| -> Option<u64> {
            by_arrival.get(cursor).map(|&i| self.specs[&entries[i].job].arrival)
        };

        let mut active: Vec<ActiveJob<'a, 'p>> = Vec::new();
        let mut records: Vec<JobRecord> = Vec::with_capacity(entries.len());
        let mut busy_gpu_slots: u64 = 0;
        let mut periods: u64 = 0;
        let mut t: u64 = 0;

        while (!pending.is_empty() || arr_cursor < by_arrival.len() || !active.is_empty())
            && t < self.options.max_slots
        {
            // Flight-recorder checkpoint (passive): one relaxed atomic
            // load unless the ledger is armed AND the cadence slot is
            // due. Link counts come from the tracker when it is live;
            // snapshot mode hashes the empty link set (a constant), so
            // cross-mode ledgers compare on the other streams.
            if ledger::checkpoint_due(t) {
                ledger::checkpoint(
                    t,
                    ledger::QueueCensus {
                        pending: pending.len() + (by_arrival.len() - arr_cursor),
                        running: active.len(),
                        recovering: 0,
                        free_gpus: self
                            .cluster
                            .server_ids()
                            .map(|s| state.free_on(s))
                            .sum(),
                    },
                    false,
                    || {
                        if use_tracker {
                            (0..topo.num_links())
                                .map(|l| {
                                    tracker.link_count(crate::topology::LinkId(l)) as u64
                                })
                                .collect::<Vec<u64>>()
                        } else {
                            Vec::new()
                        }
                    },
                );
            }

            // 1a) Reveal arrivals due by now into the dispatch queue,
            //     preserving dispatch (plan) order: a newly arrived entry
            //     with an earlier plan position outranks already-waiting
            //     later ones, exactly like the old full rescan.
            while arr_cursor < by_arrival.len() {
                let idx = by_arrival[arr_cursor];
                if self.specs[&entries[idx].job].arrival > t {
                    break;
                }
                newly.push(idx);
                arr_cursor += 1;
            }
            if !newly.is_empty() {
                newly.sort_unstable(); // (arrival, idx) order → idx order
                if pending.is_empty() {
                    std::mem::swap(&mut pending, &mut newly);
                } else {
                    // merge two idx-sorted runs
                    merge_buf.clear();
                    let (mut a, mut b) = (0usize, 0usize);
                    while a < pending.len() && b < newly.len() {
                        if pending[a] < newly[b] {
                            merge_buf.push(pending[a]);
                            a += 1;
                        } else {
                            merge_buf.push(newly[b]);
                            b += 1;
                        }
                    }
                    merge_buf.extend_from_slice(&pending[a..]);
                    merge_buf.extend_from_slice(&newly[b..]);
                    std::mem::swap(&mut pending, &mut merge_buf);
                }
                newly.clear();
            }

            // 1b) Admission: walk the arrived queue in dispatch order;
            //     start every job whose gang of GPUs is entirely free.
            //     Earlier entries win contested GPUs (we allocate as we
            //     scan), and one pass suffices — admissions only *take*
            //     GPUs, so a rescan could never admit more. Blocked jobs
            //     are compacted in place.
            let mut kept = 0usize;
            let mut admitted_any = false;
            for i in 0..pending.len() {
                let idx = pending[i];
                let e = &entries[idx];
                let placement: &'p JobPlacement = &e.placement;
                // free-gang fast check (per-server free counts, O(span))
                // before the exact per-GPU scan (O(G_j))
                let fits = placement
                    .servers()
                    .all(|s| state.free_on(s) >= placement.gpus_on(s))
                    && placement.gpus().iter().all(|g| state.is_free(*g));
                if !fits {
                    pending[kept] = idx;
                    kept += 1;
                    continue;
                }
                state.allocate(e.job, placement);
                if use_tracker {
                    tracker.admit(e.job, placement);
                    dirty.on_admit(topo, e.job, placement);
                    active_idx[e.job.0] = active.len();
                }
                active.push(ActiveJob {
                    job: e.job,
                    spec: self.specs[&e.job],
                    placement,
                    start: t,
                    progress: 0.0,
                    tau_sum: 0.0,
                    tau_slots: 0,
                    max_p: 0,
                    rate: RatePoint::IDLE,
                });
                admitted_any = true;
                if trace::armed() {
                    let link = if use_tracker {
                        tracker.try_bottleneck(e.job).and_then(|b| b.link)
                    } else {
                        None
                    };
                    trace::instant(
                        "job.admit",
                        "sim",
                        &[
                            ("job", e.job.0 as f64),
                            ("t", t as f64),
                            ("link", link.map_or(-1.0, |l| l.0 as f64)),
                        ],
                    );
                }
            }
            pending.truncate(kept);
            if use_tracker && admitted_any {
                timeline::sample(t, tracker);
            }

            if active.is_empty() {
                // nothing runnable yet (all remaining jobs have future
                // arrivals); advance to the next arrival.
                if self.options.event_driven {
                    t = next_arrival(arr_cursor).unwrap_or(t + 1).min(self.options.max_slots);
                } else {
                    t += 1;
                }
                continue;
            }

            // 2) Per-job rates for this period (shared kernel arithmetic),
            //    each taken at the job's bottleneck link — constant until
            //    the next admission or completion event.
            let _period_span = trace::span("sim.period", "sim")
                .arg("t", t as f64)
                .arg("active", active.len() as f64);
            if use_tracker {
                // Tracker + dirty set: only jobs whose bottleneck-link
                // counts changed since the last period are re-rated.
                let active_count = active.len();
                let rerated = dirty.drain(
                    |j| active_idx.get(j.0).map_or(false, |&i| i != usize::MAX),
                    |j| {
                        let a = &mut active[active_idx[j.0]];
                        a.rate = kernel::rate_point(
                            self.params,
                            self.cluster,
                            a.spec,
                            a.placement,
                            tracker.bottleneck(j),
                            self.options.fractional_progress,
                        );
                    },
                );
                metrics::add(metrics::Counter::DirtyMisses, rerated as u64);
                metrics::add(metrics::Counter::DirtyHits, (active_count - rerated) as u64);
                metrics::record(metrics::Hist::ReratedPerDrain, rerated as u64);
            } else {
                // Reference: full snapshot rebuild (generalized Eq. 6 over
                // the whole active set) and a re-rate of every job.
                snapshot
                    .rebuild_iter(self.cluster, active.iter().map(|a| (a.job, a.placement)));
                for a in active.iter_mut() {
                    a.rate = kernel::rate_point(
                        self.params,
                        self.cluster,
                        a.spec,
                        a.placement,
                        snapshot.bottleneck(a.job),
                        self.options.fractional_progress,
                    );
                }
            }
            periods += 1;
            metrics::incr(metrics::Counter::EnginePeriods);

            // 3) Period length dt: 1 slot (reference mode), or jump to the
            //    next completion/arrival (event-driven fast path).
            let dt = if !self.options.event_driven {
                1
            } else {
                let mut dt = u64::MAX;
                for a in active.iter() {
                    let remaining = a.spec.iterations as f64 - a.progress;
                    // stalled jobs yield u64::MAX, bounded below by max_slots
                    dt = dt.min(kernel::slots_until_done(remaining, a.rate.inc));
                }
                // the next future arrival can unlock an admission
                if let Some(na) = next_arrival(arr_cursor) {
                    debug_assert!(na > t, "due arrivals were revealed in step 1a");
                    dt = dt.min(na - t);
                }
                dt.min(self.options.max_slots - t).max(1)
            };

            // 4) Progress every active job by dt periods of φ_j.
            for a in active.iter_mut() {
                a.progress += a.rate.inc * dt as f64;
                a.tau_sum += a.rate.tau * dt as f64;
                a.tau_slots += dt;
                a.max_p = a.max_p.max(a.rate.p);
                busy_gpu_slots += a.placement.num_workers() as u64 * dt;
            }
            t += dt;

            // 5) Completions at the end of the period: O(path) count
            //    deltas, surviving link-sharers re-rated next period.
            let mut i = 0;
            let mut completed_any = false;
            while i < active.len() {
                if active[i].progress >= active[i].spec.iterations as f64 {
                    let a = active.swap_remove(i);
                    state.release(a.job, a.placement);
                    completed_any = true;
                    if trace::armed() {
                        let link = if use_tracker {
                            tracker.try_bottleneck(a.job).and_then(|b| b.link)
                        } else {
                            None
                        };
                        trace::instant(
                            "job.complete",
                            "sim",
                            &[
                                ("job", a.job.0 as f64),
                                ("t", t as f64),
                                ("link", link.map_or(-1.0, |l| l.0 as f64)),
                            ],
                        );
                    }
                    if use_tracker {
                        let _ = tracker.complete(a.job);
                        dirty.on_complete(topo, a.placement);
                        active_idx[a.job.0] = usize::MAX;
                        if i < active.len() {
                            active_idx[active[i].job.0] = i;
                        }
                    }
                    let rec = JobRecord {
                        job: a.job,
                        arrival: a.spec.arrival,
                        start: a.start,
                        finish: t,
                        span: a.placement.span(),
                        workers: a.placement.num_workers(),
                        max_p: a.max_p,
                        mean_tau: a.tau_sum / a.tau_slots.max(1) as f64,
                        iterations_done: a.spec.iterations,
                        migrations: 0,
                    };
                    ledger::note_record(&rec);
                    records.push(rec);
                } else {
                    i += 1;
                }
            }
            if use_tracker && completed_any {
                timeline::sample(t, tracker);
            }
        }

        let truncated =
            !pending.is_empty() || arr_cursor < by_arrival.len() || !active.is_empty();
        // Record unfinished jobs (truncation) with what they achieved.
        for a in active {
            let rec = JobRecord {
                job: a.job,
                arrival: a.spec.arrival,
                start: a.start,
                finish: t,
                span: a.placement.span(),
                workers: a.placement.num_workers(),
                max_p: a.max_p,
                mean_tau: a.tau_sum / a.tau_slots.max(1) as f64,
                iterations_done: kernel::completed_iterations(a.progress),
                migrations: 0,
            };
            ledger::note_record(&rec);
            records.push(rec);
        }
        records.sort_by_key(|r| r.job);
        // Forced final checkpoint: the record stream is complete, so two
        // equivalent plan replays close their ledgers on identical
        // digests regardless of cadence alignment.
        if ledger::armed() {
            ledger::checkpoint(
                t,
                ledger::QueueCensus {
                    pending: pending.len() + (by_arrival.len() - arr_cursor),
                    running: 0,
                    recovering: 0,
                    free_gpus: self.cluster.server_ids().map(|s| state.free_on(s)).sum(),
                },
                true,
                || {
                    if use_tracker {
                        (0..topo.num_links())
                            .map(|l| tracker.link_count(crate::topology::LinkId(l)) as u64)
                            .collect::<Vec<u64>>()
                    } else {
                        Vec::new()
                    }
                },
            );
        }

        let makespan = records.iter().map(|r| r.finish).max().unwrap_or(0);
        let avg_jct = if records.is_empty() {
            0.0
        } else {
            records.iter().map(|r| r.jct() as f64).sum::<f64>() / records.len() as f64
        };
        let gpu_utilization = if makespan == 0 {
            0.0
        } else {
            busy_gpu_slots as f64 / (makespan * self.cluster.num_gpus() as u64) as f64
        };
        SimOutcome {
            makespan,
            avg_jct,
            gpu_utilization,
            records,
            slots_simulated: t,
            periods,
            truncated,
        }
    }
}
