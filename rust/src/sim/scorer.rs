//! What-if candidate-plan scoring for the planners' search loops.
//!
//! SJF-BCO's Algorithm 1 crosses a θ bisection with a κ sweep and
//! evaluates *every* candidate schedule through the contention model
//! (the paper's Fig. 3 "search, then evaluate τ_j[t]" framework); the
//! baseline policies bisect θ the same way. Pre-unification each
//! evaluation built a fresh [`Simulator`] run that rebuilt a
//! `ContentionSnapshot` — `O(Σ span)` plus allocations — on every event
//! period of every candidate.
//!
//! [`PlanScorer`] owns one [`SimScratch`] (persistent tracker, dirty-set
//! reverse index, active table) and replays each candidate on the
//! tracker + dirty-set engine, so a full (θ × κ) search reuses the same
//! buffers throughout: per candidate the only allocation left is the
//! output record table. The per-period contention queries inside are the
//! tracker's `O(path)` speculative bottleneck reads — the same machinery
//! behind the online θ-admission `whatif_bottleneck` path.

use super::{SimOptions, SimOutcome, SimScratch, Simulator};
use crate::cluster::Cluster;
use crate::contention::ContentionParams;
use crate::jobs::JobSpec;
use crate::sched::Plan;

/// Reusable candidate-plan evaluator over one (cluster, workload, params)
/// context.
pub struct PlanScorer<'a> {
    sim: Simulator<'a>,
    scratch: SimScratch,
}

impl<'a> PlanScorer<'a> {
    pub fn new(cluster: &'a Cluster, jobs: &'a [JobSpec], params: &'a ContentionParams) -> Self {
        PlanScorer { sim: Simulator::new(cluster, jobs, params), scratch: SimScratch::new(cluster) }
    }

    /// Override the engine options (defaults: event-driven tracker mode).
    pub fn with_options(mut self, options: SimOptions) -> Self {
        self.sim = self.sim.with_options(options);
        self
    }

    /// Realized makespan of one candidate plan under live contention.
    pub fn makespan(&mut self, plan: &Plan) -> u64 {
        let _span = crate::obs::trace::span("scorer.makespan", "planner")
            .arg("entries", plan.entries.len() as f64);
        self.sim.run_with(&mut self.scratch, plan).makespan
    }

    /// Full outcome of one candidate plan (records allocate; the engine
    /// buffers are still reused).
    pub fn outcome(&mut self, plan: &Plan) -> SimOutcome {
        let _span = crate::obs::trace::span("scorer.outcome", "planner")
            .arg("entries", plan.entries.len() as f64);
        self.sim.run_with(&mut self.scratch, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{schedule, Policy};
    use crate::trace::TraceGenerator;

    #[test]
    fn repeated_scoring_matches_fresh_runs() {
        let cluster = Cluster::uniform(4, 8, 1.0, 25.0);
        let params = ContentionParams::paper();
        let jobs = TraceGenerator::tiny().generate(3);
        let plan_a = schedule(Policy::FirstFit, &cluster, &jobs, &params, 100_000).unwrap();
        let plan_b =
            schedule(Policy::ListScheduling, &cluster, &jobs, &params, 100_000).unwrap();
        let mut scorer = PlanScorer::new(&cluster, &jobs, &params);
        // interleave candidates; scratch reuse must never bleed state
        for _ in 0..3 {
            for plan in [&plan_a, &plan_b] {
                let fresh = Simulator::new(&cluster, &jobs, &params).run(plan);
                assert_eq!(scorer.makespan(plan), fresh.makespan);
                let scored = scorer.outcome(plan);
                assert_eq!(scored.makespan, fresh.makespan);
                assert_eq!(scored.avg_jct, fresh.avg_jct);
                assert_eq!(scored.records.len(), fresh.records.len());
            }
        }
    }
}
