//! Discrete-event (time-slotted) evaluation of a schedule under the full
//! contention model.
//!
//! The planner side of the paper works with *estimated* execution times
//! ρ̂_j(y^k)/u; this simulator is the "evaluate τ_j[t]" half of the search
//! framework (paper Fig. 3): it replays a [`Plan`](crate::sched::Plan)
//! slot-by-slot, recomputing each active job's contention degree `p_j[t]`
//! (Eq. 6), bandwidth `B_j(y[t])`, per-iteration time `τ_j[t]` (Eq. 8) and
//! progress `φ_j[t]` (Eq. 9) from the *live* set of co-running jobs — so
//! the reported makespan reflects actual contention, not estimates.

mod engine;
pub mod kernel;
mod outcome;
mod scorer;

pub use engine::{ContentionMode, SimOptions, SimScratch, Simulator};
pub use outcome::{JobRecord, Percentiles, SimOutcome};
pub use scorer::PlanScorer;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, JobPlacement, ServerId};
    use crate::contention::ContentionParams;
    use crate::jobs::{JobId, JobSpec};
    use crate::sched::{Plan, PlannedJob};

    fn one_job_plan(c: &Cluster, job: &JobSpec, gpus: Vec<(usize, usize)>) -> Plan {
        let placement = JobPlacement::new(
            gpus.into_iter().map(|(s, i)| c.global_gpu(ServerId(s), i)).collect(),
        );
        Plan::new(
            "test",
            vec![PlannedJob { job: job.id, placement, est_start: 0.0, est_finish: 0.0 }],
        )
    }

    #[test]
    fn single_colocated_job_runs_at_model_speed() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let params = ContentionParams::paper();
        let mut job = JobSpec::synthetic(JobId(0), 2);
        job.iterations = 500;
        let plan = one_job_plan(&c, &job, vec![(0, 0), (0, 1)]);
        let jobs = vec![job.clone()];
        let out = Simulator::new(&c, &jobs, &params).run(&plan);
        // expected: tau colocated, phi per slot, ceil(F/phi) slots
        let placement = &plan.entries[0].placement;
        let tau = params.tau(&c, &job, placement, 0);
        let phi = params.phi(tau);
        let expect = (job.iterations + phi - 1) / phi;
        assert_eq!(out.makespan, expect);
        assert_eq!(out.records.len(), 1);
        assert_eq!(out.records[0].start, 0);
    }

    #[test]
    fn contention_slows_spread_jobs() {
        let c = Cluster::uniform(2, 8, 1.0, 25.0);
        let params = ContentionParams::paper();
        let mk_job = |id: usize| {
            let mut j = JobSpec::synthetic(JobId(id), 4);
            j.iterations = 1000;
            j
        };
        let jobs: Vec<_> = (0..3).map(mk_job).collect();

        // Case A: each job spread alone (sequential plans) vs
        // Case B: all three spread concurrently.
        let spread = |base: usize| {
            JobPlacement::new(vec![
                c.global_gpu(ServerId(0), base),
                c.global_gpu(ServerId(0), base + 1),
                c.global_gpu(ServerId(1), base),
                c.global_gpu(ServerId(1), base + 1),
            ])
        };
        let solo_plan = Plan::new(
            "solo",
            vec![PlannedJob {
                job: JobId(0),
                placement: spread(0),
                est_start: 0.0,
                est_finish: 0.0,
            }],
        );
        let solo = Simulator::new(&c, &jobs[..1].to_vec(), &params).run(&solo_plan);

        let all_plan = Plan::new(
            "concurrent",
            (0..3)
                .map(|i| PlannedJob {
                    job: JobId(i),
                    placement: spread(2 * i),
                    est_start: 0.0,
                    est_finish: 0.0,
                })
                .collect(),
        );
        let all = Simulator::new(&c, &jobs, &params).run(&all_plan);
        assert!(
            all.makespan > solo.makespan,
            "contention must slow concurrent spread jobs: {} vs {}",
            all.makespan,
            solo.makespan
        );
        // every record saw contention degree 3 while all three ran
        assert!(all.records.iter().all(|r| r.max_p >= 2));
    }

    #[test]
    fn queued_job_waits_for_gpus() {
        let c = Cluster::uniform(1, 4, 1.0, 25.0);
        let params = ContentionParams::paper();
        let mut j0 = JobSpec::synthetic(JobId(0), 4);
        j0.iterations = 200;
        let mut j1 = JobSpec::synthetic(JobId(1), 4);
        j1.iterations = 200;
        let jobs = vec![j0, j1];
        let placement = JobPlacement::new(
            (0..4).map(|i| c.global_gpu(ServerId(0), i)).collect::<Vec<_>>(),
        );
        let plan = Plan::new(
            "fifo",
            vec![
                PlannedJob {
                    job: JobId(0),
                    placement: placement.clone(),
                    est_start: 0.0,
                    est_finish: 0.0,
                },
                PlannedJob {
                    job: JobId(1),
                    placement,
                    est_start: 0.0,
                    est_finish: 0.0,
                },
            ],
        );
        let out = Simulator::new(&c, &jobs, &params).run(&plan);
        let r0 = out.records.iter().find(|r| r.job == JobId(0)).unwrap();
        let r1 = out.records.iter().find(|r| r.job == JobId(1)).unwrap();
        assert_eq!(r0.start, 0);
        assert_eq!(r1.start, r0.finish, "gang job starts when GPUs release");
        assert_eq!(out.makespan, r1.finish);
    }

    #[test]
    fn fast_path_matches_reference() {
        // the event-driven engine must reproduce the slot-by-slot
        // reference exactly, record for record
        let mut rng = crate::util::Rng::seed_from_u64(99);
        for case in 0..25 {
            let c = Cluster::random(4, rng.next_u64());
            let params = ContentionParams::paper();
            let n = rng.gen_usize(2, 8);
            let jobs: Vec<JobSpec> = (0..n)
                .map(|i| {
                    let mut j = JobSpec::synthetic(JobId(i), rng.gen_usize(1, 4));
                    j.iterations = rng.gen_u64(100, 3000);
                    j.arrival = if rng.gen_f64() < 0.5 { rng.gen_u64(0, 40) } else { 0 };
                    j
                })
                .collect();
            let plan = crate::sched::schedule(
                crate::sched::Policy::ListScheduling,
                &c,
                &jobs,
                &params,
                1_000_000,
            )
            .unwrap();
            let fast = Simulator::new(&c, &jobs, &params).run(&plan);
            let snap = Simulator::new(&c, &jobs, &params)
                .with_options(SimOptions {
                    contention: ContentionMode::SnapshotRebuild,
                    ..SimOptions::default()
                })
                .run(&plan);
            let slow = Simulator::new(&c, &jobs, &params)
                .with_options(SimOptions {
                    event_driven: false,
                    ..SimOptions::default()
                })
                .run(&plan);
            // the two event-driven contention modes are fully bit-identical
            assert_eq!(fast.makespan, snap.makespan, "case {case}");
            assert_eq!(fast.avg_jct, snap.avg_jct, "case {case}");
            assert_eq!(fast.periods, snap.periods, "case {case}: same period structure");
            for (a, b) in fast.records.iter().zip(&snap.records) {
                assert_eq!((a.job, a.start, a.finish), (b.job, b.start, b.finish));
                assert_eq!(a.mean_tau, b.mean_tau, "case {case}: bitwise");
            }
            assert_eq!(fast.makespan, slow.makespan, "case {case}");
            assert_eq!(fast.avg_jct, slow.avg_jct, "case {case}");
            assert_eq!(fast.records.len(), slow.records.len());
            for (a, b) in fast.records.iter().zip(&slow.records) {
                assert_eq!((a.job, a.start, a.finish), (b.job, b.start, b.finish));
                assert_eq!(a.max_p, b.max_p);
                assert!((a.mean_tau - b.mean_tau).abs() < 1e-9);
            }
            assert_eq!(fast.gpu_utilization, slow.gpu_utilization);
        }
    }

    #[test]
    fn arrival_gates_start() {
        let c = Cluster::uniform(1, 4, 1.0, 25.0);
        let params = ContentionParams::paper();
        let mut job = JobSpec::synthetic(JobId(0), 2);
        job.iterations = 100;
        job.arrival = 25;
        let plan = one_job_plan(&c, &job, vec![(0, 0), (0, 1)]);
        let jobs = vec![job];
        let out = Simulator::new(&c, &jobs, &params).run(&plan);
        let r = &out.records[0];
        assert_eq!(r.start, 25, "job must wait for its arrival");
        assert_eq!(r.arrival, 25);
        assert_eq!(r.wait(), 0, "no queueing beyond arrival on an empty cluster");
        assert_eq!(r.jct(), r.finish - 25);
    }

    #[test]
    fn makespan_counts_all_jobs() {
        let c = Cluster::uniform(4, 8, 1.0, 25.0);
        let params = ContentionParams::paper();
        let jobs: Vec<_> = (0..6)
            .map(|i| {
                let mut j = JobSpec::synthetic(JobId(i), 1 + (i % 3));
                j.iterations = 300 + 50 * i as u64;
                j
            })
            .collect();
        let plan = crate::sched::schedule(
            crate::sched::Policy::FirstFit,
            &c,
            &jobs,
            &params,
            10_000,
        )
        .unwrap();
        let out = Simulator::new(&c, &jobs, &params).run(&plan);
        assert_eq!(out.records.len(), 6);
        assert_eq!(out.makespan, out.records.iter().map(|r| r.finish).max().unwrap());
        assert!(out.avg_jct > 0.0);
    }
}
