//! Simulation results.

use crate::jobs::JobId;

/// Per-job outcome of a simulated schedule.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub job: JobId,
    /// Arrival slot (0 in the paper's batch setting).
    pub arrival: u64,
    /// Actual start slot `a_j` (when all gang GPUs became free).
    pub start: u64,
    /// Actual completion slot `T_j` (Eq. 9).
    pub finish: u64,
    /// Server span of the placement.
    pub span: usize,
    /// Max contention degree `p_j[t]` observed over the job's lifetime.
    pub max_p: usize,
    /// Time-average per-iteration time (slots).
    pub mean_tau: f64,
    /// Iterations completed (== F_j on success).
    pub iterations_done: u64,
}

impl JobRecord {
    /// Job completion time (finish − arrival).
    pub fn jct(&self) -> u64 {
        self.finish - self.arrival
    }

    /// Queueing delay before the gang started.
    pub fn wait(&self) -> u64 {
        self.start - self.arrival
    }
}

/// Aggregate outcome of one simulated schedule.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// `max_j T_j` — the paper's objective.
    pub makespan: u64,
    /// Mean job completion time (paper Fig. 4 also reports avg JCT).
    pub avg_jct: f64,
    /// Fraction of GPU-slots spent busy up to the makespan.
    pub gpu_utilization: f64,
    /// Per-job records, sorted by job id.
    pub records: Vec<JobRecord>,
    /// Slots actually simulated (== makespan unless truncated).
    pub slots_simulated: u64,
    /// True if the safety horizon truncated the run before all jobs done.
    pub truncated: bool,
}

impl SimOutcome {
    pub fn record(&self, job: JobId) -> Option<&JobRecord> {
        self.records.iter().find(|r| r.job == job)
    }

    /// p-th percentile of JCT (p in [0, 100]).
    pub fn jct_percentile(&self, p: f64) -> u64 {
        if self.records.is_empty() {
            return 0;
        }
        let mut jcts: Vec<u64> = self.records.iter().map(|r| r.jct()).collect();
        jcts.sort_unstable();
        let idx = ((p / 100.0) * (jcts.len() - 1) as f64).round() as usize;
        jcts[idx.min(jcts.len() - 1)]
    }

    /// Mean queueing delay.
    pub fn avg_wait(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.wait() as f64).sum::<f64>() / self.records.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, start: u64, finish: u64) -> JobRecord {
        JobRecord {
            job: JobId(id),
            arrival: 0,
            start,
            finish,
            span: 1,
            max_p: 0,
            mean_tau: 0.02,
            iterations_done: 100,
        }
    }

    #[test]
    fn percentiles_and_waits() {
        let out = SimOutcome {
            makespan: 40,
            avg_jct: 25.0,
            gpu_utilization: 0.5,
            records: vec![rec(0, 0, 10), rec(1, 5, 20), rec(2, 10, 40)],
            slots_simulated: 40,
            truncated: false,
        };
        assert_eq!(out.jct_percentile(0.0), 10);
        assert_eq!(out.jct_percentile(100.0), 40);
        assert_eq!(out.jct_percentile(50.0), 20);
        assert!((out.avg_wait() - 5.0).abs() < 1e-12);
        assert!(out.record(JobId(1)).is_some());
    }

    #[test]
    fn empty_outcome_is_safe() {
        let out = SimOutcome {
            makespan: 0,
            avg_jct: 0.0,
            gpu_utilization: 0.0,
            records: vec![],
            slots_simulated: 0,
            truncated: false,
        };
        assert_eq!(out.jct_percentile(50.0), 0);
        assert_eq!(out.avg_wait(), 0.0);
    }
}
