//! Simulation results.

use crate::jobs::JobId;

/// Per-job outcome of a simulated schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub job: JobId,
    /// Arrival slot (0 in the paper's batch setting).
    pub arrival: u64,
    /// Actual start slot `a_j` (when all gang GPUs became free).
    pub start: u64,
    /// Actual completion slot `T_j` (Eq. 9).
    pub finish: u64,
    /// Server span of the placement.
    pub span: usize,
    /// Workers (GPUs) in the gang — `G_j`.
    pub workers: usize,
    /// Max contention degree `p_j[t]` observed over the job's lifetime.
    pub max_p: usize,
    /// Time-average per-iteration time (slots).
    pub mean_tau: f64,
    /// Iterations completed (== F_j on success).
    pub iterations_done: u64,
    /// Preemption/migration count over the job's lifetime (0 in the
    /// offline replay engine — plans never re-place a running job; the
    /// online loop's completion-event migration policy increments it).
    pub migrations: usize,
}

impl JobRecord {
    /// Job completion time (finish − arrival).
    pub fn jct(&self) -> u64 {
        self.finish - self.arrival
    }

    /// Queueing delay before the gang started.
    pub fn wait(&self) -> u64 {
        self.start - self.arrival
    }
}

/// Aggregate outcome of one simulated schedule.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// `max_j T_j` — the paper's objective.
    pub makespan: u64,
    /// Mean job completion time (paper Fig. 4 also reports avg JCT).
    pub avg_jct: f64,
    /// Fraction of GPU-slots spent busy up to the makespan.
    pub gpu_utilization: f64,
    /// Per-job records, sorted by job id.
    pub records: Vec<JobRecord>,
    /// Slots actually simulated (== makespan unless truncated).
    pub slots_simulated: u64,
    /// Constant-rate event periods evaluated (rate refresh + jump). The
    /// engine bench derives events/sec and ns/event from this; identical
    /// across contention modes of the same event-driven run.
    pub periods: u64,
    /// True if the safety horizon truncated the run before all jobs done.
    pub truncated: bool,
}

/// Nearest-rank percentile over a **sorted** slice (p in [0, 100]); 0
/// when empty. The single rank rule (`idx = round(p/100 · (n−1))`) shared
/// by every per-job percentile metric — including the streaming
/// [`crate::metrics::StreamSketch`] — so it cannot drift between them.
pub(crate) fn percentile_of_sorted(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    // NaN / negative p clamps to rank 0; the cast is then in-range and
    // the final `.min` + direct last() keeps the lookup panic-free.
    let idx = if rank.is_nan() || rank <= 0.0 { 0 } else { rank.round() as usize };
    sorted.get(idx.min(sorted.len() - 1)).copied().unwrap_or(0)
}

/// A sorted view over one metric's values: sort **once**, answer any
/// number of percentile queries in O(1) each. Callers that read several
/// percentiles per outcome (the `experiments/` sweep rows) build one of
/// these instead of paying a fresh collect + O(n log n) sort per query.
#[derive(Debug, Clone)]
pub struct Percentiles {
    sorted: Vec<u64>,
}

impl Percentiles {
    /// Take ownership of the values and sort them once.
    pub fn from_values(mut values: Vec<u64>) -> Self {
        values.sort_unstable();
        Percentiles { sorted: values }
    }

    /// Nearest-rank percentile, `p ∈ [0, 100]`; 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        percentile_of_sorted(&self.sorted, p)
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }
}

impl SimOutcome {
    pub fn record(&self, job: JobId) -> Option<&JobRecord> {
        self.records.iter().find(|r| r.job == job)
    }

    /// Sorted view over all JCTs — sort once, query many percentiles.
    pub fn jct_percentiles(&self) -> Percentiles {
        Percentiles::from_values(self.records.iter().map(|r| r.jct()).collect())
    }

    /// Sorted view over all queueing delays.
    pub fn wait_percentiles(&self) -> Percentiles {
        Percentiles::from_values(self.records.iter().map(|r| r.wait()).collect())
    }

    /// One pass over the records, split by `pred` into two sorted wait
    /// views `(matching, rest)` — the overload sweep reads per-class
    /// percentiles without re-collecting per query.
    pub fn wait_percentiles_partition(
        &self,
        pred: impl Fn(&JobRecord) -> bool,
    ) -> (Percentiles, Percentiles) {
        let mut hit = Vec::new();
        let mut miss = Vec::new();
        for r in &self.records {
            if pred(r) {
                hit.push(r.wait());
            } else {
                miss.push(r.wait());
            }
        }
        (Percentiles::from_values(hit), Percentiles::from_values(miss))
    }

    /// p-th percentile of JCT (p in [0, 100]).
    pub fn jct_percentile(&self, p: f64) -> u64 {
        self.jct_percentiles().percentile(p)
    }

    /// Mean queueing delay.
    pub fn avg_wait(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.wait() as f64).sum::<f64>() / self.records.len() as f64
    }

    /// p-th percentile of queueing delay (arrival → start), p in [0, 100].
    pub fn wait_percentile(&self, p: f64) -> u64 {
        self.wait_percentiles().percentile(p)
    }

    /// p-th percentile of queueing delay over the records matching `pred`
    /// — per-class wait under overload (e.g. single-GPU vs multi-GPU
    /// gangs queue very differently once admission control bites).
    pub fn wait_percentile_where(
        &self,
        p: f64,
        pred: impl Fn(&JobRecord) -> bool,
    ) -> u64 {
        Percentiles::from_values(
            self.records.iter().filter(|r| pred(r)).map(|r| r.wait()).collect(),
        )
        .percentile(p)
    }

    /// Total migrations over all records (0 for offline replays).
    pub fn total_migrations(&self) -> usize {
        self.records.iter().map(|r| r.migrations).sum()
    }

    /// Time-averaged GPU utilization over the span the cluster was
    /// actually in service: busy GPU-slots divided by capacity between the
    /// first start and the last finish. Under staggered arrivals this
    /// excludes the leading idle period [`gpu_utilization`](Self::gpu_utilization)
    /// charges to the cluster, so it is the fairer online metric.
    pub fn service_utilization(&self, num_gpus: usize) -> f64 {
        let first_start = self.records.iter().map(|r| r.start).min().unwrap_or(0);
        let span = self.makespan.saturating_sub(first_start);
        if span == 0 || num_gpus == 0 {
            return 0.0;
        }
        let busy: f64 = self
            .records
            .iter()
            .map(|r| (r.finish - r.start) as f64 * r.workers as f64)
            .sum();
        busy / (span * num_gpus as u64) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, start: u64, finish: u64) -> JobRecord {
        JobRecord {
            job: JobId(id),
            arrival: 0,
            start,
            finish,
            span: 1,
            workers: 1,
            max_p: 0,
            mean_tau: 0.02,
            iterations_done: 100,
            migrations: 0,
        }
    }

    #[test]
    fn percentiles_and_waits() {
        let out = SimOutcome {
            makespan: 40,
            avg_jct: 25.0,
            gpu_utilization: 0.5,
            records: vec![rec(0, 0, 10), rec(1, 5, 20), rec(2, 10, 40)],
            slots_simulated: 40,
            periods: 3,
            truncated: false,
        };
        assert_eq!(out.jct_percentile(0.0), 10);
        assert_eq!(out.jct_percentile(100.0), 40);
        assert_eq!(out.jct_percentile(50.0), 20);
        assert!((out.avg_wait() - 5.0).abs() < 1e-12);
        assert!(out.record(JobId(1)).is_some());
        assert_eq!(out.wait_percentile(0.0), 0);
        assert_eq!(out.wait_percentile(100.0), 10);
        assert_eq!(out.wait_percentile(50.0), 5);
        // filtered percentile: only jobs 1 and 2 (waits 5 and 10)
        assert_eq!(out.wait_percentile_where(100.0, |r| r.job.0 >= 1), 10);
        assert_eq!(out.wait_percentile_where(0.0, |r| r.job.0 >= 1), 5);
        assert_eq!(out.wait_percentile_where(50.0, |r| r.job.0 >= 99), 0, "empty class");
        assert_eq!(out.total_migrations(), 0);
        // busy = 10 + 15 + 30 = 55 GPU-slots over 40 slots x 1 GPU... the
        // fixture pretends a 2-GPU cluster for a fractional check:
        assert!((out.service_utilization(2) - 55.0 / 80.0).abs() < 1e-12);
    }

    #[test]
    fn sorted_views_match_per_query_percentiles() {
        let out = SimOutcome {
            makespan: 40,
            avg_jct: 25.0,
            gpu_utilization: 0.5,
            records: vec![rec(0, 0, 10), rec(1, 5, 20), rec(2, 10, 40)],
            slots_simulated: 40,
            periods: 3,
            truncated: false,
        };
        let jcts = out.jct_percentiles();
        let waits = out.wait_percentiles();
        for p in [0.0, 25.0, 50.0, 75.0, 95.0, 100.0] {
            assert_eq!(jcts.percentile(p), out.jct_percentile(p), "jct p={p}");
            assert_eq!(waits.percentile(p), out.wait_percentile(p), "wait p={p}");
        }
        // the one-pass partition agrees with the filtered queries
        let (hit, miss) = out.wait_percentiles_partition(|r| r.job.0 >= 1);
        assert_eq!(hit.len(), 2);
        assert_eq!(miss.len(), 1);
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(hit.percentile(p), out.wait_percentile_where(p, |r| r.job.0 >= 1));
            assert_eq!(miss.percentile(p), out.wait_percentile_where(p, |r| r.job.0 < 1));
        }
        // empty view is safe
        assert_eq!(Percentiles::from_values(vec![]).percentile(50.0), 0);
        assert!(Percentiles::from_values(vec![]).is_empty());
    }

    #[test]
    fn empty_outcome_is_safe() {
        let out = SimOutcome {
            makespan: 0,
            avg_jct: 0.0,
            gpu_utilization: 0.0,
            records: vec![],
            slots_simulated: 0,
            periods: 0,
            truncated: false,
        };
        assert_eq!(out.jct_percentile(50.0), 0);
        assert_eq!(out.avg_wait(), 0.0);
        assert_eq!(out.wait_percentile(95.0), 0);
        assert_eq!(out.service_utilization(8), 0.0);
    }
}
