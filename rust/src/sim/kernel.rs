//! The event-driven simulation core shared by the offline replay engine
//! ([`Simulator`](super::Simulator)) and the non-clairvoyant
//! [`online`](crate::online) scheduler loop.
//!
//! Between two scheduling events (an admission or a completion) the active
//! set is constant, so every job's contention degree `p_j`, per-iteration
//! time `τ_j` (Eq. 8) and progress rate `φ_j` (Eq. 9) are constant too.
//! Both engines therefore advance time in *periods*: compute each active
//! job's [`RatePoint`], jump `dt = min(next completion, next arrival)`
//! slots at once, and only then re-evaluate. These helpers are that
//! shared per-period arithmetic — keeping the two engines numerically
//! identical by construction.

use crate::cluster::{Cluster, JobPlacement};
use crate::contention::ContentionParams;
use crate::jobs::JobSpec;
use crate::topology::Bottleneck;

/// One active job's constant-rate operating point for the current period.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RatePoint {
    /// Ring count at the job's bottleneck link — Eq. 6's `p_j[t]` on a
    /// flat fabric.
    pub p: usize,
    /// The **allocated bandwidth** `B_j` the point was evaluated at
    /// (model units per slot): `b^i` co-located, else the job's
    /// contention-degraded share of the fabric — `b^e / f(α, k_j)` with
    /// `k_j` taken from the bottleneck's effective degree (degree
    /// counting or max-min share, per the fabric's
    /// [`ContentionModel`](crate::net::ContentionModel)).
    pub bandwidth: f64,
    /// Per-iteration time `τ_j[t]` in slots (Eq. 8).
    pub tau: f64,
    /// Iterations completed per slot: `φ_j = ⌊1/τ⌋`, or the fractional
    /// fallback `1/τ` when enabled and `φ` floors to zero.
    pub inc: f64,
}

impl RatePoint {
    /// Placeholder for a job with no evaluated rate yet (a freshly
    /// admitted job before its first dirty-set drain, or a frozen
    /// migrant): makes no progress, holds no bandwidth, accrues no τ.
    pub const IDLE: RatePoint = RatePoint { p: 0, bandwidth: 0.0, tau: 0.0, inc: 0.0 };
}

/// Evaluate one job's operating point given its bottleneck-link
/// contention (use [`Bottleneck::flat`] for a scalar Eq. 6 degree): the
/// allocated bandwidth is resolved first, then τ/φ follow from it — the
/// rate point is a function of the *allocation*, with the bottleneck
/// degree as the allocator's input.
pub fn rate_point(
    params: &ContentionParams,
    cluster: &Cluster,
    spec: &JobSpec,
    placement: &JobPlacement,
    bottleneck: Bottleneck,
    fractional_progress: bool,
) -> RatePoint {
    let bandwidth = params.bandwidth_at(cluster, placement, bottleneck);
    let tau = params.tau_with_bandwidth(cluster, spec, placement, bandwidth);
    let phi = params.phi(tau);
    let inc = if phi == 0 && fractional_progress { 1.0 / tau } else { phi as f64 };
    RatePoint { p: bottleneck.p, bandwidth, tau, inc }
}

/// Slots until `remaining` iterations finish at `inc` iterations/slot
/// (at least 1); `u64::MAX` for a stalled job (`inc == 0`), which the
/// caller bounds by its safety horizon.
///
/// The division can overflow f64 (`remaining = ∞`, or a subnormal `inc`
/// like `f64::MIN_POSITIVE`) or go undefined (`∞ / ∞ = NaN`); both cases
/// saturate explicitly to the stalled sentinel instead of relying on the
/// platform's float→int cast behaviour.
pub fn slots_until_done(remaining: f64, inc: f64) -> u64 {
    if inc > 0.0 {
        let ratio = remaining / inc;
        if !ratio.is_finite() {
            return u64::MAX; // overflowed or NaN: indistinguishable from stalled
        }
        let slots = ratio.ceil().max(1.0);
        if slots >= u64::MAX as f64 {
            u64::MAX
        } else {
            slots as u64
        }
    } else {
        u64::MAX
    }
}

/// Iterations completed so far, as reported in a truncated-job record:
/// a guarded `progress → u64` cast. Progress is accumulated as f64 (it
/// can be fractional under `fractional_progress`), so the horizon-flush
/// paths must not trust a raw `as` cast — NaN and negative values clamp
/// to 0, and anything at or above `u64::MAX` saturates.
pub fn completed_iterations(progress: f64) -> u64 {
    if progress.is_nan() || progress <= 0.0 {
        return 0;
    }
    if progress >= u64::MAX as f64 {
        u64::MAX // +∞ included: saturate rather than trust the cast
    } else {
        progress as u64
    }
}

/// Completion-time estimate for a job that must pay a checkpoint-restart
/// penalty of `restart_slots` before resuming at rate `inc`: the shared
/// arithmetic behind the migration decision (saturating — a stalled rate
/// stays the `u64::MAX` sentinel).
pub fn slots_until_done_with_restart(remaining: f64, inc: f64, restart_slots: u64) -> u64 {
    slots_until_done(remaining, inc).saturating_add(restart_slots)
}

/// Does moving a job with `remaining` iterations from rate `inc_old` to
/// rate `inc_new` pay off *net of* a `restart_slots` checkpoint-restart
/// penalty? True iff the projected completion strictly improves — the
/// guard the online [`MigrationPolicy`](crate::online::MigrationControl)
/// applies before preempting a running job.
pub fn migration_pays(remaining: f64, inc_old: f64, inc_new: f64, restart_slots: u64) -> bool {
    slots_until_done_with_restart(remaining, inc_new, restart_slots)
        < slots_until_done(remaining, inc_old)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerId;
    use crate::jobs::JobId;

    #[test]
    fn rate_point_matches_params() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let params = ContentionParams::paper();
        let job = JobSpec::synthetic(JobId(0), 2);
        let pl = JobPlacement::new(vec![c.global_gpu(ServerId(0), 0), c.global_gpu(ServerId(0), 1)]);
        let r = rate_point(&params, &c, &job, &pl, Bottleneck::NONE, false);
        assert_eq!(r.p, 0);
        assert!((r.tau - params.tau(&c, &job, &pl, 0)).abs() < 1e-15);
        assert_eq!(r.inc, params.phi(r.tau) as f64);
        assert_eq!(r.bandwidth, c.intra_bw, "co-located rings run on the intra link");
        // spread ring: the rate point carries the contention-degraded
        // allocation the τ was computed from
        let spread =
            JobPlacement::new(vec![c.global_gpu(ServerId(0), 0), c.global_gpu(ServerId(1), 0)]);
        let r = rate_point(&params, &c, &job, &spread, Bottleneck::flat(3), false);
        assert_eq!(r.bandwidth, params.bandwidth(&c, &spread, 3));
        assert_eq!(RatePoint::IDLE.bandwidth, 0.0);
    }

    #[test]
    fn fractional_fallback_only_when_enabled() {
        let c = Cluster::uniform(2, 4, 0.001, 25.0); // starved inter-server link
        let params = ContentionParams::paper();
        let job = JobSpec::synthetic(JobId(0), 2);
        let pl = JobPlacement::new(vec![c.global_gpu(ServerId(0), 0), c.global_gpu(ServerId(1), 0)]);
        let stalled = rate_point(&params, &c, &job, &pl, Bottleneck::flat(1), false);
        assert_eq!(stalled.inc, 0.0, "tau {} should floor phi to 0", stalled.tau);
        let frac = rate_point(&params, &c, &job, &pl, Bottleneck::flat(1), true);
        assert!(frac.inc > 0.0 && frac.inc < 1.0);
    }

    #[test]
    fn oversubscribed_bottleneck_reduces_rate() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let params = ContentionParams::paper();
        let job = JobSpec::synthetic(JobId(0), 2);
        let pl = JobPlacement::new(vec![c.global_gpu(ServerId(0), 0), c.global_gpu(ServerId(1), 0)]);
        let flat = rate_point(&params, &c, &job, &pl, Bottleneck::flat(4), false);
        let over = rate_point(
            &params,
            &c,
            &job,
            &pl,
            Bottleneck { p: 4, oversub: 2.0, link: None },
            false,
        );
        assert!(over.tau > flat.tau);
        assert!(over.inc <= flat.inc);
        assert_eq!(over.p, 4, "RatePoint reports the bottleneck ring count");
    }

    #[test]
    fn slots_until_done_edges() {
        assert_eq!(slots_until_done(100.0, 50.0), 2);
        assert_eq!(slots_until_done(101.0, 50.0), 3);
        assert_eq!(slots_until_done(0.5, 50.0), 1, "at least one slot");
        assert_eq!(slots_until_done(10.0, 0.0), u64::MAX, "stalled");
    }

    #[test]
    fn slots_until_done_saturates_on_non_finite_ratios() {
        // subnormal rate: the division overflows f64 → stalled sentinel
        assert_eq!(
            slots_until_done(1000.0, f64::MIN_POSITIVE),
            u64::MAX,
            "overflowing ratio must saturate, not wrap through the cast"
        );
        // infinite remaining work: sentinel regardless of the rate
        assert_eq!(slots_until_done(f64::INFINITY, 50.0), u64::MAX);
        // ∞ / ∞ = NaN: still the sentinel (NOT 1 via NaN.max(1.0))
        assert_eq!(slots_until_done(f64::INFINITY, f64::INFINITY), u64::MAX);
        // finite but > u64::MAX slots: saturates exactly
        assert_eq!(slots_until_done(1.0e30, 1.0e-9), u64::MAX);
        // a large-but-representable count still passes through
        assert_eq!(slots_until_done(1.0e12, 1.0), 1_000_000_000_000);
    }

    #[test]
    fn completed_iterations_guards_the_cast() {
        assert_eq!(completed_iterations(0.0), 0);
        assert_eq!(completed_iterations(41.9), 41, "truncates, never rounds up");
        assert_eq!(completed_iterations(-3.0), 0, "negative progress clamps");
        assert_eq!(completed_iterations(f64::NAN), 0, "NaN clamps, not UB-ish 0-cast");
        assert_eq!(completed_iterations(f64::INFINITY), u64::MAX, "∞ saturates");
        assert_eq!(completed_iterations(1.0e30), u64::MAX, "past u64::MAX saturates");
        assert_eq!(completed_iterations(1.0e12), 1_000_000_000_000);
    }

    #[test]
    fn restart_arithmetic_and_migration_guard() {
        assert_eq!(slots_until_done_with_restart(100.0, 50.0, 10), 12);
        assert_eq!(
            slots_until_done_with_restart(10.0, 0.0, 10),
            u64::MAX,
            "stalled stays saturated through the restart add"
        );
        // 100 iters: old rate 1/slot = 100 slots; new rate 4/slot = 25 + restart
        assert!(migration_pays(100.0, 1.0, 4.0, 10), "25 + 10 < 100");
        assert!(!migration_pays(100.0, 1.0, 4.0, 80), "25 + 80 > 100");
        assert!(!migration_pays(100.0, 1.0, 1.0, 0), "equal rates never strictly pay");
        assert!(
            migration_pays(100.0, 0.0, 1.0, 1_000),
            "unsticking a stalled job always pays"
        );
        assert!(!migration_pays(100.0, 1.0, 0.0, 0), "never migrate into a stall");
    }
}
