//! TOML configuration for experiments and the live coordinator.
//!
//! Everything a run needs is captured in one [`ExperimentConfig`] so runs
//! are fully reproducible from a config file + seed. Parsing uses the
//! in-tree TOML subset ([`crate::util::toml_lite`]); unknown keys are
//! rejected to catch typos early.

use crate::cluster::Cluster;
use crate::contention::ContentionParams;
use crate::faults::{FaultSpec, FaultTrace};
use crate::net::ContentionModel;
use crate::online::{AdmissionControl, MigrationControl, OnlineOptions};
use crate::sched::Policy;
use crate::topology::TopologySpec;
use crate::trace::TraceGenerator;
use crate::util::{TomlDoc, TomlValue};
use crate::Result;
use anyhow::bail;
use std::path::Path;

/// Cluster shape section.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of servers.
    pub servers: usize,
    /// Explicit per-server capacities; when empty, capacities are drawn
    /// u.a.r. from {4, 8, 16, 32} (paper §7) with `seed`.
    pub capacities: Vec<usize>,
    /// Inter-server bandwidth `b^e`.
    pub inter_bw: f64,
    /// Intra-server bandwidth `b^i`.
    pub intra_bw: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { servers: 20, capacities: Vec::new(), inter_bw: 1.0, intra_bw: 25.0 }
    }
}

/// Workload section.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Scale factor on the paper's 160-job mix (1.0 = paper).
    pub scale: f64,
    pub iters_min: u64,
    pub iters_max: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig { scale: 1.0, iters_min: 1000, iters_max: 6000 }
    }
}

/// Scheduler section.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: Policy,
    /// Fixed κ for SJF-BCO (None = sweep, Alg. 1).
    pub kappa: Option<usize>,
    /// λ for LBSGF.
    pub lambda: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { policy: Policy::SjfBco, kappa: None, lambda: 1.0 }
    }
}

/// Online overload-control section (`[online]`): θ-admission, queue cap
/// and completion-event migration for the non-clairvoyant scheduler.
/// Every default leaves the control inert (θ = ∞, unbounded queue,
/// migration off — the control-free loop bit for bit).
///
/// Keys: `theta` (float > 0; absent = ∞ / disabled), `queue_cap`
/// (int ≥ 1; absent = unbounded), `migrate` (bool, default false),
/// `max_moves` (int ≥ 1, default 2), `restart_slots` (int ≥ 0,
/// default 10), `stream` (bool, default false — run the O(active)-memory
/// streaming engine with sketch-backed percentiles instead of
/// materializing the trace), `stream_jobs` (int ≥ 1, default 10000 —
/// arrivals drawn from the lazy generator in streaming mode).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OnlineConfig {
    /// θ-threshold on the projected bottleneck effective degree
    /// (`count × oversub`); `f64::INFINITY` disables.
    pub theta: f64,
    /// Pending-queue hard cap; `None` = unbounded.
    pub queue_cap: Option<usize>,
    /// Enable completion-event preemption/migration.
    pub migrate: bool,
    /// Max re-placements per completion event (K).
    pub max_moves: usize,
    /// Checkpoint-restart penalty charged per move, in slots.
    pub restart_slots: u64,
    /// Drive the online comparison through the streaming engine
    /// ([`OnlineScheduler::run_streaming`](crate::online::OnlineScheduler::run_streaming)):
    /// arrivals come from a lazy generator, memory stays O(active jobs)
    /// and percentiles are sketch-backed. The CLI's `--stream` flag.
    pub stream: bool,
    /// Number of arrivals to draw in streaming mode (`--stream-jobs`).
    pub stream_jobs: usize,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        let m = MigrationControl::default();
        OnlineConfig {
            theta: f64::INFINITY,
            queue_cap: None,
            migrate: false,
            max_moves: m.max_moves,
            restart_slots: m.restart_slots,
            stream: false,
            stream_jobs: 10_000,
        }
    }
}

impl OnlineConfig {
    /// Materialise loop options (other [`OnlineOptions`] fields stay at
    /// their defaults).
    pub fn build_options(&self) -> OnlineOptions {
        OnlineOptions {
            admission: AdmissionControl {
                theta: self.theta,
                queue_cap: self.queue_cap.unwrap_or(usize::MAX),
            },
            migration: MigrationControl {
                enabled: self.migrate,
                max_moves: self.max_moves,
                restart_slots: self.restart_slots,
            },
            ..OnlineOptions::default()
        }
    }
}

/// Fault-injection section (`[faults]`): a deterministic fault trace
/// for the online scheduler (see [`crate::faults`]). Exactly one of
/// `spec` (a fault-spec string, e.g.
/// `"server:2000:200,link:1500:300:0.25"` — validated at parse time) or
/// `trace` (path to a saved [`FaultTrace`] JSON) may be set; an absent
/// section injects nothing — the fault-free loop bit for bit.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultsConfig {
    /// Fault-spec string ([`FaultSpec`] `FromStr` syntax).
    pub spec: Option<String>,
    /// Path to a saved fault-trace JSON ([`FaultTrace::save`]).
    pub trace: Option<String>,
}

impl FaultsConfig {
    /// Whether any fault input was requested.
    pub fn any_enabled(&self) -> bool {
        self.spec.is_some() || self.trace.is_some()
    }

    /// Resolve to a concrete trace: load the saved file, or generate
    /// from the spec against this cluster / horizon / run seed. `None`
    /// when the section is absent (or the spec is inert).
    pub fn build_trace(
        &self,
        cluster: &Cluster,
        horizon: u64,
        run_seed: u64,
    ) -> Result<Option<FaultTrace>> {
        if let Some(path) = &self.trace {
            return Ok(Some(FaultTrace::load(Path::new(path))?));
        }
        if let Some(s) = &self.spec {
            let spec: FaultSpec = s.parse()?;
            if spec.is_active() {
                return Ok(Some(spec.generate(cluster, horizon, run_seed)));
            }
        }
        Ok(None)
    }
}

/// Observability section (`[obs]`): output paths for the passive
/// recorders of [`crate::obs`]. Every key names a file to write at the
/// end of the run; an absent key leaves that recorder disarmed (absence
/// IS the disabled state — the default config runs the uninstrumented
/// loop bit for bit, see the passivity invariant in [`crate::obs`]).
///
/// Keys: `trace_out` (Chrome-trace JSON), `obs_json` (counter/histogram
/// registry dump), `explain` (decision-audit JSON; `-` renders the
/// human-readable report to stdout), `timeline` (per-link utilization
/// CSV), `ledger` (run-digest flight-recorder JSON — see
/// [`crate::obs::ledger`]), `ledger_events` (bool: keep a bounded ring
/// of per-interval event fingerprints so `rarsched diff` can pin the
/// first divergent event), `ledger_cadence` (int ≥ 1: checkpoint slot
/// cadence; default 1000, or the `--window` width when one is armed),
/// `profile` (bool: fold the trace spans into an in-terminal total/self
/// time profile at run end).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ObsConfig {
    pub trace_out: Option<String>,
    pub obs_json: Option<String>,
    pub explain: Option<String>,
    pub timeline: Option<String>,
    /// Run-digest ledger output path (`--ledger`).
    pub ledger: Option<String>,
    /// Record per-interval event-fingerprint rings (`--ledger-events`).
    pub ledger_events: bool,
    /// Checkpoint cadence in slots (`--ledger-cadence`); `None` picks the
    /// default (the window width under `--window`, else 1000 slots).
    pub ledger_cadence: Option<u64>,
    /// Print the in-terminal span profile at run end (`--profile`).
    pub profile: bool,
}

impl ObsConfig {
    /// Whether any recorder output was requested.
    pub fn any_enabled(&self) -> bool {
        self.trace_out.is_some()
            || self.obs_json.is_some()
            || self.explain.is_some()
            || self.timeline.is_some()
            || self.ledger.is_some()
            || self.profile
    }
}

/// Contention-model constants section (§4.1 / §7).
#[derive(Debug, Clone)]
pub struct ModelParamsConfig {
    pub xi1: f64,
    pub xi2: f64,
    pub alpha: f64,
    pub compute_speed: f64,
}

impl Default for ModelParamsConfig {
    fn default() -> Self {
        let p = ContentionParams::paper();
        ModelParamsConfig {
            xi1: p.xi1,
            xi2: p.xi2,
            alpha: p.alpha,
            compute_speed: p.compute_speed,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone, Default)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Scheduling horizon `T` in slots (paper: 1200 / 1500).
    pub horizon: Option<u64>,
    pub cluster: ClusterConfig,
    /// Network fabric above the servers (`[topology]` section; absent =
    /// the paper's flat 1-tier fabric).
    pub topology: TopologySpec,
    /// Contention model at the fabric's links (`[topology] model`;
    /// absent = the paper's effective-degree counting).
    pub contention: ContentionModel,
    pub workload: WorkloadConfig,
    pub scheduler: SchedulerConfig,
    pub model: ModelParamsConfig,
    /// Online overload controls (`[online]` section; absent = all off).
    pub online: OnlineConfig,
    /// Fault injection (`[faults]` section; absent = fault-free).
    pub faults: FaultsConfig,
    /// Observability outputs (`[obs]` section; absent = all disarmed).
    pub obs: ObsConfig,
}

impl ExperimentConfig {
    /// Paper §7 defaults (T = 1200).
    pub fn paper() -> Self {
        ExperimentConfig { horizon: Some(1200), ..Default::default() }
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = doc.get("", "seed") {
            cfg.seed = v.as_u64()?;
        }
        if let Some(v) = doc.get("", "horizon") {
            cfg.horizon = Some(v.as_u64()?);
        }
        if let Some(v) = doc.get("cluster", "servers") {
            cfg.cluster.servers = v.as_usize()?;
        }
        if let Some(v) = doc.get("cluster", "capacities") {
            cfg.cluster.capacities = v.as_int_array()?.iter().map(|&i| i as usize).collect();
        }
        if let Some(v) = doc.get("cluster", "inter_bw") {
            cfg.cluster.inter_bw = v.as_f64()?;
        }
        if let Some(v) = doc.get("cluster", "intra_bw") {
            cfg.cluster.intra_bw = v.as_f64()?;
        }
        if let Some(v) = doc.get("topology", "servers_per_rack") {
            let spr = v.as_usize()?;
            if spr == 0 {
                bail!("topology.servers_per_rack must be >= 1");
            }
            let racks_per_pod = match doc.get("topology", "racks_per_pod") {
                Some(r) => {
                    let rpp = r.as_usize()?;
                    if rpp == 0 {
                        bail!("topology.racks_per_pod must be >= 1");
                    }
                    Some(rpp)
                }
                None => None,
            };
            let gbps = |key: &str| -> Result<Option<f64>> {
                match doc.get("topology", key) {
                    None => Ok(None),
                    Some(v) => {
                        let g = v.as_f64()?;
                        if !(g > 0.0) {
                            bail!("topology.{key} must be positive Gbps, got {g}");
                        }
                        Ok(Some(g))
                    }
                }
            };
            let oversub_key = |key: &str| -> Result<Option<f64>> {
                match doc.get("topology", key) {
                    None => Ok(None),
                    Some(v) => {
                        let o = v.as_f64()?;
                        if !(o >= 1.0) {
                            bail!("topology.{key} must be >= 1, got {o}");
                        }
                        Ok(Some(o))
                    }
                }
            };
            let tor_gbps = gbps("tor_gbps")?;
            let pod_gbps = gbps("pod_gbps")?;
            let uplink_gbps = gbps("uplink_gbps")?;
            let oversub = oversub_key("oversub")?;
            let pod_oversub = oversub_key("pod_oversub")?;
            let speeds = tor_gbps.is_some() || pod_gbps.is_some() || uplink_gbps.is_some();
            let factors = oversub.is_some() || pod_oversub.is_some();
            if speeds && factors {
                bail!(
                    "topology: mixing absolute speeds (uplink_gbps/tor_gbps/pod_gbps) \
                     with oversubscription factors (oversub/pod_oversub) is ambiguous \
                     — use one form"
                );
            }
            // pod-tier keys without a pod tier would otherwise be dropped
            // silently, building a different fabric than configured
            if racks_per_pod.is_none() {
                if pod_gbps.is_some() {
                    bail!("topology.pod_gbps requires topology.racks_per_pod");
                }
                if pod_oversub.is_some() {
                    bail!("topology.pod_oversub requires topology.racks_per_pod");
                }
            }
            cfg.topology = match (racks_per_pod, speeds) {
                (None, false) => TopologySpec::Rack {
                    servers_per_rack: spr,
                    oversub: oversub.unwrap_or(1.0),
                },
                (None, true) => TopologySpec::RackGbps {
                    servers_per_rack: spr,
                    uplink_gbps: uplink_gbps
                        .unwrap_or(crate::net::DEFAULT_UPLINK_GBPS),
                    tor_gbps: tor_gbps
                        .ok_or_else(|| anyhow::anyhow!("topology.tor_gbps required"))?,
                },
                (Some(rpp), false) => TopologySpec::Pod {
                    racks_per_pod: rpp,
                    servers_per_rack: spr,
                    tor_oversub: oversub.unwrap_or(1.0),
                    pod_oversub: pod_oversub.unwrap_or(1.0),
                },
                (Some(rpp), true) => TopologySpec::PodGbps {
                    racks_per_pod: rpp,
                    servers_per_rack: spr,
                    uplink_gbps: uplink_gbps
                        .unwrap_or(crate::net::DEFAULT_UPLINK_GBPS),
                    tor_gbps: tor_gbps
                        .ok_or_else(|| anyhow::anyhow!("topology.tor_gbps required"))?,
                    pod_gbps: pod_gbps
                        .ok_or_else(|| anyhow::anyhow!("topology.pod_gbps required"))?,
                },
            };
        } else {
            // no rack tier: any fabric-shape key is an orphan (a typo'd
            // or half-written section must not silently build flat)
            for key in
                ["oversub", "pod_oversub", "uplink_gbps", "tor_gbps", "pod_gbps", "racks_per_pod"]
            {
                if doc.get("topology", key).is_some() {
                    bail!("topology.{key} requires topology.servers_per_rack");
                }
            }
        }
        if let Some(v) = doc.get("topology", "model") {
            cfg.contention = v.as_str()?.parse()?;
        }
        if let Some(v) = doc.get("online", "theta") {
            let theta = v.as_f64()?;
            if !(theta > 0.0) {
                bail!("online.theta must be positive, got {theta}");
            }
            cfg.online.theta = theta;
        }
        if let Some(v) = doc.get("online", "queue_cap") {
            let cap = v.as_usize()?;
            if cap == 0 {
                bail!("online.queue_cap must be >= 1 (omit the key to disable)");
            }
            cfg.online.queue_cap = Some(cap);
        }
        if let Some(v) = doc.get("online", "migrate") {
            cfg.online.migrate = v.as_bool()?;
        }
        if let Some(v) = doc.get("online", "max_moves") {
            let k = v.as_usize()?;
            if k == 0 {
                bail!("online.max_moves must be >= 1");
            }
            cfg.online.max_moves = k;
        }
        if let Some(v) = doc.get("online", "restart_slots") {
            cfg.online.restart_slots = v.as_u64()?;
        }
        if let Some(v) = doc.get("online", "stream") {
            cfg.online.stream = v.as_bool()?;
        }
        if let Some(v) = doc.get("online", "stream_jobs") {
            let n = v.as_usize()?;
            if n == 0 {
                bail!("online.stream_jobs must be >= 1");
            }
            cfg.online.stream_jobs = n;
        }
        if let Some(v) = doc.get("faults", "spec") {
            let s = v.as_str()?;
            // validate eagerly so a typo'd spec fails at load, not mid-run
            let spec: FaultSpec = s.parse()?;
            if !spec.is_active() {
                bail!(
                    "faults.spec must enable at least one fault class \
                     (omit the key to disable injection)"
                );
            }
            cfg.faults.spec = Some(s.to_string());
        }
        if let Some(v) = doc.get("faults", "trace") {
            let path = v.as_str()?;
            if path.is_empty() {
                bail!("faults.trace must be a non-empty path (omit the key to disable)");
            }
            cfg.faults.trace = Some(path.to_string());
        }
        if cfg.faults.spec.is_some() && cfg.faults.trace.is_some() {
            bail!("faults: spec and trace are mutually exclusive — use one");
        }
        for (key, slot) in [
            ("trace_out", &mut cfg.obs.trace_out),
            ("obs_json", &mut cfg.obs.obs_json),
            ("explain", &mut cfg.obs.explain),
            ("timeline", &mut cfg.obs.timeline),
            ("ledger", &mut cfg.obs.ledger),
        ] {
            if let Some(v) = doc.get("obs", key) {
                let path = v.as_str()?;
                if path.is_empty() {
                    bail!("obs.{key} must be a non-empty path (omit the key to disable)");
                }
                *slot = Some(path.to_string());
            }
        }
        if let Some(v) = doc.get("obs", "ledger_events") {
            cfg.obs.ledger_events = v.as_bool()?;
        }
        if let Some(v) = doc.get("obs", "ledger_cadence") {
            let n = v.as_u64()?;
            if n == 0 {
                bail!("obs.ledger_cadence must be >= 1 slot (omit the key for the default)");
            }
            cfg.obs.ledger_cadence = Some(n);
        }
        if let Some(v) = doc.get("obs", "profile") {
            cfg.obs.profile = v.as_bool()?;
        }
        if let Some(v) = doc.get("workload", "scale") {
            cfg.workload.scale = v.as_f64()?;
        }
        if let Some(v) = doc.get("workload", "iters_min") {
            cfg.workload.iters_min = v.as_u64()?;
        }
        if let Some(v) = doc.get("workload", "iters_max") {
            cfg.workload.iters_max = v.as_u64()?;
        }
        if let Some(v) = doc.get("scheduler", "policy") {
            cfg.scheduler.policy = v.as_str()?.parse()?;
        }
        if let Some(v) = doc.get("scheduler", "kappa") {
            let k = v.as_i64()?;
            cfg.scheduler.kappa = if k < 0 { None } else { Some(k as usize) };
        }
        if let Some(v) = doc.get("scheduler", "lambda") {
            cfg.scheduler.lambda = v.as_f64()?;
        }
        if let Some(v) = doc.get("model", "xi1") {
            cfg.model.xi1 = v.as_f64()?;
        }
        if let Some(v) = doc.get("model", "xi2") {
            cfg.model.xi2 = v.as_f64()?;
        }
        if let Some(v) = doc.get("model", "alpha") {
            cfg.model.alpha = v.as_f64()?;
        }
        if let Some(v) = doc.get("model", "compute_speed") {
            cfg.model.compute_speed = v.as_f64()?;
        }
        Ok(cfg)
    }

    pub fn to_toml_string(&self) -> String {
        let mut doc = TomlDoc::default();
        doc.set("", "seed", TomlValue::Int(self.seed as i64));
        if let Some(h) = self.horizon {
            doc.set("", "horizon", TomlValue::Int(h as i64));
        }
        doc.set("cluster", "servers", TomlValue::Int(self.cluster.servers as i64));
        if !self.cluster.capacities.is_empty() {
            doc.set(
                "cluster",
                "capacities",
                TomlValue::IntArray(self.cluster.capacities.iter().map(|&c| c as i64).collect()),
            );
        }
        doc.set("cluster", "inter_bw", TomlValue::Float(self.cluster.inter_bw));
        doc.set("cluster", "intra_bw", TomlValue::Float(self.cluster.intra_bw));
        match self.topology {
            TopologySpec::Flat => {}
            TopologySpec::Rack { servers_per_rack, oversub } => {
                doc.set(
                    "topology",
                    "servers_per_rack",
                    TomlValue::Int(servers_per_rack as i64),
                );
                doc.set("topology", "oversub", TomlValue::Float(oversub));
            }
            TopologySpec::RackGbps { servers_per_rack, uplink_gbps, tor_gbps } => {
                doc.set(
                    "topology",
                    "servers_per_rack",
                    TomlValue::Int(servers_per_rack as i64),
                );
                doc.set("topology", "uplink_gbps", TomlValue::Float(uplink_gbps));
                doc.set("topology", "tor_gbps", TomlValue::Float(tor_gbps));
            }
            TopologySpec::Pod { racks_per_pod, servers_per_rack, tor_oversub, pod_oversub } => {
                doc.set(
                    "topology",
                    "servers_per_rack",
                    TomlValue::Int(servers_per_rack as i64),
                );
                doc.set("topology", "racks_per_pod", TomlValue::Int(racks_per_pod as i64));
                doc.set("topology", "oversub", TomlValue::Float(tor_oversub));
                doc.set("topology", "pod_oversub", TomlValue::Float(pod_oversub));
            }
            TopologySpec::PodGbps {
                racks_per_pod,
                servers_per_rack,
                uplink_gbps,
                tor_gbps,
                pod_gbps,
            } => {
                doc.set(
                    "topology",
                    "servers_per_rack",
                    TomlValue::Int(servers_per_rack as i64),
                );
                doc.set("topology", "racks_per_pod", TomlValue::Int(racks_per_pod as i64));
                doc.set("topology", "uplink_gbps", TomlValue::Float(uplink_gbps));
                doc.set("topology", "tor_gbps", TomlValue::Float(tor_gbps));
                doc.set("topology", "pod_gbps", TomlValue::Float(pod_gbps));
            }
        }
        if self.contention != ContentionModel::default() {
            doc.set("topology", "model", TomlValue::Str(self.contention.name().into()));
        }
        // [online] — only non-default keys are emitted (θ = ∞ has no TOML
        // representation; absence IS the disabled state)
        if self.online.theta.is_finite() {
            doc.set("online", "theta", TomlValue::Float(self.online.theta));
        }
        if let Some(cap) = self.online.queue_cap {
            doc.set("online", "queue_cap", TomlValue::Int(cap as i64));
        }
        if self.online.migrate {
            doc.set("online", "migrate", TomlValue::Bool(true));
        }
        let mig_defaults = OnlineConfig::default();
        if self.online.max_moves != mig_defaults.max_moves {
            doc.set("online", "max_moves", TomlValue::Int(self.online.max_moves as i64));
        }
        if self.online.restart_slots != mig_defaults.restart_slots {
            doc.set(
                "online",
                "restart_slots",
                TomlValue::Int(self.online.restart_slots as i64),
            );
        }
        if self.online.stream {
            doc.set("online", "stream", TomlValue::Bool(true));
        }
        if self.online.stream_jobs != mig_defaults.stream_jobs {
            doc.set(
                "online",
                "stream_jobs",
                TomlValue::Int(self.online.stream_jobs as i64),
            );
        }
        // [faults] — only a requested input is emitted (absence IS the
        // fault-free state, like [online])
        if let Some(s) = &self.faults.spec {
            doc.set("faults", "spec", TomlValue::Str(s.clone()));
        }
        if let Some(p) = &self.faults.trace {
            doc.set("faults", "trace", TomlValue::Str(p.clone()));
        }
        // [obs] — only requested outputs are emitted (absence IS the
        // disarmed state, like [online])
        for (key, slot) in [
            ("trace_out", &self.obs.trace_out),
            ("obs_json", &self.obs.obs_json),
            ("explain", &self.obs.explain),
            ("timeline", &self.obs.timeline),
            ("ledger", &self.obs.ledger),
        ] {
            if let Some(path) = slot {
                doc.set("obs", key, TomlValue::Str(path.clone()));
            }
        }
        if self.obs.ledger_events {
            doc.set("obs", "ledger_events", TomlValue::Bool(true));
        }
        if let Some(n) = self.obs.ledger_cadence {
            doc.set("obs", "ledger_cadence", TomlValue::Int(n as i64));
        }
        if self.obs.profile {
            doc.set("obs", "profile", TomlValue::Bool(true));
        }
        doc.set("workload", "scale", TomlValue::Float(self.workload.scale));
        doc.set("workload", "iters_min", TomlValue::Int(self.workload.iters_min as i64));
        doc.set("workload", "iters_max", TomlValue::Int(self.workload.iters_max as i64));
        doc.set(
            "scheduler",
            "policy",
            TomlValue::Str(
                match self.scheduler.policy {
                    Policy::SjfBco => "sjf-bco",
                    Policy::FirstFit => "ff",
                    Policy::ListScheduling => "ls",
                    Policy::Random => "rand",
                    Policy::Gadget => "gadget",
                }
                .into(),
            ),
        );
        if let Some(k) = self.scheduler.kappa {
            doc.set("scheduler", "kappa", TomlValue::Int(k as i64));
        }
        doc.set("scheduler", "lambda", TomlValue::Float(self.scheduler.lambda));
        doc.set("model", "xi1", TomlValue::Float(self.model.xi1));
        doc.set("model", "xi2", TomlValue::Float(self.model.xi2));
        doc.set("model", "alpha", TomlValue::Float(self.model.alpha));
        doc.set("model", "compute_speed", TomlValue::Float(self.model.compute_speed));
        doc.to_string()
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_toml_str(&std::fs::read_to_string(path)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_toml_string())?;
        Ok(())
    }

    /// Materialise the cluster (including its network fabric).
    pub fn build_cluster(&self) -> Cluster {
        let c = if !self.cluster.capacities.is_empty() {
            Cluster::new(&self.cluster.capacities, self.cluster.inter_bw, self.cluster.intra_bw)
        } else {
            // random capacities, seeded; then override bandwidths
            let mut c = Cluster::random(self.cluster.servers, self.seed);
            c.inter_bw = self.cluster.inter_bw;
            c.intra_bw = self.cluster.intra_bw;
            c
        };
        let n = c.num_servers();
        c.with_topology(self.topology.build(n).with_model(self.contention))
    }

    /// Materialise the trace generator.
    pub fn build_generator(&self) -> TraceGenerator {
        let mut g = if (self.workload.scale - 1.0).abs() < 1e-9 {
            TraceGenerator::paper()
        } else {
            TraceGenerator::paper_scaled(self.workload.scale)
        };
        g.iters_min = self.workload.iters_min;
        g.iters_max = self.workload.iters_max;
        g
    }

    /// Materialise the contention parameters.
    pub fn build_params(&self) -> ContentionParams {
        ContentionParams {
            xi1: self.model.xi1,
            xi2: self.model.xi2,
            alpha: self.model.alpha,
            compute_speed: self.model.compute_speed,
        }
    }

    /// Horizon with the paper default.
    pub fn horizon(&self) -> u64 {
        self.horizon.unwrap_or(1200)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let cfg = ExperimentConfig::paper();
        assert_eq!(cfg.horizon(), 1200);
        let c = cfg.build_cluster();
        assert_eq!(c.num_servers(), 20);
        assert_eq!(cfg.build_generator().num_jobs(), 160);
        let p = cfg.build_params();
        assert_eq!(p, ContentionParams::paper());
    }

    #[test]
    fn toml_roundtrip() {
        let mut cfg = ExperimentConfig::paper();
        cfg.scheduler.kappa = Some(4);
        cfg.scheduler.policy = Policy::ListScheduling;
        let dir = crate::util::temp_dir("rarsched-config").unwrap();
        let path = dir.join("exp.toml");
        cfg.save(&path).unwrap();
        let back = ExperimentConfig::load(&path).unwrap();
        assert_eq!(back.horizon(), 1200);
        assert_eq!(back.cluster.servers, 20);
        assert_eq!(back.scheduler.kappa, Some(4));
        assert_eq!(back.scheduler.policy, Policy::ListScheduling);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            seed = 9
            [cluster]
            servers = 10
            "#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.cluster.servers, 10);
        assert_eq!(cfg.cluster.intra_bw, 25.0);
        assert_eq!(cfg.workload.scale, 1.0);
        assert_eq!(cfg.scheduler.policy, Policy::SjfBco);
    }

    #[test]
    fn explicit_capacities_win() {
        let mut cfg = ExperimentConfig::paper();
        cfg.cluster.capacities = vec![4, 4];
        let c = cfg.build_cluster();
        assert_eq!(c.num_servers(), 2);
        assert_eq!(c.num_gpus(), 8);
    }

    #[test]
    fn bad_policy_rejected() {
        let r = ExperimentConfig::from_toml_str("[scheduler]\npolicy = \"bogus\"\n");
        assert!(r.is_err());
    }

    #[test]
    fn topology_section_roundtrips_and_builds() {
        let mut cfg = ExperimentConfig::paper();
        cfg.topology = TopologySpec::Rack { servers_per_rack: 4, oversub: 2.0 };
        let text = cfg.to_toml_string();
        let back = ExperimentConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.topology, cfg.topology);
        let c = back.build_cluster();
        assert!(c.topology().has_racks());
        assert_eq!(c.topology().num_racks(), 5, "20 servers in racks of 4");
        // default stays flat
        let flat = ExperimentConfig::paper().build_cluster();
        assert!(!flat.topology().has_racks());
    }

    #[test]
    fn online_section_defaults_roundtrip_and_build() {
        // absent section = every control inert
        let cfg = ExperimentConfig::paper();
        assert_eq!(cfg.online, OnlineConfig::default());
        let opts = cfg.online.build_options();
        assert!(!opts.admission.is_active());
        assert!(!opts.migration.enabled);
        // and no [online] keys are emitted for the defaults
        assert!(!cfg.to_toml_string().contains("[online]"));

        // a fully-specified section roundtrips
        let mut cfg = ExperimentConfig::paper();
        cfg.online = OnlineConfig {
            theta: 6.5,
            queue_cap: Some(32),
            migrate: true,
            max_moves: 3,
            restart_slots: 25,
            stream: true,
            stream_jobs: 250_000,
        };
        let back = ExperimentConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.online, cfg.online);
        let opts = back.online.build_options();
        assert!(opts.admission.is_active());
        assert_eq!(opts.admission.theta, 6.5);
        assert_eq!(opts.admission.queue_cap, 32);
        assert!(opts.migration.enabled);
        assert_eq!(opts.migration.max_moves, 3);
        assert_eq!(opts.migration.restart_slots, 25);
    }

    #[test]
    fn bad_online_section_rejected() {
        assert!(ExperimentConfig::from_toml_str("[online]\ntheta = 0.0\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[online]\ntheta = -3.0\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[online]\nqueue_cap = 0\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[online]\nmax_moves = 0\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[online]\nstream_jobs = 0\n").is_err());
        // integers are accepted where floats are expected (toml_lite rule)
        let cfg = ExperimentConfig::from_toml_str("[online]\ntheta = 4\n").unwrap();
        assert_eq!(cfg.online.theta, 4.0);
    }

    #[test]
    fn obs_section_defaults_roundtrip_and_reject_empty_paths() {
        // absent section = nothing armed, no keys emitted
        let cfg = ExperimentConfig::paper();
        assert_eq!(cfg.obs, ObsConfig::default());
        assert!(!cfg.obs.any_enabled());
        assert!(!cfg.to_toml_string().contains("[obs]"));

        // a fully-specified section roundtrips
        let mut cfg = ExperimentConfig::paper();
        cfg.obs = ObsConfig {
            trace_out: Some("trace.json".into()),
            obs_json: Some("obs.json".into()),
            explain: Some("-".into()),
            timeline: Some("links.csv".into()),
            ledger: Some("ledger.json".into()),
            ledger_events: true,
            ledger_cadence: Some(500),
            profile: true,
        };
        assert!(cfg.obs.any_enabled());
        let back = ExperimentConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.obs, cfg.obs);

        // a partial section leaves the rest disarmed
        let cfg =
            ExperimentConfig::from_toml_str("[obs]\ntrace_out = \"t.json\"\n").unwrap();
        assert_eq!(cfg.obs.trace_out.as_deref(), Some("t.json"));
        assert_eq!(cfg.obs.obs_json, None);
        assert_eq!(cfg.obs.ledger, None);
        assert!(!cfg.obs.ledger_events && !cfg.obs.profile);
        assert_eq!(cfg.obs.ledger_cadence, None);

        // empty paths are typos, not "disabled"
        assert!(ExperimentConfig::from_toml_str("[obs]\ntrace_out = \"\"\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[obs]\nexplain = \"\"\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[obs]\nledger = \"\"\n").is_err());
        // a zero cadence is a typo, not "disabled"
        assert!(ExperimentConfig::from_toml_str("[obs]\nledger_cadence = 0\n").is_err());
        // the ledger flags roundtrip standalone too
        let cfg = ExperimentConfig::from_toml_str(
            "[obs]\nledger = \"l.json\"\nledger_events = true\nledger_cadence = 64\n",
        )
        .unwrap();
        assert_eq!(cfg.obs.ledger.as_deref(), Some("l.json"));
        assert!(cfg.obs.ledger_events);
        assert_eq!(cfg.obs.ledger_cadence, Some(64));
    }

    #[test]
    fn faults_section_defaults_roundtrip_and_build() {
        // absent section = fault-free, no keys emitted
        let cfg = ExperimentConfig::paper();
        assert_eq!(cfg.faults, FaultsConfig::default());
        assert!(!cfg.faults.any_enabled());
        assert!(!cfg.to_toml_string().contains("[faults]"));
        let c = cfg.build_cluster();
        assert!(cfg.faults.build_trace(&c, 10_000, cfg.seed).unwrap().is_none());

        // a spec roundtrips and resolves to a deterministic trace
        let mut cfg = ExperimentConfig::paper();
        cfg.faults.spec = Some("server:2000:200,seed:7".into());
        let back = ExperimentConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.faults, cfg.faults);
        let c = back.build_cluster();
        let t1 = back.faults.build_trace(&c, 10_000, back.seed).unwrap().unwrap();
        let t2 = back.faults.build_trace(&c, 10_000, back.seed).unwrap().unwrap();
        assert_eq!(t1.events, t2.events);
        assert!(!t1.is_empty());
    }

    #[test]
    fn bad_faults_section_rejected() {
        // a typo'd spec fails at load, not mid-run
        assert!(ExperimentConfig::from_toml_str("[faults]\nspec = \"quux:1\"\n").is_err());
        // an inert spec is a typo, not "disabled"
        assert!(ExperimentConfig::from_toml_str("[faults]\nspec = \"none\"\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[faults]\ntrace = \"\"\n").is_err());
        // spec and trace are mutually exclusive
        assert!(ExperimentConfig::from_toml_str(
            "[faults]\nspec = \"server:2000:200\"\ntrace = \"t.json\"\n"
        )
        .is_err());
    }

    #[test]
    fn bad_topology_rejected() {
        assert!(ExperimentConfig::from_toml_str("[topology]\nservers_per_rack = 0\n").is_err());
        assert!(ExperimentConfig::from_toml_str(
            "[topology]\nservers_per_rack = 4\noversub = 0.5\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_str("[topology]\noversub = 2.0\n").is_err());
        // mixing speed and factor forms is ambiguous
        assert!(ExperimentConfig::from_toml_str(
            "[topology]\nservers_per_rack = 4\noversub = 2.0\ntor_gbps = 40.0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_str(
            "[topology]\nservers_per_rack = 4\ntor_gbps = 0.0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_str(
            "[topology]\nservers_per_rack = 4\nracks_per_pod = 0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_str("[topology]\nmodel = \"bogus\"\n").is_err());
        // orphan keys must be rejected, not silently dropped
        assert!(
            ExperimentConfig::from_toml_str(
                "[topology]\nservers_per_rack = 4\ntor_gbps = 40.0\npod_gbps = 160.0\n"
            )
            .is_err(),
            "pod_gbps without racks_per_pod must not silently build a 2-tier fabric"
        );
        assert!(ExperimentConfig::from_toml_str(
            "[topology]\nservers_per_rack = 4\npod_oversub = 2.0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_str("[topology]\nracks_per_pod = 2\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[topology]\ntor_gbps = 40.0\n").is_err());
    }

    #[test]
    fn gbps_pod_and_model_sections_roundtrip_and_build() {
        // absolute-speed rack form + the share model
        let mut cfg = ExperimentConfig::paper();
        cfg.topology = TopologySpec::RackGbps {
            servers_per_rack: 4,
            uplink_gbps: 25.0,
            tor_gbps: 100.0,
        };
        cfg.contention = ContentionModel::MaxMinFair;
        let back = ExperimentConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.topology, cfg.topology);
        assert_eq!(back.contention, ContentionModel::MaxMinFair);
        let c = back.build_cluster();
        assert_eq!(c.topology().model(), ContentionModel::MaxMinFair);
        assert_eq!(c.topology().link_gbps(c.topology().rack_uplink(0)), 100.0);

        // 3-tier oversub form
        let mut cfg = ExperimentConfig::paper();
        cfg.topology = TopologySpec::Pod {
            racks_per_pod: 2,
            servers_per_rack: 2,
            tor_oversub: 2.0,
            pod_oversub: 4.0,
        };
        let back = ExperimentConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.topology, cfg.topology);
        let c = back.build_cluster();
        assert!(c.topology().has_pods());
        assert_eq!(c.topology().num_pods(), 5, "10 racks of 2 in pods of 2");

        // 3-tier speed form
        let mut cfg = ExperimentConfig::paper();
        cfg.topology = TopologySpec::PodGbps {
            racks_per_pod: 2,
            servers_per_rack: 2,
            uplink_gbps: 10.0,
            tor_gbps: 20.0,
            pod_gbps: 40.0,
        };
        let back = ExperimentConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.topology, cfg.topology);

        // defaults: no [topology] section is emitted at all (flat fabric,
        // degree model — absence IS the default state)
        let cfg = ExperimentConfig::paper();
        assert_eq!(cfg.contention, ContentionModel::EffectiveDegree);
        assert!(!cfg.to_toml_string().contains("[topology]"));
    }
}
