//! Divergence forensics: align two run ledgers and pin the first
//! divergent checkpoint, stream and event.
//!
//! Consumes the JSON documents [`ledger`](crate::obs::ledger) writes and
//! backs the `rarsched diff <a.json> <b.json>` subcommand. The
//! comparison walks checkpoints in lockstep: the first ordinal where any
//! recorded field differs (slot, queue census, free slots, link-count
//! hash, counter-delta hash, or a per-stream digest) is *the* divergence
//! — everything before it is proven bit-identical by the rolling
//! hashes. When both runs were recorded with `--ledger-events`, the
//! divergent interval's fingerprint rings narrow the answer further to
//! the first divergent item ("slot 412, job 37, events/start"), and if
//! either run also logged `--explain` decision audits the report
//! cross-links them, since the audit records around the pinned slot are
//! where the *why* lives.
//!
//! Output is human text ([`DiffReport::render`]) and streamed JSON
//! ([`DiffReport::write_json`] via [`JsonEmitter`]). A clean report
//! (zero divergence) is the equivalence-ladder success case and what
//! `scripts/verify.sh` gates its mirrored-fabric smoke run on.

use crate::util::{Json, JsonEmitter};
use anyhow::{bail, Context};
use std::fmt::Write as _;
use std::path::Path;

/// Per-stream digest as read back from a ledger file (hashes stay hex
/// strings — they are compared, never re-folded).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamSig {
    pub name: String,
    pub count: u64,
    pub hash: String,
}

/// One item fingerprint from a checkpoint's `recent` ring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FpDoc {
    pub at: u64,
    /// Trace job id (`-1` is the fabric-event sentinel).
    pub job: i64,
    pub stream: String,
    pub tag: u64,
    pub fp: String,
}

impl FpDoc {
    /// Human label: "slot 412, job 37, events/start".
    pub fn describe(&self) -> String {
        const EVENT_KINDS: [&str; 8] = [
            "arrival",
            "start",
            "completion",
            "rejected",
            "migrated",
            "failed",
            "recovered",
            "degraded",
        ];
        const FAULT_KINDS: [&str; 5] =
            ["server-crash", "server-recover", "gpu-fail", "link-degrade", "link-restore"];
        let tag = match (self.stream.as_str(), self.tag) {
            ("events", t) if (t as usize) < EVENT_KINDS.len() => {
                format!("/{}", EVENT_KINDS[t as usize])
            }
            ("faults", t) if (t as usize) < FAULT_KINDS.len() => {
                format!("/{}", FAULT_KINDS[t as usize])
            }
            _ => String::new(),
        };
        let job = if self.job < 0 { "fabric".to_string() } else { format!("job {}", self.job) };
        format!("slot {}, {}, {}{}", self.at, job, self.stream, tag)
    }
}

/// One checkpoint as read back from a ledger file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointDoc {
    pub seq: u64,
    pub at: u64,
    pub pending: u64,
    pub running: u64,
    pub recovering: u64,
    pub free_gpus: u64,
    pub links_hash: String,
    pub counters_hash: String,
    pub streams: Vec<StreamSig>,
    pub recent: Vec<FpDoc>,
    pub dropped: u64,
}

/// A parsed ledger document.
#[derive(Debug, Clone, PartialEq)]
pub struct LedgerDoc {
    pub cadence: u64,
    pub events: bool,
    /// `--explain` path recorded at arm time, if any.
    pub explain: Option<String>,
    /// Final whole-run per-stream digests.
    pub streams: Vec<StreamSig>,
    pub checkpoints: Vec<CheckpointDoc>,
    /// Config digest from the stamped manifest, if present.
    pub config_digest: Option<String>,
}

fn parse_sigs(v: &Json) -> crate::Result<Vec<StreamSig>> {
    let Json::Obj(pairs) = v else { bail!("stream digests must be an object") };
    pairs
        .iter()
        .map(|(name, sig)| {
            Ok(StreamSig {
                name: name.clone(),
                count: sig.req("count")?.as_u64().context("stream count")?,
                hash: sig.req("hash")?.as_str().context("stream hash")?.to_string(),
            })
        })
        .collect()
}

fn parse_fp(fp: &Json) -> crate::Result<FpDoc> {
    let job = fp.req("job")?.as_f64()?;
    if !job.is_finite() {
        bail!("non-finite job id in event fingerprint");
    }
    Ok(FpDoc {
        at: fp.req("at")?.as_u64()?,
        job: job as i64,
        stream: fp.req("stream")?.as_str()?.to_string(),
        tag: fp.req("tag")?.as_u64()?,
        fp: fp.req("fp")?.as_str()?.to_string(),
    })
}

fn parse_checkpoint(cp: &Json) -> crate::Result<CheckpointDoc> {
    let recent = match cp.get("recent") {
        Some(arr) => arr
            .as_arr()
            .context("recent must be an array")?
            .iter()
            .map(parse_fp)
            .collect::<crate::Result<Vec<_>>>()?,
        None => Vec::new(),
    };
    Ok(CheckpointDoc {
        seq: cp.req("seq")?.as_u64()?,
        at: cp.req("at")?.as_u64()?,
        pending: cp.req("pending")?.as_u64()?,
        running: cp.req("running")?.as_u64()?,
        recovering: cp.req("recovering")?.as_u64()?,
        free_gpus: cp.req("free_gpus")?.as_u64()?,
        links_hash: cp.req("links_hash")?.as_str()?.to_string(),
        counters_hash: cp.req("counters_hash")?.as_str()?.to_string(),
        streams: parse_sigs(cp.req("streams")?)?,
        recent,
        dropped: cp.get("dropped").map(Json::as_u64).transpose()?.unwrap_or(0),
    })
}

/// Parse a ledger document (shared by [`load`] and the writer's
/// roundtrip test).
pub fn parse(doc: &Json) -> crate::Result<LedgerDoc> {
    let version = doc.req("version")?.as_u64().context("ledger version")?;
    if version != 1 {
        bail!("unsupported ledger version {version} (expected 1)");
    }
    let checkpoints = doc
        .req("checkpoints")?
        .as_arr()
        .context("checkpoints must be an array")?
        .iter()
        .enumerate()
        .map(|(i, cp)| parse_checkpoint(cp).with_context(|| format!("checkpoint {i}")))
        .collect::<crate::Result<Vec<_>>>()?;
    Ok(LedgerDoc {
        cadence: doc.req("cadence")?.as_u64().context("cadence")?,
        events: doc.req("events")?.as_bool().context("events flag")?,
        explain: doc.get("explain").map(Json::as_str).transpose()?.map(str::to_string),
        streams: parse_sigs(doc.req("streams")?)?,
        checkpoints,
        config_digest: doc
            .get("manifest")
            .and_then(|m| m.get("config_digest"))
            .and_then(|d| d.as_str().ok())
            .map(str::to_string),
    })
}

/// Load and parse a ledger file, with clean errors for missing,
/// truncated or corrupt documents.
pub fn load(path: &Path) -> crate::Result<LedgerDoc> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading ledger {}", path.display()))?;
    let doc = Json::parse(&text)
        .with_context(|| format!("ledger {} is not valid JSON (truncated?)", path.display()))?;
    parse(&doc).with_context(|| format!("ledger {} is not a ledger document", path.display()))
}

/// The first divergent item inside a divergent checkpoint interval.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EventDivergence {
    /// Index into the interval's fingerprint ring.
    pub index: usize,
    /// Side A's item at that index (`None` past its ring).
    pub a: Option<FpDoc>,
    /// Side B's item at that index.
    pub b: Option<FpDoc>,
    /// True when the rings match entirely but overflowed
    /// ([`ledger::RING_CAP`](crate::obs::ledger::RING_CAP)) — the first
    /// divergent item lies beyond what was recorded.
    pub truncated: bool,
}

/// Where two ledgers first part ways.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Divergence {
    /// Ordinal of the first divergent checkpoint (== count of proven-
    /// identical checkpoints before it).
    pub seq: u64,
    /// Side A's slot for that checkpoint (`None` when A ran out).
    pub at_a: Option<u64>,
    pub at_b: Option<u64>,
    /// Divergent field/stream labels, e.g. `["events", "pending"]`;
    /// `["checkpoint-count"]` when one run simply recorded more, and
    /// `final:`-prefixed stream names for a tail-only divergence.
    pub fields: Vec<String>,
    /// First divergent item, when both runs recorded event rings.
    pub first_event: Option<EventDivergence>,
}

/// Full comparison outcome for two ledgers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DiffReport {
    /// Set when the ledgers were recorded at different cadences (their
    /// checkpoints don't align; only final stream digests are compared).
    pub cadence_mismatch: Option<(u64, u64)>,
    /// Checkpoints proven bit-identical before the divergence (all of
    /// them on a clean diff).
    pub checkpoints_compared: usize,
    pub divergence: Option<Divergence>,
    /// Whether the stamped config digests match (informational — runs
    /// being diffed usually differ in configuration by design).
    pub configs_match: Option<bool>,
    /// `--explain` paths recorded by each side, for cross-linking.
    pub explain: (Option<String>, Option<String>),
}

impl DiffReport {
    /// Zero divergence: every aligned checkpoint and every final stream
    /// digest matched.
    pub fn clean(&self) -> bool {
        self.divergence.is_none() && self.cadence_mismatch.is_none()
    }
}

fn sig_fields(a: &[StreamSig], b: &[StreamSig], prefix: &str, out: &mut Vec<String>) {
    for sa in a {
        match b.iter().find(|sb| sb.name == sa.name) {
            Some(sb) => {
                if sa.count != sb.count || sa.hash != sb.hash {
                    out.push(format!("{prefix}{}", sa.name));
                }
            }
            None => out.push(format!("{prefix}{}", sa.name)),
        }
    }
    for sb in b {
        if !a.iter().any(|sa| sa.name == sb.name) {
            out.push(format!("{prefix}{}", sb.name));
        }
    }
}

fn first_event(a: &CheckpointDoc, b: &CheckpointDoc) -> Option<EventDivergence> {
    let n = a.recent.len().max(b.recent.len());
    for i in 0..n {
        let (fa, fb) = (a.recent.get(i), b.recent.get(i));
        if fa != fb {
            return Some(EventDivergence {
                index: i,
                a: fa.cloned(),
                b: fb.cloned(),
                truncated: false,
            });
        }
    }
    // rings identical: the divergence happened past the recorded prefix
    (a.dropped > 0 || b.dropped > 0).then_some(EventDivergence {
        index: a.recent.len(),
        a: None,
        b: None,
        truncated: true,
    })
}

/// Align two ledgers and pin the first divergence (if any).
pub fn diff(a: &LedgerDoc, b: &LedgerDoc) -> DiffReport {
    let configs_match = match (&a.config_digest, &b.config_digest) {
        (Some(da), Some(db)) => Some(da == db),
        _ => None,
    };
    let explain = (a.explain.clone(), b.explain.clone());
    if a.cadence != b.cadence {
        return DiffReport {
            cadence_mismatch: Some((a.cadence, b.cadence)),
            checkpoints_compared: 0,
            divergence: None,
            configs_match,
            explain,
        };
    }
    let mut divergence = None;
    let common = a.checkpoints.len().min(b.checkpoints.len());
    for i in 0..common {
        let (ca, cb) = (&a.checkpoints[i], &b.checkpoints[i]);
        let mut fields = Vec::new();
        sig_fields(&ca.streams, &cb.streams, "", &mut fields);
        for (label, va, vb) in [
            ("at", ca.at, cb.at),
            ("pending", ca.pending, cb.pending),
            ("running", ca.running, cb.running),
            ("recovering", ca.recovering, cb.recovering),
            ("free_gpus", ca.free_gpus, cb.free_gpus),
        ] {
            if va != vb {
                fields.push(label.to_string());
            }
        }
        if ca.links_hash != cb.links_hash {
            fields.push("links".to_string());
        }
        if ca.counters_hash != cb.counters_hash {
            fields.push("counters".to_string());
        }
        if !fields.is_empty() {
            divergence = Some(Divergence {
                seq: ca.seq,
                at_a: Some(ca.at),
                at_b: Some(cb.at),
                fields,
                first_event: first_event(ca, cb),
            });
            break;
        }
    }
    if divergence.is_none() && a.checkpoints.len() != b.checkpoints.len() {
        let (longer, at_a, at_b) = if a.checkpoints.len() > b.checkpoints.len() {
            (&a.checkpoints[common], Some(a.checkpoints[common].at), None)
        } else {
            (&b.checkpoints[common], None, Some(b.checkpoints[common].at))
        };
        divergence = Some(Divergence {
            seq: longer.seq,
            at_a,
            at_b,
            fields: vec!["checkpoint-count".to_string()],
            first_event: None,
        });
    }
    if divergence.is_none() {
        // tail: runs agree at every checkpoint but end differently
        let mut fields = Vec::new();
        sig_fields(&a.streams, &b.streams, "final:", &mut fields);
        if !fields.is_empty() {
            divergence = Some(Divergence {
                seq: common as u64,
                at_a: None,
                at_b: None,
                fields,
                first_event: None,
            });
        }
    }
    let compared = match &divergence {
        Some(d) => (d.seq as usize).min(common),
        None => common,
    };
    DiffReport {
        cadence_mismatch: None,
        checkpoints_compared: compared,
        divergence,
        configs_match,
        explain,
    }
}

impl DiffReport {
    /// Human-readable report; `a` and `b` label the two sides (usually
    /// the ledger file paths).
    pub fn render(&self, a: &str, b: &str) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "ledger diff: {a} vs {b}");
        if let Some(same) = self.configs_match {
            let note = if same { "match" } else { "differ (expected for cross-config runs)" };
            let _ = writeln!(out, "  config digests {note}");
        }
        if let Some((ca, cb)) = self.cadence_mismatch {
            let _ = writeln!(
                out,
                "  cadence mismatch: {ca} vs {cb} slots — checkpoints do not align;\n  \
                 re-record both runs with the same --ledger cadence to compare"
            );
            return out;
        }
        let _ = writeln!(out, "  {} checkpoint(s) bit-identical", self.checkpoints_compared);
        let Some(d) = &self.divergence else {
            let _ = writeln!(out, "  zero divergence: every stream digest matches");
            return out;
        };
        let slot = |at: Option<u64>| at.map_or("-".to_string(), |t| t.to_string());
        let _ = writeln!(
            out,
            "  FIRST DIVERGENCE at checkpoint {} (slot {} vs {}): {}",
            d.seq,
            slot(d.at_a),
            slot(d.at_b),
            d.fields.join(", ")
        );
        match &d.first_event {
            Some(ev) if ev.truncated => {
                let _ = writeln!(
                    out,
                    "    recorded event rings match — the first divergent item lies past the \
                     ring capacity; lower the --ledger cadence and re-record to pin it"
                );
            }
            Some(ev) => {
                let side = |fp: &Option<FpDoc>| {
                    fp.as_ref().map_or("(stream ended)".to_string(), |f| {
                        format!("{} (fp {})", f.describe(), f.fp)
                    })
                };
                let _ = writeln!(out, "    first divergent event (interval item {}):", ev.index);
                let _ = writeln!(out, "      a: {}", side(&ev.a));
                let _ = writeln!(out, "      b: {}", side(&ev.b));
            }
            None => {
                let _ = writeln!(
                    out,
                    "    (no event rings recorded — re-run both sides with --ledger-events to \
                     pin the first divergent event)"
                );
            }
        }
        match &self.explain {
            (Some(ea), Some(eb)) => {
                let _ = writeln!(
                    out,
                    "    decision audit: compare records near the pinned slot in {ea} vs {eb}"
                );
            }
            (Some(e), None) | (None, Some(e)) => {
                let _ = writeln!(
                    out,
                    "    decision audit: one side logged --explain ({e}); re-run the other \
                     with --explain to compare the why"
                );
            }
            (None, None) => {}
        }
        out
    }

    /// Stream the report as JSON (the machine-readable twin of
    /// [`render`](Self::render)).
    pub fn write_json<W: std::io::Write>(
        &self,
        emitter: &mut JsonEmitter<W>,
    ) -> std::io::Result<()> {
        fn fp<W: std::io::Write>(
            e: &mut JsonEmitter<W>,
            doc: &Option<FpDoc>,
        ) -> std::io::Result<()> {
            match doc {
                None => e.null(),
                Some(f) => {
                    e.begin_obj()?;
                    e.key("at")?;
                    e.uint(f.at)?;
                    e.key("job")?;
                    e.num(f.job as f64)?;
                    e.key("stream")?;
                    e.str(&f.stream)?;
                    e.key("tag")?;
                    e.uint(f.tag)?;
                    e.key("fp")?;
                    e.str(&f.fp)?;
                    e.key("describe")?;
                    e.str(&f.describe())?;
                    e.end_obj()
                }
            }
        }
        let e = emitter;
        e.begin_obj()?;
        e.key("clean")?;
        e.bool(self.clean())?;
        e.key("checkpoints_compared")?;
        e.uint(self.checkpoints_compared as u64)?;
        if let Some((ca, cb)) = self.cadence_mismatch {
            e.key("cadence_mismatch")?;
            e.begin_arr()?;
            e.uint(ca)?;
            e.uint(cb)?;
            e.end_arr()?;
        }
        if let Some(same) = self.configs_match {
            e.key("configs_match")?;
            e.bool(same)?;
        }
        e.key("divergence")?;
        match &self.divergence {
            None => e.null()?,
            Some(d) => {
                e.begin_obj()?;
                e.key("seq")?;
                e.uint(d.seq)?;
                e.key("at_a")?;
                match d.at_a {
                    Some(t) => e.uint(t)?,
                    None => e.null()?,
                }
                e.key("at_b")?;
                match d.at_b {
                    Some(t) => e.uint(t)?,
                    None => e.null()?,
                }
                e.key("fields")?;
                e.begin_arr()?;
                for f in &d.fields {
                    e.str(f)?;
                }
                e.end_arr()?;
                e.key("first_event")?;
                match &d.first_event {
                    None => e.null()?,
                    Some(ev) => {
                        e.begin_obj()?;
                        e.key("index")?;
                        e.uint(ev.index as u64)?;
                        e.key("truncated")?;
                        e.bool(ev.truncated)?;
                        e.key("a")?;
                        fp(e, &ev.a)?;
                        e.key("b")?;
                        fp(e, &ev.b)?;
                        e.end_obj()?;
                    }
                }
                e.end_obj()?;
            }
        }
        e.key("explain")?;
        e.begin_arr()?;
        for side in [&self.explain.0, &self.explain.1] {
            match side {
                Some(p) => e.str(p)?,
                None => e.null()?,
            }
        }
        e.end_arr()?;
        e.end_obj()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(name: &str, count: u64, hash: &str) -> StreamSig {
        StreamSig { name: name.to_string(), count, hash: hash.to_string() }
    }

    fn sigs(hash: &str) -> Vec<StreamSig> {
        ["events", "records", "rejections", "migrations", "faults"]
            .iter()
            .map(|n| sig(n, 3, hash))
            .collect()
    }

    fn cp(seq: u64, at: u64, hash: &str) -> CheckpointDoc {
        CheckpointDoc {
            seq,
            at,
            pending: 1,
            running: 2,
            recovering: 0,
            free_gpus: 4,
            links_hash: "aa".to_string(),
            counters_hash: "bb".to_string(),
            streams: sigs(hash),
            recent: Vec::new(),
            dropped: 0,
        }
    }

    fn doc(hashes: &[&str]) -> LedgerDoc {
        LedgerDoc {
            cadence: 100,
            events: false,
            explain: None,
            streams: sigs(hashes.last().copied().unwrap_or("00")),
            checkpoints: hashes
                .iter()
                .enumerate()
                .map(|(i, h)| cp(i as u64, (i as u64 + 1) * 100, h))
                .collect(),
            config_digest: Some("cfg".to_string()),
        }
    }

    #[test]
    fn identical_ledgers_are_clean() {
        let a = doc(&["11", "22", "33"]);
        let report = diff(&a, &a.clone());
        assert!(report.clean());
        assert_eq!(report.checkpoints_compared, 3);
        assert!(report.render("a.json", "b.json").contains("zero divergence"));
    }

    #[test]
    fn first_divergent_checkpoint_and_stream_are_pinned() {
        let a = doc(&["11", "22", "33"]);
        let mut b = doc(&["11", "22", "33"]);
        b.checkpoints[1].streams[0].hash = "ff".to_string();
        b.checkpoints[1].pending = 9;
        let report = diff(&a, &b);
        assert!(!report.clean());
        assert_eq!(report.checkpoints_compared, 1);
        let d = report.divergence.unwrap();
        assert_eq!(d.seq, 1);
        assert_eq!(d.at_a, Some(200));
        assert_eq!(d.fields, vec!["events".to_string(), "pending".to_string()]);
        assert!(d.first_event.is_none());
    }

    #[test]
    fn event_rings_narrow_to_the_first_divergent_item() {
        let mk = |tag: u64| FpDoc {
            at: 412,
            job: 37,
            stream: "events".to_string(),
            tag,
            fp: format!("{tag:016x}"),
        };
        let mut a = doc(&["11", "22"]);
        let mut b = doc(&["11", "22"]);
        a.checkpoints[1].streams[0].hash = "ee".to_string();
        a.checkpoints[1].recent = vec![mk(0), mk(1)];
        b.checkpoints[1].recent = vec![mk(0), mk(4)];
        let report = diff(&a, &b);
        let ev = report.divergence.unwrap().first_event.unwrap();
        assert_eq!(ev.index, 1);
        assert_eq!(ev.a.unwrap().tag, 1);
        assert_eq!(ev.b.unwrap().describe(), "slot 412, job 37, events/migrated");
        assert!(!ev.truncated);
    }

    #[test]
    fn overflowed_identical_rings_report_truncation() {
        let mut a = doc(&["11", "22"]);
        let mut b = doc(&["11", "22"]);
        a.checkpoints[1].streams[2].count = 7; // rejections diverge...
        a.checkpoints[1].dropped = 5; // ...past the recorded ring
        b.checkpoints[1].dropped = 5;
        let report = diff(&a, &b);
        let ev = report.divergence.unwrap().first_event.unwrap();
        assert!(ev.truncated);
    }

    #[test]
    fn length_and_tail_divergences_are_reported() {
        // one run recorded more checkpoints
        let a = doc(&["11", "22", "33"]);
        let b = doc(&["11", "22"]);
        let d = diff(&a, &b).divergence.unwrap();
        assert_eq!(d.fields, vec!["checkpoint-count".to_string()]);
        assert_eq!(d.seq, 2);
        assert_eq!(d.at_a, Some(300));
        assert_eq!(d.at_b, None);
        // same checkpoints, different final digests
        let a = doc(&["11", "22"]);
        let mut b = doc(&["11", "22"]);
        b.streams[1].hash = "ff".to_string();
        let d = diff(&a, &b).divergence.unwrap();
        assert_eq!(d.fields, vec!["final:records".to_string()]);
    }

    #[test]
    fn cadence_mismatch_short_circuits() {
        let a = doc(&["11"]);
        let mut b = doc(&["11"]);
        b.cadence = 50;
        let report = diff(&a, &b);
        assert!(!report.clean());
        assert_eq!(report.cadence_mismatch, Some((100, 50)));
        assert!(report.render("a", "b").contains("cadence mismatch"));
    }

    #[test]
    fn report_json_streams_and_parses() {
        let a = doc(&["11", "22"]);
        let mut b = doc(&["11", "22"]);
        b.checkpoints[1].streams[4].hash = "ff".to_string();
        b.explain = Some("b_explain.json".to_string());
        let report = diff(&a, &b);
        let mut emitter = JsonEmitter::pretty(Vec::new());
        report.write_json(&mut emitter).unwrap();
        let text = String::from_utf8(emitter.finish().unwrap()).unwrap();
        let json = Json::parse(&text).unwrap();
        assert!(!json.req("clean").unwrap().as_bool().unwrap());
        let d = json.req("divergence").unwrap();
        assert_eq!(d.req("seq").unwrap().as_u64().unwrap(), 1);
        assert_eq!(d.req("fields").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn loader_rejects_corrupt_documents() {
        let dir = crate::util::temp_dir("rarsched-ledger-diff").unwrap();
        let garbage = dir.join("garbage.json");
        std::fs::write(&garbage, "{\"version\": 1, \"cadence\":").unwrap();
        let err = load(&garbage).unwrap_err().to_string();
        assert!(err.contains("not valid JSON"), "got: {err}");
        let wrong = dir.join("wrong.json");
        std::fs::write(&wrong, "{\"version\": 2}").unwrap();
        assert!(load(&wrong).is_err());
        let missing = dir.join("missing.json");
        assert!(load(&missing).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
