//! Run-digest flight recorder: per-stream rolling hashes plus periodic
//! state checkpoints, cheap enough to arm on every run.
//!
//! Every equivalence ladder in this repo (tracker == snapshot,
//! streaming == materialized, empty-fault-trace == fault-free, armed ==
//! disarmed obs, MaxMinFair == EffectiveDegree on mirrored fabrics) is
//! proven as "two runs are bit-identical" — and fails as one opaque
//! `assert_eq!` over a whole outcome. The ledger turns each run into a
//! compact digest that `rarsched diff` ([`crate::obs::diff`]) can align
//! pairwise, so a broken ladder localizes to *the first divergent
//! checkpoint, stream and event* instead of "the runs differ".
//!
//! Five streams are folded with an FNV-1a rolling hash (the same
//! function as [`crate::runtime::config_digest`]): lifecycle **events**,
//! completed job **records**, admission **rejections**, **migrations**
//! and consumed **fault events**. Each stream costs O(1) memory — a
//! 64-bit hash and a count — so the ledger composes with
//! `run_streaming`. At a configurable slot cadence (optionally aligned
//! to `--window` boundaries) the loop adds a [`Checkpoint`]: queue
//! depths, free-slot census, a hash of the per-link ring counts and a
//! hash of the obs counter deltas since arm. With `--ledger-events` a
//! bounded ring keeps the *first* [`RING_CAP`] item fingerprints of each
//! checkpoint interval, which is what lets the diff pin the first
//! divergent event inside a divergent interval.
//!
//! Process-global facade in the [`timeline`](crate::obs::timeline) /
//! [`explain`](crate::obs::explain) idiom: disarmed, every hook is one
//! relaxed atomic load; armed, recording is a passive read of scheduler
//! state that never flows back into a decision (the `obs_passivity`
//! property test pins bit-identity with the ledger armed).
//!
//! Counter caveat: [`metrics`] counters are process-global and
//! monotonic, so checkpoints hash the *delta from an arm-time snapshot*
//! — two equivalent runs recorded in different processes (or after
//! different warm-up work in the same process) still produce identical
//! ledgers.

use crate::faults::{FaultAction, FaultEvent};
use crate::obs::metrics;
use crate::sim::JobRecord;
use crate::util::JsonEmitter;
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// FNV-1a offset basis (mirrors `runtime::config_digest`).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one little-endian word into an FNV-1a hash.
fn fnv_word(mut h: u64, w: u64) -> u64 {
    for b in w.to_le_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fold a byte string into an FNV-1a hash.
fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash a word sequence from the offset basis (item fingerprints).
fn fnv_words(words: &[u64]) -> u64 {
    words.iter().fold(FNV_OFFSET, |h, &w| fnv_word(h, w))
}

/// The five digested streams, in dense order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stream {
    /// Lifecycle events (`RunSink::event` order).
    Events,
    /// Completed job records (completion order, residuals included).
    Records,
    /// Admission rejections.
    Rejections,
    /// Committed migrations.
    Migrations,
    /// Consumed fault events.
    Faults,
}

/// Number of digested streams.
pub const NUM_STREAMS: usize = 5;

impl Stream {
    pub const ALL: [Stream; NUM_STREAMS] = [
        Stream::Events,
        Stream::Records,
        Stream::Rejections,
        Stream::Migrations,
        Stream::Faults,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stream::Events => "events",
            Stream::Records => "records",
            Stream::Rejections => "rejections",
            Stream::Migrations => "migrations",
            Stream::Faults => "faults",
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

/// Rolling digest of one stream: item count + FNV-1a hash of every
/// word folded so far. O(1) memory regardless of run length.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamSig {
    pub count: u64,
    pub hash: u64,
}

impl StreamSig {
    fn new() -> StreamSig {
        StreamSig { count: 0, hash: FNV_OFFSET }
    }

    fn fold(&mut self, words: &[u64]) {
        for &w in words {
            self.hash = fnv_word(self.hash, w);
        }
        self.count += 1;
    }
}

/// Ring capacity: the first `RING_CAP` item fingerprints of each
/// checkpoint interval are kept (a *prefix*, so the first divergent
/// event inside the interval is pinned exactly whenever it falls within
/// capacity; overflow is reported as `dropped`).
pub const RING_CAP: usize = 64;

/// One recorded item fingerprint (`--ledger-events` mode only).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EventFp {
    /// Slot the item was recorded at.
    pub at: u64,
    /// Trace job id (`u64::MAX` for the fabric-event sentinel).
    pub job: u64,
    pub stream: Stream,
    /// Stream-specific tag (event-kind index, fault-action index, …).
    pub tag: u64,
    /// FNV-1a fingerprint over the item's full word encoding.
    pub fp: u64,
}

/// Scheduler-state census captured by a [`Checkpoint`] — built by the
/// caller so the probe reads are free when the ledger is disarmed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueCensus {
    pub pending: usize,
    pub running: usize,
    pub recovering: usize,
    /// Free schedulable GPU slots across healthy servers.
    pub free_gpus: usize,
}

/// One periodic state checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Checkpoint ordinal (0-based).
    pub seq: u64,
    /// Slot the checkpoint was taken at.
    pub at: u64,
    pub census: QueueCensus,
    /// FNV-1a over the per-link ring counts in link order
    /// (offset basis when the engine recorded no link census).
    pub links_hash: u64,
    /// FNV-1a over the obs counter deltas since arm, name + value.
    pub counters_hash: u64,
    /// Per-stream digests as of this checkpoint.
    pub streams: [StreamSig; NUM_STREAMS],
    /// First item fingerprints of the interval (events mode only).
    pub recent: Vec<EventFp>,
    /// Fingerprints dropped past [`RING_CAP`] this interval.
    pub dropped: u64,
}

/// The drained flight recorder: everything [`disarm`] hands back, ready
/// for a [`save`](Ledger::save) stamped with the run manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Ledger {
    /// Checkpoint cadence in slots.
    pub cadence: u64,
    /// Whether event-fingerprint rings were recorded.
    pub events: bool,
    /// `--explain` output path recorded at arm time, for the diff's
    /// decision-audit cross-link.
    pub explain: Option<String>,
    /// Final per-stream digests (cover the whole run, beyond the last
    /// checkpoint).
    pub streams: [StreamSig; NUM_STREAMS],
    pub checkpoints: Vec<Checkpoint>,
}

struct LedgerState {
    cadence: u64,
    events: bool,
    explain: Option<String>,
    streams: [StreamSig; NUM_STREAMS],
    ring: Vec<EventFp>,
    dropped: u64,
    seq: u64,
    baseline: metrics::Snapshot,
    checkpoints: Vec<Checkpoint>,
}

impl LedgerState {
    fn note(&mut self, stream: Stream, at: u64, job: u64, tag: u64, words: &[u64]) {
        self.streams[stream.index()].fold(words);
        if self.events {
            if self.ring.len() < RING_CAP {
                self.ring.push(EventFp { at, job, stream, tag, fp: fnv_words(words) });
            } else {
                self.dropped += 1;
            }
        }
    }
}

static ARMED: AtomicBool = AtomicBool::new(false);
/// Next slot at which a cadence checkpoint is due (`u64::MAX` when
/// disarmed), so the per-iteration due-check costs no lock.
static NEXT_DUE: AtomicU64 = AtomicU64::new(u64::MAX);
static STATE: Mutex<Option<LedgerState>> = Mutex::new(None);

/// Arm the recorder (clears any previous state and snapshots the obs
/// counters as the delta baseline). `explain` is the `--explain` output
/// path, recorded so `rarsched diff` can cross-link decision audits.
pub fn arm(cadence: u64, record_events: bool, explain: Option<String>) {
    let cadence = cadence.max(1);
    *STATE.lock().expect("ledger poisoned") = Some(LedgerState {
        cadence,
        events: record_events,
        explain,
        streams: [StreamSig::new(); NUM_STREAMS],
        ring: Vec::new(),
        dropped: 0,
        seq: 0,
        baseline: metrics::snapshot(),
        checkpoints: Vec::new(),
    });
    NEXT_DUE.store(cadence, Ordering::Release);
    ARMED.store(true, Ordering::Release);
}

/// Disarm and drain: the recorded [`Ledger`], or `None` if the recorder
/// was never armed.
pub fn disarm() -> Option<Ledger> {
    ARMED.store(false, Ordering::Release);
    NEXT_DUE.store(u64::MAX, Ordering::Release);
    let st = STATE.lock().expect("ledger poisoned").take()?;
    Some(Ledger {
        cadence: st.cadence,
        events: st.events,
        explain: st.explain,
        streams: st.streams,
        checkpoints: st.checkpoints,
    })
}

/// Whether the recorder is armed — the hooks' fast path.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Fold one lifecycle event (mirrors `RunSink::event`).
pub fn note_event(at: u64, job: u64, kind: u64) {
    if !armed() {
        return;
    }
    if let Some(st) = STATE.lock().expect("ledger poisoned").as_mut() {
        st.note(Stream::Events, at, job, kind, &[at, job, kind]);
    }
}

/// Fold one completed job record (mirrors `RunSink::record`).
pub fn note_record(rec: &JobRecord) {
    if !armed() {
        return;
    }
    let words = [
        rec.job.0 as u64,
        rec.arrival,
        rec.start,
        rec.finish,
        rec.span as u64,
        rec.workers as u64,
        rec.max_p as u64,
        rec.mean_tau.to_bits(),
        rec.iterations_done,
        rec.migrations as u64,
    ];
    if let Some(st) = STATE.lock().expect("ledger poisoned").as_mut() {
        st.note(Stream::Records, rec.finish, rec.job.0 as u64, 0, &words);
    }
}

/// Fold one admission rejection (mirrors `RunSink::reject`).
pub fn note_reject(at: u64, job: u64) {
    if !armed() {
        return;
    }
    if let Some(st) = STATE.lock().expect("ledger poisoned").as_mut() {
        st.note(Stream::Rejections, at, job, 0, &[at, job]);
    }
}

/// Fold one committed migration (mirrors `RunSink::migration`).
pub fn note_migration(at: u64, job: u64, from_effective: f64, to_effective: f64, restart: u64) {
    if !armed() {
        return;
    }
    let words = [at, job, from_effective.to_bits(), to_effective.to_bits(), restart];
    if let Some(st) = STATE.lock().expect("ledger poisoned").as_mut() {
        st.note(Stream::Migrations, at, job, 0, &words);
    }
}

/// Fold one consumed fault event (step-0 fault application).
pub fn note_fault(fe: &FaultEvent) {
    if !armed() {
        return;
    }
    let (tag, words) = match fe.action {
        FaultAction::ServerCrash { server } => (0u64, [fe.at, 0, server as u64, 0]),
        FaultAction::ServerRecover { server } => (1, [fe.at, 1, server as u64, 0]),
        FaultAction::GpuFail { server, gpu } => (2, [fe.at, 2, server as u64, gpu as u64]),
        FaultAction::LinkDegrade { link, factor } => {
            (3, [fe.at, 3, link as u64, factor.to_bits()])
        }
        FaultAction::LinkRestore { link } => (4, [fe.at, 4, link as u64, 0]),
    };
    if let Some(st) = STATE.lock().expect("ledger poisoned").as_mut() {
        st.note(Stream::Faults, fe.at, u64::MAX, tag, &words);
    }
}

/// Whether a cadence checkpoint is due at slot `t`. One relaxed load
/// when disarmed; no lock either way.
#[inline]
pub fn checkpoint_due(t: u64) -> bool {
    t >= NEXT_DUE.load(Ordering::Relaxed)
}

/// Record a checkpoint at slot `t` if one is due (or unconditionally
/// with `force`, for the end-of-run tail checkpoint). `links` is only
/// invoked when a checkpoint is actually taken, so the per-link count
/// walk is free otherwise; engines without a maintained link census
/// pass `|| []`.
pub fn checkpoint<I, F>(t: u64, census: QueueCensus, force: bool, links: F)
where
    F: FnOnce() -> I,
    I: IntoIterator<Item = u64>,
{
    if !armed() || (!force && !checkpoint_due(t)) {
        return;
    }
    let mut guard = STATE.lock().expect("ledger poisoned");
    let Some(st) = guard.as_mut() else {
        return;
    };
    let links_hash = links().into_iter().fold(FNV_OFFSET, fnv_word);
    let current = metrics::snapshot();
    let counters_hash = st
        .baseline
        .delta(&current)
        .iter()
        .fold(FNV_OFFSET, |h, (name, &v)| fnv_word(fnv_bytes(h, name.as_bytes()), v));
    let recent = std::mem::take(&mut st.ring);
    let dropped = std::mem::take(&mut st.dropped);
    st.checkpoints.push(Checkpoint {
        seq: st.seq,
        at: t,
        census,
        links_hash,
        counters_hash,
        streams: st.streams,
        recent,
        dropped,
    });
    st.seq += 1;
    // next cadence boundary strictly after t
    let next = (t / st.cadence + 1).saturating_mul(st.cadence);
    NEXT_DUE.store(next, Ordering::Release);
}

fn hex(h: u64) -> String {
    format!("{h:016x}")
}

impl Ledger {
    /// Stream the ledger as JSON through a [`JsonEmitter`], with the run
    /// manifest (pre-rendered JSON text) stamped under `"manifest"`.
    /// Hashes are emitted as 16-digit hex strings — a JSON number would
    /// lose bits past 2^53.
    pub fn write_json<W: std::io::Write>(
        &self,
        emitter: &mut JsonEmitter<W>,
        manifest_json: Option<&str>,
    ) -> std::io::Result<()> {
        fn sigs<W: std::io::Write>(
            e: &mut JsonEmitter<W>,
            streams: &[StreamSig; NUM_STREAMS],
        ) -> std::io::Result<()> {
            e.begin_obj()?;
            for s in Stream::ALL {
                e.key(s.name())?;
                e.begin_obj()?;
                e.key("count")?;
                e.uint(streams[s.index()].count)?;
                e.key("hash")?;
                e.str(&hex(streams[s.index()].hash))?;
                e.end_obj()?;
            }
            e.end_obj()
        }
        let e = emitter;
        e.begin_obj()?;
        e.key("version")?;
        e.uint(1)?;
        e.key("cadence")?;
        e.uint(self.cadence)?;
        e.key("events")?;
        e.bool(self.events)?;
        if let Some(explain) = &self.explain {
            e.key("explain")?;
            e.str(explain)?;
        }
        e.key("streams")?;
        sigs(e, &self.streams)?;
        e.key("checkpoints")?;
        e.begin_arr()?;
        for cp in &self.checkpoints {
            e.begin_obj()?;
            e.key("seq")?;
            e.uint(cp.seq)?;
            e.key("at")?;
            e.uint(cp.at)?;
            e.key("pending")?;
            e.uint(cp.census.pending as u64)?;
            e.key("running")?;
            e.uint(cp.census.running as u64)?;
            e.key("recovering")?;
            e.uint(cp.census.recovering as u64)?;
            e.key("free_gpus")?;
            e.uint(cp.census.free_gpus as u64)?;
            e.key("links_hash")?;
            e.str(&hex(cp.links_hash))?;
            e.key("counters_hash")?;
            e.str(&hex(cp.counters_hash))?;
            e.key("streams")?;
            sigs(e, &cp.streams)?;
            if self.events {
                e.key("recent")?;
                e.begin_arr()?;
                for fp in &cp.recent {
                    e.begin_obj()?;
                    e.key("at")?;
                    e.uint(fp.at)?;
                    e.key("job")?;
                    if fp.job == u64::MAX {
                        e.num(-1.0)?;
                    } else {
                        e.uint(fp.job)?;
                    }
                    e.key("stream")?;
                    e.str(fp.stream.name())?;
                    e.key("tag")?;
                    e.uint(fp.tag)?;
                    e.key("fp")?;
                    e.str(&hex(fp.fp))?;
                    e.end_obj()?;
                }
                e.end_arr()?;
                e.key("dropped")?;
                e.uint(cp.dropped)?;
            }
            e.end_obj()?;
        }
        e.end_arr()?;
        if let Some(m) = manifest_json {
            e.key("manifest")?;
            e.raw(m)?;
        }
        e.end_obj()
    }

    /// Write the ledger to `path` (pretty JSON, streamed — never builds
    /// the whole document in memory).
    pub fn save(&self, path: &Path, manifest_json: Option<&str>) -> crate::Result<()> {
        use anyhow::Context;
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating ledger file {}", path.display()))?;
        let mut emitter = JsonEmitter::pretty(std::io::BufWriter::new(file));
        self.write_json(&mut emitter, manifest_json)?;
        let mut out = emitter.finish()?;
        out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::JobId;
    use std::sync::{Mutex as TestMutex, MutexGuard};

    // The recorder is process-global; serialize tests touching it.
    static LOCK: TestMutex<()> = TestMutex::new(());

    fn lock() -> MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn rec(job: usize, finish: u64) -> JobRecord {
        JobRecord {
            job: JobId(job),
            arrival: 0,
            start: 1,
            finish,
            span: 2,
            workers: 4,
            max_p: 3,
            mean_tau: 1.5,
            iterations_done: 100,
            migrations: 0,
        }
    }

    #[test]
    fn disarmed_hooks_record_nothing() {
        let _g = lock();
        assert!(!armed());
        note_event(1, 0, 0);
        note_record(&rec(0, 10));
        note_reject(2, 1);
        note_migration(3, 0, 4.0, 2.0, 5);
        checkpoint(1000, QueueCensus::default(), false, || [1u64, 2]);
        // arming immediately after sees a clean slate
        arm(100, true, None);
        let led = disarm().unwrap();
        assert!(led.checkpoints.is_empty());
        assert!(led.streams.iter().all(|s| s.count == 0 && s.hash == FNV_OFFSET));
    }

    #[test]
    fn identical_sequences_fold_to_identical_ledgers() {
        let _g = lock();
        let run = || {
            arm(10, true, None);
            note_event(0, 0, 0);
            note_event(1, 0, 1);
            note_reject(2, 7);
            note_migration(4, 0, 4.0, 2.0, 5);
            note_fault(&FaultEvent {
                at: 5,
                action: FaultAction::LinkDegrade { link: 2, factor: 0.5 },
            });
            checkpoint(10, QueueCensus { pending: 1, running: 2, recovering: 0, free_gpus: 4 },
                false, || [3u64, 0, 1]);
            note_record(&rec(0, 12));
            checkpoint(13, QueueCensus::default(), true, || [0u64, 0, 0]);
            disarm().unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert_eq!(a.checkpoints.len(), 2);
        assert_eq!(a.checkpoints[0].seq, 0);
        assert_eq!(a.checkpoints[0].at, 10);
        // the interval ring holds the five pre-checkpoint items in order
        assert_eq!(a.checkpoints[0].recent.len(), 5);
        assert_eq!(a.checkpoints[0].recent[0].stream, Stream::Events);
        assert_eq!(a.checkpoints[1].recent.len(), 1);
        assert_eq!(a.checkpoints[1].recent[0].stream, Stream::Records);
        // final stream digests carry the whole run
        assert_eq!(a.streams[Stream::Events.index()].count, 2);
        assert_eq!(a.streams[Stream::Records.index()].count, 1);
        assert_eq!(a.streams[Stream::Faults.index()].count, 1);
    }

    #[test]
    fn perturbed_item_changes_exactly_its_stream_hash() {
        let _g = lock();
        let run = |kind: u64| {
            arm(1000, false, None);
            note_event(0, 0, 0);
            note_event(5, 1, kind);
            note_reject(9, 3);
            disarm().unwrap()
        };
        let a = run(1);
        let b = run(2);
        assert_ne!(
            a.streams[Stream::Events.index()].hash,
            b.streams[Stream::Events.index()].hash
        );
        assert_eq!(a.streams[Stream::Rejections.index()], b.streams[Stream::Rejections.index()]);
        assert_eq!(a.streams[Stream::Events.index()].count, 2);
    }

    #[test]
    fn cadence_gates_checkpoints_and_ring_overflow_counts_drops() {
        let _g = lock();
        arm(100, true, None);
        assert!(!checkpoint_due(99));
        assert!(checkpoint_due(100));
        for i in 0..(RING_CAP as u64 + 10) {
            note_event(i, i, 0);
        }
        // not due yet: no checkpoint recorded
        checkpoint(50, QueueCensus::default(), false, || [0u64; 0]);
        checkpoint(120, QueueCensus::default(), false, std::iter::empty::<u64>);
        // due again only past the next boundary
        assert!(!checkpoint_due(150));
        assert!(checkpoint_due(200));
        let led = disarm().unwrap();
        assert_eq!(led.checkpoints.len(), 1);
        let cp = &led.checkpoints[0];
        assert_eq!(cp.at, 120);
        assert_eq!(cp.recent.len(), RING_CAP);
        assert_eq!(cp.dropped, 10);
        assert_eq!(cp.streams[Stream::Events.index()].count, RING_CAP as u64 + 10);
    }

    #[test]
    fn json_roundtrips_through_the_diff_loader() {
        let _g = lock();
        arm(10, true, Some("explain.json".to_string()));
        note_event(0, 0, 0);
        note_event(1, u64::MAX, 7); // fabric sentinel renders as -1
        checkpoint(10, QueueCensus { pending: 1, running: 1, recovering: 0, free_gpus: 2 },
            false, || [1u64, 2, 3]);
        let led = disarm().unwrap();
        let mut emitter = JsonEmitter::pretty(Vec::new());
        led.write_json(&mut emitter, Some("{\"seed\": 1}")).unwrap();
        let text = String::from_utf8(emitter.finish().unwrap()).unwrap();
        let doc = crate::util::Json::parse(&text).unwrap();
        assert_eq!(doc.req("cadence").unwrap().as_u64().unwrap(), 10);
        assert_eq!(doc.req("explain").unwrap().as_str().unwrap(), "explain.json");
        assert_eq!(doc.req("manifest").unwrap().req("seed").unwrap().as_u64().unwrap(), 1);
        let cps = doc.req("checkpoints").unwrap().as_arr().unwrap();
        assert_eq!(cps.len(), 1);
        let recent = cps[0].req("recent").unwrap().as_arr().unwrap();
        assert_eq!(recent.len(), 2);
        assert_eq!(recent[1].req("job").unwrap().as_f64().unwrap(), -1.0);
        // the diff-side loader accepts what the writer emits
        let parsed = crate::obs::diff::parse(&doc).unwrap();
        assert_eq!(parsed.cadence, 10);
        assert_eq!(parsed.checkpoints.len(), 1);
        assert_eq!(parsed.checkpoints[0].recent.len(), 2);
        assert_eq!(parsed.explain.as_deref(), Some("explain.json"));
    }
}
