//! Per-link utilization time series, sampled at scheduling events.
//!
//! Each sample row records — for one fabric link at one event time —
//! the active-ring count, the link multiplier, the **effective degree**
//! `count × multiplier` (the generalized Eq. 6 quantity the scheduler
//! minimizes) and the **residual Gbps** left on the link under the
//! engines' bottleneck-share rates. Under
//! [`ContentionModel::MaxMinFair`](crate::net::ContentionModel) the
//! multiplier already carries the capacity ratio, so the series shows
//! exactly what the active model charges each link.
//!
//! Process-global recorder, disarmed by default, passive when armed
//! (samples are read-only probes of the tracker). Exported CSV/JSON and
//! wired as `figures --fig links`.

use crate::online::ContentionTracker;
use crate::util::Json;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// One (event time, link) utilization sample.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSample {
    /// Event time (slots) the sample was taken at.
    pub t: u64,
    /// Link index within the fabric.
    pub link: usize,
    /// Human label ([`Topology::describe`](crate::topology::Topology::describe)).
    pub label: String,
    /// Active rings crossing the link.
    pub count: usize,
    /// Contention multiplier of the link under the active model.
    pub multiplier: f64,
    /// Effective degree `count × multiplier`.
    pub effective: f64,
    /// Residual bandwidth (Gbps) after the bottleneck-share charges.
    pub residual_gbps: f64,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static SAMPLES: Mutex<Vec<LinkSample>> = Mutex::new(Vec::new());

/// Arm the recorder (clears any previous samples).
pub fn arm() {
    SAMPLES.lock().expect("timeline poisoned").clear();
    ARMED.store(true, Ordering::Release);
}

/// Disarm and drain: returns everything sampled since [`arm`].
pub fn disarm() -> Vec<LinkSample> {
    ARMED.store(false, Ordering::Release);
    std::mem::take(&mut *SAMPLES.lock().expect("timeline poisoned"))
}

/// Whether the recorder is armed — the hooks' fast path.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Sample every fabric link from the tracker's maintained counts at
/// event time `t`. No-op when disarmed; `O(L + Σ span)` when armed (the
/// residual ledger walks the active set) — event-rate, not slot-rate.
pub fn sample(t: u64, tracker: &ContentionTracker) {
    if !armed() {
        return;
    }
    let topo = tracker.topology();
    let residual = tracker.residual_gbps();
    let mut rows = SAMPLES.lock().expect("timeline poisoned");
    for l in 0..topo.num_links() {
        let link = crate::topology::LinkId(l);
        let count = tracker.link_count(link);
        let multiplier = topo.multiplier(link);
        rows.push(LinkSample {
            t,
            link: l,
            label: topo.describe(link),
            count,
            multiplier,
            effective: count as f64 * multiplier,
            residual_gbps: residual[l],
        });
    }
    drop(rows);
    super::metrics::add(super::metrics::Counter::TimelineSamples, topo.num_links() as u64);
}

/// CSV export: one row per (event time, link).
pub fn to_csv(samples: &[LinkSample]) -> String {
    let mut out = String::from("t,link,label,count,multiplier,effective,residual_gbps\n");
    for s in samples {
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{}",
            s.t, s.link, s.label, s.count, s.multiplier, s.effective, s.residual_gbps
        );
    }
    out
}

/// JSON export mirroring [`to_csv`].
pub fn to_json(samples: &[LinkSample]) -> Json {
    Json::obj(vec![(
        "samples",
        Json::arr(
            samples
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("t", Json::Num(s.t as f64)),
                        ("link", Json::Num(s.link as f64)),
                        ("label", Json::Str(s.label.clone())),
                        ("count", Json::Num(s.count as f64)),
                        ("multiplier", Json::Num(s.multiplier)),
                        ("effective", Json::Num(s.effective)),
                        ("residual_gbps", Json::Num(s.residual_gbps)),
                    ])
                })
                .collect(),
        ),
    )])
}

/// Write the CSV export to `path`.
pub fn save_csv(path: &std::path::Path, samples: &[LinkSample]) -> crate::Result<()> {
    std::fs::write(path, to_csv(samples))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, JobPlacement, ServerId};
    use crate::jobs::JobId;

    // sample() is exercised end-to-end (armed, through the online loop)
    // in tests/obs_passivity.rs; here we drive the tracker directly with
    // the recorder disarmed plus test the exporters on literal rows.

    #[test]
    fn disarmed_sample_records_nothing() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(
            JobId(0),
            &JobPlacement::new(vec![c.global_gpu(ServerId(0), 0), c.global_gpu(ServerId(1), 0)]),
        );
        assert!(!armed());
        sample(5, &tr);
        // arm() clears, so an immediate drain after arming sees nothing
        arm();
        assert!(disarm().is_empty());
    }

    fn rows() -> Vec<LinkSample> {
        vec![
            LinkSample {
                t: 0,
                link: 0,
                label: "server 0 uplink".into(),
                count: 2,
                multiplier: 1.0,
                effective: 2.0,
                residual_gbps: 0.0,
            },
            LinkSample {
                t: 0,
                link: 1,
                label: "server 1 uplink".into(),
                count: 1,
                multiplier: 2.0,
                effective: 2.0,
                residual_gbps: 12.5,
            },
        ]
    }

    #[test]
    fn csv_export_shape() {
        let csv = to_csv(&rows());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "t,link,label,count,multiplier,effective,residual_gbps");
        assert_eq!(lines[1], "0,0,server 0 uplink,2,1,2,0");
        assert_eq!(lines[2], "0,1,server 1 uplink,1,2,2,12.5");
    }

    #[test]
    fn json_export_roundtrips() {
        let json = to_json(&rows());
        let rows_json = json.req("samples").unwrap().as_arr().unwrap();
        assert_eq!(rows_json.len(), 2);
        assert_eq!(rows_json[1].req("residual_gbps").unwrap().as_f64().unwrap(), 12.5);
        assert_eq!(Json::parse(&json.to_string()).unwrap(), json);
    }
}
