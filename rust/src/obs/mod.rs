//! Observability: tracing, metrics and decision audit for the
//! contention stack.
//!
//! The paper's whole argument is that contention — count × multiplier on
//! a crossed link (Eq. 6) — drives makespan, so this layer instruments
//! exactly the choke points the contention model flows through:
//!
//! * [`trace`] — a [`TraceSink`](trace::TraceSink) facade emitting
//!   Chrome-trace/Perfetto JSON: duration spans for sim rate periods,
//!   SJF-BCO bisection rounds, what-if probes, `progressive_fill` calls
//!   and `par_map` worker tasks; instant events for
//!   Arrive/Admit/Reject/Complete/Migrate carrying the bottleneck link
//!   id (`--trace-out trace.json`).
//! * [`metrics`] — a fixed-slot counter/histogram registry (dirty-set
//!   hits vs misses, jobs re-rated per drain, what-if calls per arrival,
//!   bisection iterations, scratch-buffer reuse vs realloc, per-thread
//!   `par_map` task counts, debug cross-check executions) merged from
//!   per-thread accumulators at run end and dumped via `--obs-json`.
//! * [`explain`] — decision-audit records for every admission rejection
//!   (projected bottleneck vs θ), placement choice (winning candidate's
//!   link score vs runner-up) and migration commit/abort (which guard
//!   fired), surfaced via `--explain` on `online`.
//! * [`timeline`] — per-link utilization time series (ring count,
//!   effective degree and residual Gbps under the active
//!   [`ContentionModel`](crate::net::ContentionModel)), sampled at
//!   scheduling events and exported CSV/JSON (`figures --fig links`).
//! * [`ledger`] — the run-digest flight recorder: FNV-1a rolling hashes
//!   over the event/record/rejection/migration/fault streams plus
//!   periodic state checkpoints, armed via `--ledger <file>`
//!   (`--ledger-events` adds bounded per-interval event-fingerprint
//!   rings), O(1) memory per stream.
//! * [`diff`] — `rarsched diff <a.json> <b.json>`: aligns two ledgers
//!   and pins the first divergent checkpoint, stream and event; the
//!   forensics tool for a broken equivalence ladder ("ladder fails →
//!   re-run both sides with `--ledger` → `rarsched diff`").
//! * [`prof`] — in-terminal span profiling (`--profile`): folds the
//!   [`trace`] sink's duration spans into a per-thread call tree with
//!   total/self time, call counts and a top-N-by-self-time table.
//!
//! # The passivity invariant
//!
//! Observability is **zero-cost-when-off and bit-identical-when-on**:
//! the default state (no sink armed — the Null sink) costs one relaxed
//! atomic load per hook, and arming any sink, counter dump, explain log
//! or timeline recorder **never changes a scheduling outcome** — not a
//! makespan, not a `JobRecord`, not an event sequence, not a migration
//! decision. Instrumentation only ever *reads* scheduler state; nothing
//! it computes flows back into a decision. This is an architecture
//! invariant in the same ladder as tracker-vs-snapshot equivalence, and
//! it is enforced by the `obs_passivity` property test (flat/rack/pod
//! fabrics × all three engine modes × the online loop with migration and
//! θ-admission on and off).
//!
//! The counters in [`metrics`] are always-on relaxed atomics (they are
//! passive by construction — nothing reads them back into a decision);
//! the trace/explain/timeline recorders are armed explicitly and read
//! wall-clock time only while armed, so the disarmed stack never calls
//! [`std::time::Instant::now`] on a hot path.

pub mod diff;
pub mod explain;
pub mod ledger;
pub mod metrics;
pub mod prof;
pub mod timeline;
pub mod trace;

pub use explain::Decision;
pub use ledger::Ledger;
pub use metrics::{Counter, Hist};
pub use timeline::LinkSample;
pub use trace::{MemSink, NullSink, TraceEvent, TraceSink};
