//! Chrome-trace/Perfetto event emission behind a [`TraceSink`] facade.
//!
//! The facade follows the `log`-crate idiom already used by
//! [`crate::util::logger`]: a process-global sink, disarmed by default.
//! Disarmed (the **Null sink**) every hook is a single relaxed atomic
//! load and an early return — no clock read, no allocation, no lock —
//! which is what makes the passivity invariant cheap enough to leave the
//! hooks compiled into release builds. Arming a sink (in-memory
//! [`MemSink`] for `--trace-out`, or a custom [`TraceSink`]) turns the
//! same hooks into real emissions; by the passivity invariant (see
//! [`crate::obs`]) that still never changes a scheduling outcome.
//!
//! Event vocabulary (all timestamps in microseconds since first arm):
//!
//! * duration spans (`ph: "X"`): `sim.period`, `scorer.makespan`,
//!   `bco.bisect_round`, `net.progressive_fill`, `par.worker`,
//!   `online.period`;
//! * instant events (`ph: "i"`): `job.arrive`, `job.admit`,
//!   `job.reject`, `job.complete`, `job.migrate` — each carrying the job
//!   id and, where one exists, the bottleneck link id.

use crate::util::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Chrome-trace event phase: complete (duration) or instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// `"X"` — a complete (duration) event with `ts` + `dur`.
    Complete,
    /// `"i"` — an instant event.
    Instant,
}

/// One trace event. Args are numeric key/value pairs — enough for job
/// ids, link ids, θ values and counts, without per-event string churn.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Chrome-trace category (groups rows in the viewer).
    pub cat: &'static str,
    pub ph: Phase,
    /// Microseconds since the global trace epoch (first arm).
    pub ts_us: u64,
    /// Duration in microseconds ([`Phase::Complete`] only, else 0).
    pub dur_us: u64,
    /// Emitting thread, as a stable small integer.
    pub tid: u64,
    pub args: Vec<(&'static str, f64)>,
}

/// Receiver of trace events. Implementations must tolerate concurrent
/// emission from `par_map` workers.
pub trait TraceSink: Send + Sync {
    fn emit(&self, ev: TraceEvent);
}

/// The provably-passive default: discards every event. Arming it is
/// equivalent to not arming anything except that hooks pay the
/// event-construction cost — exactly what `benches/obs_overhead.rs`
/// measures against.
#[derive(Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn emit(&self, _ev: TraceEvent) {}
}

/// In-memory sink backing `--trace-out`: collects events for a
/// [`chrome_trace_json`] dump at process end.
#[derive(Debug, Default)]
pub struct MemSink {
    events: Mutex<Vec<TraceEvent>>,
}

impl MemSink {
    pub fn new() -> Arc<MemSink> {
        Arc::new(MemSink::default())
    }

    /// Snapshot of everything emitted so far, in emission order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().expect("trace sink poisoned").clone()
    }

    /// Drain the collected events (used between bench iterations).
    pub fn take(&self) -> Vec<TraceEvent> {
        std::mem::take(&mut *self.events.lock().expect("trace sink poisoned"))
    }

    pub fn len(&self) -> usize {
        self.events.lock().expect("trace sink poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl TraceSink for MemSink {
    fn emit(&self, ev: TraceEvent) {
        self.events.lock().expect("trace sink poisoned").push(ev);
    }
}

// ---- the global facade ---------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Arc<dyn TraceSink>>> = Mutex::new(None);

/// The global trace epoch: fixed at first use so every `ts_us` is
/// non-negative and all events share one timeline.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Stable small integer for the current thread (Chrome-trace `tid`).
fn tid() -> u64 {
    // ThreadId has no stable numeric accessor; its Debug form is
    // "ThreadId(n)" — extract the digits (stable enough for a viewer row).
    let s = format!("{:?}", std::thread::current().id());
    s.bytes().filter(u8::is_ascii_digit).fold(0u64, |acc, b| {
        acc.wrapping_mul(10).wrapping_add(u64::from(b - b'0'))
    })
}

/// Install `sink` as the global trace receiver and arm emission.
pub fn arm(sink: Arc<dyn TraceSink>) {
    epoch(); // pin the epoch before the first event
    *SINK.lock().expect("trace sink registry poisoned") = Some(sink);
    ARMED.store(true, Ordering::Release);
}

/// Disarm emission and drop the installed sink.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *SINK.lock().expect("trace sink registry poisoned") = None;
}

/// Whether a sink is armed. The disarmed fast path of every hook.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Emit one event if armed (drops silently otherwise).
pub fn emit(ev: TraceEvent) {
    if !armed() {
        return;
    }
    let sink = SINK.lock().expect("trace sink registry poisoned").clone();
    if let Some(sink) = sink {
        sink.emit(ev);
    }
}

/// Emit an instant event (`ph: "i"`) if armed.
pub fn instant(name: &'static str, cat: &'static str, args: &[(&'static str, f64)]) {
    if !armed() {
        return;
    }
    emit(TraceEvent {
        name,
        cat,
        ph: Phase::Instant,
        ts_us: now_us(),
        dur_us: 0,
        tid: tid(),
        args: args.to_vec(),
    });
}

/// RAII duration span: emits one [`Phase::Complete`] event on drop.
/// Disarmed construction is free (no clock read) and the drop is a
/// no-op; a span never straddles arm/disarm boundaries usefully, so a
/// span created disarmed stays silent even if arming races its drop.
#[must_use = "a span measures the scope it is bound to"]
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start_us: u64,
    args: Vec<(&'static str, f64)>,
    live: bool,
}

/// Open a duration span (see [`Span`]).
pub fn span(name: &'static str, cat: &'static str) -> Span {
    if !armed() {
        return Span { name, cat, start_us: 0, args: Vec::new(), live: false };
    }
    Span { name, cat, start_us: now_us(), args: Vec::new(), live: true }
}

impl Span {
    /// Attach a numeric argument (no-op when the span is dead).
    pub fn arg(mut self, key: &'static str, value: f64) -> Span {
        if self.live {
            self.args.push((key, value));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let end = now_us();
        emit(TraceEvent {
            name: self.name,
            cat: self.cat,
            ph: Phase::Complete,
            ts_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            tid: tid(),
            args: std::mem::take(&mut self.args),
        });
    }
}

// ---- Chrome-trace JSON ---------------------------------------------------

/// Render events as a Chrome-trace document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
///
/// Emission order is close-time for spans (a [`Span`] reports its
/// *open* timestamp only when dropped), so the document is sorted by
/// timestamp here — longer spans first at ties, the nesting order
/// viewers expect — which is also what makes the emitted file satisfy
/// [`validate_chrome_trace`]'s monotonicity requirement.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let mut ordered: Vec<&TraceEvent> = events.iter().collect();
    ordered.sort_by_key(|e| (e.ts_us, std::cmp::Reverse(e.dur_us)));
    let rows = ordered
        .iter()
        .map(|ev| {
            let mut pairs = vec![
                ("name", Json::Str(ev.name.to_string())),
                ("cat", Json::Str(ev.cat.to_string())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(ev.tid as f64)),
                ("ts", Json::Num(ev.ts_us as f64)),
            ];
            match ev.ph {
                Phase::Complete => {
                    pairs.push(("ph", Json::Str("X".to_string())));
                    pairs.push(("dur", Json::Num(ev.dur_us as f64)));
                }
                Phase::Instant => {
                    pairs.push(("ph", Json::Str("i".to_string())));
                    pairs.push(("s", Json::Str("p".to_string())));
                }
            }
            if !ev.args.is_empty() {
                pairs.push((
                    "args",
                    Json::obj(ev.args.iter().map(|&(k, v)| (k, Json::Num(v))).collect()),
                ));
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::arr(rows)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Write a Chrome-trace file for `events` (the `--trace-out` sink dump).
pub fn write_chrome_trace(path: &std::path::Path, events: &[TraceEvent]) -> crate::Result<()> {
    std::fs::write(path, chrome_trace_json(events).to_string())?;
    Ok(())
}

/// Validate a parsed Chrome-trace document: `traceEvents` must be an
/// array of objects each carrying a string `name`, a known `ph`, a
/// non-negative numeric `ts` (and non-negative `dur` for `"X"` spans),
/// with per-`tid` timestamps non-decreasing in file order (our sinks
/// record chronologically per thread). Returns the event count — the
/// `verify.sh` well-formedness gate for emitted `--trace-out` files.
pub fn validate_chrome_trace(doc: &Json) -> crate::Result<usize> {
    use anyhow::{bail, Context};
    let events = doc.req("traceEvents")?.as_arr().context("traceEvents must be an array")?;
    let mut last_ts: std::collections::BTreeMap<u64, f64> = std::collections::BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let name = ev.req("name").and_then(Json::as_str).with_context(|| format!("event {i}"))?;
        let ph = ev.req("ph").and_then(Json::as_str).with_context(|| format!("event {i}"))?;
        let ts = ev.req("ts").and_then(Json::as_f64).with_context(|| format!("event {i}"))?;
        if ts < 0.0 {
            bail!("event {i} ('{name}') has negative ts {ts}");
        }
        match ph {
            "X" => {
                let dur = ev.req("dur").and_then(Json::as_f64).with_context(|| format!("event {i}"))?;
                if dur < 0.0 {
                    bail!("span {i} ('{name}') has negative dur {dur}");
                }
            }
            "i" | "B" | "E" | "M" => {}
            other => bail!("event {i} ('{name}') has unknown phase '{other}'"),
        }
        let tid = ev.req("tid").and_then(Json::as_u64).with_context(|| format!("event {i}"))?;
        if let Some(&prev) = last_ts.get(&tid) {
            if ts < prev {
                bail!("event {i} ('{name}') regresses tid {tid} timestamp: {ts} < {prev}");
            }
        }
        last_ts.insert(tid, ts);
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, ph: Phase, ts: u64, dur: u64, tid: u64) -> TraceEvent {
        TraceEvent { name, cat: "test", ph, ts_us: ts, dur_us: dur, tid, args: Vec::new() }
    }

    #[test]
    fn disarmed_span_and_instant_are_silent() {
        // default state: nothing armed, nothing recorded anywhere
        assert!(!armed());
        let s = span("sim.period", "sim").arg("t", 1.0);
        drop(s);
        instant("job.arrive", "online", &[("job", 0.0)]);
        // still disarmed, still no sink
        assert!(!armed());
    }

    #[test]
    fn mem_sink_collects_direct_emissions() {
        let sink = MemSink::new();
        sink.emit(ev("sim.period", Phase::Complete, 10, 5, 1));
        sink.emit(ev("job.arrive", Phase::Instant, 20, 0, 1));
        let evs = sink.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "sim.period");
        assert_eq!(evs[1].ph, Phase::Instant);
        assert_eq!(sink.take().len(), 2);
        assert!(sink.is_empty());
    }

    #[test]
    fn chrome_json_roundtrips_and_validates() {
        let mut e0 = ev("sim.period", Phase::Complete, 10, 5, 1);
        e0.args = vec![("t", 3.0), ("active", 2.0)];
        let events = vec![e0, ev("job.complete", Phase::Instant, 30, 0, 1)];
        let doc = chrome_trace_json(&events);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(validate_chrome_trace(&parsed).unwrap(), 2);
        let rows = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].req("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(rows[0].req("args").unwrap().req("t").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(rows[1].req("ph").unwrap().as_str().unwrap(), "i");
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        // not a trace document at all
        assert!(validate_chrome_trace(&Json::parse(r#"{"x": 1}"#).unwrap()).is_err());
        // negative timestamp
        let neg = chrome_trace_json(&[ev("a", Phase::Instant, 0, 0, 1)]);
        let mut bad = neg.to_string().replace("\"ts\":0", "\"ts\":-5");
        assert!(validate_chrome_trace(&Json::parse(&bad).unwrap()).is_err());
        // unknown phase
        bad = neg.to_string().replace("\"ph\":\"i\"", "\"ph\":\"Z\"");
        assert!(validate_chrome_trace(&Json::parse(&bad).unwrap()).is_err());
        // per-tid timestamp regression (hand-built: chrome_trace_json
        // sorts, so an emitted document can no longer regress)
        let doc = Json::parse(
            r#"{"traceEvents": [
                {"name": "a", "ph": "i", "ts": 10, "tid": 1},
                {"name": "b", "ph": "i", "ts": 5, "tid": 1}
            ]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&doc).is_err());
        // same regression on different tids is fine (parallel threads)
        let doc = Json::parse(
            r#"{"traceEvents": [
                {"name": "a", "ph": "i", "ts": 10, "tid": 1},
                {"name": "b", "ph": "i", "ts": 5, "tid": 2}
            ]}"#,
        )
        .unwrap();
        assert_eq!(validate_chrome_trace(&doc).unwrap(), 2);
    }

    #[test]
    fn chrome_json_sorts_close_time_emissions() {
        // a span closing after an instant is emitted later but must be
        // rendered earlier (its ts is the open time)
        let events = vec![
            ev("job.arrive", Phase::Instant, 20, 0, 1),
            ev("online.run", Phase::Complete, 0, 50, 1),
        ];
        let doc = chrome_trace_json(&events);
        assert_eq!(validate_chrome_trace(&doc).unwrap(), 2);
        let rows = doc.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].req("name").unwrap().as_str().unwrap(), "online.run");
        // at equal ts the longer span comes first (viewer nesting order)
        let tied = vec![
            ev("bco.bisect_round", Phase::Complete, 0, 5, 1),
            ev("sim.run", Phase::Complete, 0, 50, 1),
        ];
        let rows = chrome_trace_json(&tied);
        let rows = rows.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].req("name").unwrap().as_str().unwrap(), "sim.run");
    }

    #[test]
    fn write_chrome_trace_emits_a_parseable_file() {
        let dir = crate::util::temp_dir("rarsched-obs-trace").unwrap();
        let path = dir.join("trace.json");
        write_chrome_trace(&path, &[ev("net.progressive_fill", Phase::Complete, 0, 2, 7)])
            .unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(validate_chrome_trace(&doc).unwrap(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
