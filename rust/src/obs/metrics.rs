//! Fixed-slot counter/histogram registry for the contention hot paths.
//!
//! Counters are process-global relaxed atomics, **always on**: they are
//! passive by construction (nothing ever reads them back into a
//! scheduling decision — the passivity property test pins this), and a
//! relaxed `fetch_add` is cheap enough to leave enabled in release
//! builds. Parallel stages accumulate **per thread** (plain locals in
//! the `par_map` worker loop) and merge here once at worker exit, so
//! the hot loop pays one atomic per worker rather than one per task.
//!
//! `--obs-json` dumps the registry ([`to_json`]) after a run; the debug
//! cross-check counters ([`Counter::TrackerCrossChecks`],
//! [`Counter::HistCrossChecks`]) let a debug-build verify run confirm
//! the tracker-vs-rebuild assertions actually executed instead of
//! silently compiling away.

use crate::util::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed counter slots. Adding a slot means adding it here, to
/// [`Counter::ALL`] and to [`Counter::name`] — the registry never
/// allocates or hashes on the increment path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Dirty-set drains where a cached rate survived (active job *not*
    /// re-rated this period).
    DirtyHits,
    /// Jobs re-rated by a dirty-set drain (cache misses).
    DirtyMisses,
    /// Rate-refresh periods executed by the batch engine.
    EnginePeriods,
    /// Rate-refresh periods executed by the online loop.
    OnlinePeriods,
    /// Speculative tracker probes (`whatif_bottleneck` /
    /// `whatif_rebottleneck` / `whatif_share_gbps`).
    WhatifCalls,
    /// SJF-BCO θ-bisection rounds.
    BisectionRounds,
    /// `progressive_fill` calls that reused the scratch arena capacity.
    ScratchReuse,
    /// `progressive_fill` calls that had to grow the scratch arena.
    ScratchRealloc,
    /// Tracker-vs-full-rebuild debug cross-checks executed.
    TrackerCrossChecks,
    /// Histogram-vs-O(L)-scan `max_contention` cross-checks executed.
    HistCrossChecks,
    /// Items processed by `par_map` workers (merged per thread at exit).
    ParMapTasks,
    /// Worker threads spawned by `par_map`.
    ParMapWorkers,
    /// Online admissions rejected (any reason).
    AdmissionRejects,
    /// Online migrations committed.
    MigrationCommits,
    /// Online migration candidates abandoned by a guard.
    MigrationAborts,
    /// Per-link timeline samples recorded.
    TimelineSamples,
    /// Fault events applied by the online loop (all kinds).
    FaultEvents,
    /// Running gangs killed by a fault (server crash or GPU failure).
    FaultKills,
    /// Failed jobs re-placed on surviving GPUs.
    RecoveryCommits,
    /// Recovery attempts deferred by a guard (per attempt, not per job).
    RecoveryDeferrals,
    /// Link capacity changes applied (degrade + restore instants).
    LinkChanges,
}

impl Counter {
    pub const ALL: [Counter; 21] = [
        Counter::DirtyHits,
        Counter::DirtyMisses,
        Counter::EnginePeriods,
        Counter::OnlinePeriods,
        Counter::WhatifCalls,
        Counter::BisectionRounds,
        Counter::ScratchReuse,
        Counter::ScratchRealloc,
        Counter::TrackerCrossChecks,
        Counter::HistCrossChecks,
        Counter::ParMapTasks,
        Counter::ParMapWorkers,
        Counter::AdmissionRejects,
        Counter::MigrationCommits,
        Counter::MigrationAborts,
        Counter::TimelineSamples,
        Counter::FaultEvents,
        Counter::FaultKills,
        Counter::RecoveryCommits,
        Counter::RecoveryDeferrals,
        Counter::LinkChanges,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::DirtyHits => "dirty_hits",
            Counter::DirtyMisses => "dirty_misses",
            Counter::EnginePeriods => "engine_periods",
            Counter::OnlinePeriods => "online_periods",
            Counter::WhatifCalls => "whatif_calls",
            Counter::BisectionRounds => "bisection_rounds",
            Counter::ScratchReuse => "scratch_reuse",
            Counter::ScratchRealloc => "scratch_realloc",
            Counter::TrackerCrossChecks => "tracker_cross_checks",
            Counter::HistCrossChecks => "hist_cross_checks",
            Counter::ParMapTasks => "par_map_tasks",
            Counter::ParMapWorkers => "par_map_workers",
            Counter::AdmissionRejects => "admission_rejects",
            Counter::MigrationCommits => "migration_commits",
            Counter::MigrationAborts => "migration_aborts",
            Counter::TimelineSamples => "timeline_samples",
            Counter::FaultEvents => "fault_events",
            Counter::FaultKills => "fault_kills",
            Counter::RecoveryCommits => "recovery_commits",
            Counter::RecoveryDeferrals => "recovery_deferrals",
            Counter::LinkChanges => "link_changes",
        }
    }
}

const NUM_COUNTERS: usize = Counter::ALL.len();

// const-item repeat (not inline-const) keeps the MSRV conservative
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);

static COUNTERS: [AtomicU64; NUM_COUNTERS] = [ZERO; NUM_COUNTERS];

/// Power-of-two-bucket histograms over per-event magnitudes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hist {
    /// Jobs re-rated per dirty-set drain.
    ReratedPerDrain,
    /// What-if probes issued per online arrival.
    WhatifPerArrival,
    /// θ-bisection rounds per SJF-BCO schedule.
    RoundsPerBisection,
}

impl Hist {
    pub const ALL: [Hist; 3] =
        [Hist::ReratedPerDrain, Hist::WhatifPerArrival, Hist::RoundsPerBisection];

    pub fn name(self) -> &'static str {
        match self {
            Hist::ReratedPerDrain => "rerated_per_drain",
            Hist::WhatifPerArrival => "whatif_per_arrival",
            Hist::RoundsPerBisection => "rounds_per_bisection",
        }
    }
}

/// Buckets: `[0]`, `[1]`, then `[2^(i-1), 2^i)` up to an overflow bucket.
pub const HIST_BUCKETS: usize = 17;

#[allow(clippy::declare_interior_mutable_const)]
const ZERO_ROW: [AtomicU64; HIST_BUCKETS] = [ZERO; HIST_BUCKETS];

static HISTS: [[AtomicU64; HIST_BUCKETS]; Hist::ALL.len()] = [ZERO_ROW; 3];

fn bucket_of(v: u64) -> usize {
    match v {
        0 => 0,
        _ => ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1),
    }
}

/// Human label for histogram bucket `i` (`"0"`, `"1"`, `"2-3"`, …).
pub fn bucket_label(i: usize) -> String {
    match i {
        0 => "0".to_string(),
        1 => "1".to_string(),
        _ if i == HIST_BUCKETS - 1 => format!("{}+", 1u64 << (HIST_BUCKETS - 2)),
        _ => format!("{}-{}", 1u64 << (i - 1), (1u64 << i) - 1),
    }
}

/// Per-thread `par_map` task totals, keyed by worker label (merged once
/// per worker at exit — see [`note_worker_tasks`]).
static THREAD_TASKS: Mutex<BTreeMap<String, u64>> = Mutex::new(BTreeMap::new());

/// Add `n` to a counter slot (relaxed; safe from any thread).
#[inline]
pub fn add(c: Counter, n: u64) {
    COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
}

/// Increment a counter slot by one.
#[inline]
pub fn incr(c: Counter) {
    add(c, 1);
}

/// Current value of a counter slot.
pub fn get(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// Record one observation into a histogram.
pub fn record(h: Hist, v: u64) {
    HISTS[h as usize][bucket_of(v)].fetch_add(1, Ordering::Relaxed);
}

/// Merge one worker's locally-accumulated task count: bumps
/// [`Counter::ParMapTasks`] and the per-thread table under `label`.
pub fn note_worker_tasks(label: &str, tasks: u64) {
    add(Counter::ParMapTasks, tasks);
    *THREAD_TASKS
        .lock()
        .expect("thread-task table poisoned")
        .entry(label.to_string())
        .or_insert(0) += tasks;
}

/// Point-in-time copy of every counter (for delta assertions and the
/// armed-vs-null bench).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Snapshot {
    counters: [u64; NUM_COUNTERS],
}

impl Snapshot {
    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// `current - self` per slot (saturating: reset between snapshots
    /// reads as zero, not a wrap).
    pub fn delta(&self, current: &Snapshot) -> BTreeMap<&'static str, u64> {
        Counter::ALL
            .iter()
            .map(|&c| (c.name(), current.get(c).saturating_sub(self.get(c))))
            .collect()
    }
}

/// Snapshot every counter now.
pub fn snapshot() -> Snapshot {
    let mut counters = [0u64; NUM_COUNTERS];
    for (slot, atomic) in counters.iter_mut().zip(COUNTERS.iter()) {
        *slot = atomic.load(Ordering::Relaxed);
    }
    Snapshot { counters }
}

/// Zero every counter, histogram and per-thread total. Bench/test
/// setup only — concurrent increments during the reset land on either
/// side nondeterministically.
pub fn reset() {
    for c in &COUNTERS {
        c.store(0, Ordering::Relaxed);
    }
    for h in &HISTS {
        for b in h {
            b.store(0, Ordering::Relaxed);
        }
    }
    THREAD_TASKS.lock().expect("thread-task table poisoned").clear();
}

/// Dump the whole registry (the `--obs-json` payload): counters,
/// histograms (zero buckets elided) and per-thread `par_map` totals.
pub fn to_json() -> Json {
    let counters = Json::Obj(
        Counter::ALL
            .iter()
            .map(|&c| (c.name().to_string(), Json::Num(get(c) as f64)))
            .collect(),
    );
    let hists = Json::Obj(
        Hist::ALL
            .iter()
            .map(|&h| {
                let buckets = Json::Obj(
                    (0..HIST_BUCKETS)
                        .filter_map(|i| {
                            let n = HISTS[h as usize][i].load(Ordering::Relaxed);
                            (n > 0).then(|| (bucket_label(i), Json::Num(n as f64)))
                        })
                        .collect(),
                );
                (h.name().to_string(), buckets)
            })
            .collect(),
    );
    let threads = Json::Obj(
        THREAD_TASKS
            .lock()
            .expect("thread-task table poisoned")
            .iter()
            .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
            .collect(),
    );
    Json::obj(vec![
        ("counters", counters),
        ("histograms", hists),
        ("par_map_threads", threads),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // Counters are process-global and unit tests run in parallel, so
    // every assertion here is a *delta* from a local snapshot, never an
    // absolute value.

    #[test]
    fn add_and_snapshot_deltas() {
        let before = snapshot();
        add(Counter::WhatifCalls, 3);
        incr(Counter::WhatifCalls);
        let after = snapshot();
        assert!(after.get(Counter::WhatifCalls) >= before.get(Counter::WhatifCalls) + 4);
        let delta = before.delta(&after);
        assert!(delta["whatif_calls"] >= 4);
    }

    #[test]
    fn histogram_bucketing() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1 << 40), HIST_BUCKETS - 1);
        assert_eq!(bucket_label(0), "0");
        assert_eq!(bucket_label(1), "1");
        assert_eq!(bucket_label(2), "2-3");
        assert_eq!(bucket_label(3), "4-7");
        assert_eq!(bucket_label(HIST_BUCKETS - 1), "32768+");
    }

    #[test]
    fn worker_task_merge_lands_in_counter_and_table() {
        let before = get(Counter::ParMapTasks);
        note_worker_tasks("metrics-test-worker", 5);
        note_worker_tasks("metrics-test-worker", 2);
        assert!(get(Counter::ParMapTasks) >= before + 7);
        let json = to_json();
        let threads = json.req("par_map_threads").unwrap();
        assert!(threads.req("metrics-test-worker").unwrap().as_f64().unwrap() >= 7.0);
    }

    #[test]
    fn json_dump_names_every_counter_and_histogram() {
        record(Hist::ReratedPerDrain, 3);
        let json = to_json();
        let counters = json.req("counters").unwrap();
        for c in Counter::ALL {
            assert!(counters.get(c.name()).is_some(), "missing counter {}", c.name());
        }
        let hists = json.req("histograms").unwrap();
        for h in Hist::ALL {
            assert!(hists.get(h.name()).is_some(), "missing histogram {}", h.name());
        }
        // the recorded observation shows up in a "2-3" bucket
        assert!(
            hists.req("rerated_per_drain").unwrap().req("2-3").unwrap().as_f64().unwrap() >= 1.0
        );
        // and the dump is valid JSON end to end
        assert_eq!(Json::parse(&json.to_string()).unwrap(), json);
    }
}
