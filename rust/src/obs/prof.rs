//! In-terminal span profiling: fold [`TraceEvent`] spans into a
//! hierarchical total/self-time profile.
//!
//! `--trace-out` already records duration spans (`sim.period`,
//! `bco.bisect_round`, `net.progressive_fill`, `par.worker`,
//! `online.period`, …) but reading them needs an external Chrome-trace
//! viewer. `--profile` folds the same [`MemSink`](crate::obs::trace::MemSink)
//! events into a per-thread call tree printed at process end: every
//! span path with its call count, **total** (wall time inside the span)
//! and **self** time (total minus time attributed to directly nested
//! spans), plus a flat top-N by self time — where the run actually
//! spent its microseconds.
//!
//! Nesting is reconstructed the same way [`chrome_trace_json`]
//! (crate::obs::trace::chrome_trace_json) orders its document: spans
//! are sorted by `(ts, −dur)` per thread (a [`Span`](crate::obs::trace::Span)
//! emits at *close*, so raw sink order is close-time) and a span nests
//! under the deepest still-open span. Aggregation is purely a read of
//! already-recorded events — arming `--profile` shares the passive
//! trace sink and never touches a scheduling outcome.

use crate::obs::trace::{Phase, TraceEvent};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Aggregated numbers for one span path on one thread.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PathStats {
    pub count: u64,
    pub total_us: u64,
    pub self_us: u64,
}

/// One thread's profile: span paths (root-first name chains) to stats,
/// in deterministic path order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ThreadProfile {
    pub tid: u64,
    /// Keyed by the full name chain from a root span down.
    pub paths: BTreeMap<Vec<&'static str>, PathStats>,
    pub spans: u64,
}

/// The folded profile for a whole event set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Profile {
    pub threads: Vec<ThreadProfile>,
    /// Complete spans folded (instants are skipped).
    pub spans: u64,
    pub instants: u64,
}

/// Fold trace events into a [`Profile`].
pub fn profile(events: &[TraceEvent]) -> Profile {
    let mut by_tid: BTreeMap<u64, Vec<&TraceEvent>> = BTreeMap::new();
    let mut instants = 0u64;
    for ev in events {
        match ev.ph {
            Phase::Complete => by_tid.entry(ev.tid).or_default().push(ev),
            Phase::Instant => instants += 1,
        }
    }
    let mut threads = Vec::new();
    let mut spans = 0u64;
    for (tid, mut evs) in by_tid {
        evs.sort_by_key(|e| (e.ts_us, std::cmp::Reverse(e.dur_us)));
        let mut paths: BTreeMap<Vec<&'static str>, PathStats> = BTreeMap::new();
        // open-span stack: (end timestamp, name)
        let mut stack: Vec<(u64, &'static str)> = Vec::new();
        for ev in &evs {
            while stack.last().is_some_and(|&(end, _)| ev.ts_us >= end) {
                stack.pop();
            }
            let mut path: Vec<&'static str> = stack.iter().map(|&(_, n)| n).collect();
            path.push(ev.name);
            let stats = paths.entry(path).or_default();
            stats.count += 1;
            stats.total_us += ev.dur_us;
            stack.push((ev.ts_us.saturating_add(ev.dur_us), ev.name));
        }
        // self = total − Σ direct-children totals (each child instance
        // nests in exactly one parent instance, so the aggregate
        // subtraction is exact)
        let child_totals: BTreeMap<Vec<&'static str>, u64> = paths
            .iter()
            .filter(|(path, _)| path.len() > 1)
            .map(|(path, stats)| (path[..path.len() - 1].to_vec(), stats.total_us))
            .fold(BTreeMap::new(), |mut acc, (parent, total)| {
                *acc.entry(parent).or_default() += total;
                acc
            });
        for (path, stats) in &mut paths {
            let children = child_totals.get(path).copied().unwrap_or(0);
            stats.self_us = stats.total_us.saturating_sub(children);
        }
        let thread_spans = evs.len() as u64;
        spans += thread_spans;
        threads.push(ThreadProfile { tid, paths, spans: thread_spans });
    }
    Profile { threads, spans, instants }
}

fn fmt_us(us: u64) -> String {
    if us >= 1_000_000 {
        format!("{:.2}s", us as f64 / 1_000_000.0)
    } else if us >= 1_000 {
        format!("{:.2}ms", us as f64 / 1_000.0)
    } else {
        format!("{us}us")
    }
}

impl Profile {
    pub fn is_empty(&self) -> bool {
        self.spans == 0
    }

    /// Render the profile as indented text: per-thread call trees plus
    /// a flat top-`top_n` table by self time.
    pub fn render(&self, top_n: usize) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "profile: {} span(s), {} instant event(s), {} thread(s)",
            self.spans,
            self.instants,
            self.threads.len()
        );
        if self.is_empty() {
            let _ = writeln!(
                out,
                "  (no duration spans recorded — spans are emitted by the sim/online \
                 rate loops, bisection and progressive fill)"
            );
            return out;
        }
        for thread in &self.threads {
            let _ = writeln!(out, "\nthread {} ({} spans)", thread.tid, thread.spans);
            let name_width = thread
                .paths
                .keys()
                .map(|p| 2 * p.len() + p.last().map_or(0, |n| n.len()))
                .max()
                .unwrap_or(0);
            // BTreeMap path order is exactly pre-order over the tree
            for (path, stats) in &thread.paths {
                let name = path.last().copied().unwrap_or("?");
                let indented = format!("{}{}", "  ".repeat(path.len()), name);
                let _ = writeln!(
                    out,
                    "{indented:<width$}  {count:>7}x  total {total:>9}  self {slf:>9}",
                    width = name_width + 2,
                    count = stats.count,
                    total = fmt_us(stats.total_us),
                    slf = fmt_us(stats.self_us),
                );
            }
            let mut flat: BTreeMap<&'static str, PathStats> = BTreeMap::new();
            for (path, stats) in &thread.paths {
                if let Some(&name) = path.last() {
                    let agg = flat.entry(name).or_default();
                    agg.count += stats.count;
                    agg.total_us += stats.total_us;
                    agg.self_us += stats.self_us;
                }
            }
            let mut ranked: Vec<(&'static str, PathStats)> = flat.into_iter().collect();
            ranked.sort_by_key(|&(name, s)| (std::cmp::Reverse(s.self_us), name));
            let _ = writeln!(out, "  top {} by self time:", top_n.min(ranked.len()));
            for (name, s) in ranked.into_iter().take(top_n) {
                let _ = writeln!(
                    out,
                    "    {:<24} {:>9} self ({} calls)",
                    name,
                    fmt_us(s.self_us),
                    s.count
                );
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &'static str, ts: u64, dur: u64, tid: u64) -> TraceEvent {
        TraceEvent { name, cat: "test", ph: Phase::Complete, ts_us: ts, dur_us: dur, tid, args: Vec::new() }
    }

    fn instant(name: &'static str, ts: u64, tid: u64) -> TraceEvent {
        TraceEvent { name, cat: "test", ph: Phase::Instant, ts_us: ts, dur_us: 0, tid, args: Vec::new() }
    }

    #[test]
    fn nesting_and_self_time() {
        // online.run [0,100) containing two online.period spans — in
        // close-time emission order, the way a MemSink records them
        let events = vec![
            span("online.period", 10, 20, 1),
            span("online.period", 40, 30, 1),
            span("online.run", 0, 100, 1),
            instant("job.arrive", 5, 1),
        ];
        let p = profile(&events);
        assert_eq!(p.spans, 3);
        assert_eq!(p.instants, 1);
        assert_eq!(p.threads.len(), 1);
        let paths = &p.threads[0].paths;
        let run = &paths[&vec!["online.run"]];
        assert_eq!((run.count, run.total_us, run.self_us), (1, 100, 50));
        let period = &paths[&vec!["online.run", "online.period"]];
        assert_eq!((period.count, period.total_us, period.self_us), (2, 50, 50));
    }

    #[test]
    fn sibling_spans_do_not_nest() {
        // back-to-back spans where the second starts exactly at the
        // first's end — siblings, not parent/child
        let events = vec![span("a", 0, 10, 1), span("b", 10, 10, 1)];
        let p = profile(&events);
        let paths = &p.threads[0].paths;
        assert_eq!(paths.len(), 2);
        assert!(paths.contains_key(&vec!["a"]));
        assert!(paths.contains_key(&vec!["b"]));
    }

    #[test]
    fn threads_fold_independently() {
        let events = vec![
            span("par.worker", 0, 50, 2),
            span("par.worker", 0, 40, 3),
            span("sim.run", 0, 100, 1),
        ];
        let p = profile(&events);
        assert_eq!(p.threads.len(), 3);
        assert_eq!(p.threads.iter().map(|t| t.tid).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn deep_chains_unwind_correctly() {
        // a [0,100) > b [10,40) > c [20,10); then d [60,20) back under a
        let events = vec![
            span("c", 20, 10, 1),
            span("b", 10, 40, 1),
            span("d", 60, 20, 1),
            span("a", 0, 100, 1),
        ];
        let p = profile(&events);
        let paths = &p.threads[0].paths;
        assert_eq!(paths[&vec!["a"]].self_us, 100 - 40 - 20);
        assert_eq!(paths[&vec!["a", "b"]].self_us, 40 - 10);
        assert_eq!(paths[&vec!["a", "b", "c"]].total_us, 10);
        assert_eq!(paths[&vec!["a", "d"]].total_us, 20);
    }

    #[test]
    fn render_shapes_and_empty_profile() {
        let p = profile(&[]);
        assert!(p.is_empty());
        assert!(p.render(5).contains("no duration spans"));
        let events = vec![span("online.period", 10, 20, 1), span("online.run", 0, 100, 1)];
        let text = profile(&events).render(5);
        assert!(text.contains("thread 1 (2 spans)"));
        assert!(text.contains("online.run"));
        assert!(text.contains("top 2 by self time:"));
        assert!(text.contains("self"));
    }
}
