//! Decision-audit records: *why* the online scheduler did what it did.
//!
//! Every consequential decision on the online path — an admission
//! rejection, a placement choice, a migration commit or abort — can be
//! captured as a [`Decision`] with the numbers that drove it (the
//! projected bottleneck vs θ, the winning candidate's link score vs the
//! runner-up, which migration guard fired). Like the trace facade this
//! is a process-global recorder, disarmed by default (one relaxed load
//! per hook) and passive when armed: records are *copies* of values the
//! scheduler already computed, never inputs to it.
//!
//! `online --explain <path>` arms the recorder for the run and writes
//! the drained records as JSON (or a human-readable report for `-`).

use crate::jobs::JobId;
use crate::util::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Why an admission was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The job wants more GPUs than the cluster has.
    TooLarge,
    /// The pending queue hit its cap.
    QueueFull,
    /// The projected bottleneck degree exceeded θ.
    Theta,
}

impl RejectReason {
    pub fn name(self) -> &'static str {
        match self {
            RejectReason::TooLarge => "too_large",
            RejectReason::QueueFull => "queue_full",
            RejectReason::Theta => "theta",
        }
    }
}

/// Which migration guard stopped a candidate move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationGuard {
    /// No feasible candidate placement existed.
    NoCandidate,
    /// Guard 1: the candidate's effective degree is not a strict
    /// improvement over the current bottleneck.
    StrictImprovement,
    /// Guard 2: the rate gain does not pay for the restart stall
    /// within the job's remaining work.
    PaysForItself,
}

impl MigrationGuard {
    pub fn name(self) -> &'static str {
        match self {
            MigrationGuard::NoCandidate => "no_candidate",
            MigrationGuard::StrictImprovement => "strict_improvement",
            MigrationGuard::PaysForItself => "pays_for_itself",
        }
    }
}

/// Which guard deferred a failed job's re-placement this attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryGuard {
    /// Migration-armed recovery: no feasible placement existed on the
    /// surviving GPUs.
    NoCapacity,
    /// Wait-only (rigid) recovery: the job's original gang is not yet
    /// fully healthy and free.
    HomeDown,
}

impl RecoveryGuard {
    pub fn name(self) -> &'static str {
        match self {
            RecoveryGuard::NoCapacity => "no_capacity",
            RecoveryGuard::HomeDown => "home_down",
        }
    }
}

/// One audited decision.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// Admission rejection: `projected` is the effective bottleneck
    /// degree the candidate placement would have seen (0 when the
    /// rejection happened before a placement probe, e.g. queue-full).
    Reject { job: JobId, at: u64, reason: RejectReason, projected: f64, theta: f64 },
    /// Placement choice: the winning candidate's link score against the
    /// runner-up (`None` when only one candidate group existed).
    Placement {
        job: JobId,
        at: u64,
        chosen_score: f64,
        runner_up: Option<f64>,
        candidates: usize,
    },
    /// A migration that was committed.
    MigrationCommit {
        job: JobId,
        at: u64,
        from_effective: f64,
        to_effective: f64,
        restart_slots: u64,
    },
    /// A migration candidate abandoned by `guard`.
    MigrationAbort {
        job: JobId,
        at: u64,
        guard: MigrationGuard,
        current_effective: f64,
        candidate_effective: f64,
    },
    /// A fault killed this job's gang (`server` is the crashed/degraded
    /// component's server; `workers` the gang size that lost its GPUs).
    /// Paired one-to-one with `Failed` events.
    FaultKill { job: JobId, at: u64, server: usize, workers: usize },
    /// A failed job was re-placed on surviving GPUs after waiting
    /// `wait_slots` in the recovery queue; `effective` is the bottleneck
    /// degree of the new placement. Paired one-to-one with `Recovered`
    /// events.
    RecoveryPlace { job: JobId, at: u64, wait_slots: u64, effective: f64 },
    /// A recovery attempt for this job was deferred by `guard`;
    /// `wait_slots` is the starvation so far.
    RecoveryDefer { job: JobId, at: u64, guard: RecoveryGuard, wait_slots: u64 },
    /// A fabric link's capacity changed: degraded to `factor` of pristine
    /// (1.0 = restored). Fabric-level — carries no real job id. Paired
    /// one-to-one with `Degraded` events.
    LinkChange { link: usize, at: u64, factor: f64 },
}

impl Decision {
    pub fn job(&self) -> JobId {
        match *self {
            Decision::Reject { job, .. }
            | Decision::Placement { job, .. }
            | Decision::MigrationCommit { job, .. }
            | Decision::MigrationAbort { job, .. }
            | Decision::FaultKill { job, .. }
            | Decision::RecoveryPlace { job, .. }
            | Decision::RecoveryDefer { job, .. } => job,
            // fabric-level: the sentinel the event log uses for link events
            Decision::LinkChange { .. } => JobId(usize::MAX),
        }
    }

    pub fn at(&self) -> u64 {
        match *self {
            Decision::Reject { at, .. }
            | Decision::Placement { at, .. }
            | Decision::MigrationCommit { at, .. }
            | Decision::MigrationAbort { at, .. }
            | Decision::FaultKill { at, .. }
            | Decision::RecoveryPlace { at, .. }
            | Decision::RecoveryDefer { at, .. }
            | Decision::LinkChange { at, .. } => at,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Decision::Reject { .. } => "reject",
            Decision::Placement { .. } => "placement",
            Decision::MigrationCommit { .. } => "migration_commit",
            Decision::MigrationAbort { .. } => "migration_abort",
            Decision::FaultKill { .. } => "fault_kill",
            Decision::RecoveryPlace { .. } => "recovery_place",
            Decision::RecoveryDefer { .. } => "recovery_defer",
            Decision::LinkChange { .. } => "link_change",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("kind", Json::Str(self.kind().to_string()))];
        // fabric-level records carry no job id (the sentinel is an
        // in-memory convention, not a serialisable value)
        if !matches!(self, Decision::LinkChange { .. }) {
            pairs.push(("job", Json::Num(self.job().0 as f64)));
        }
        pairs.push(("at", Json::Num(self.at() as f64)));
        match *self {
            Decision::Reject { reason, projected, theta, .. } => {
                pairs.push(("reason", Json::Str(reason.name().to_string())));
                pairs.push(("projected", Json::Num(projected)));
                pairs.push(("theta", Json::Num(theta)));
            }
            Decision::Placement { chosen_score, runner_up, candidates, .. } => {
                pairs.push(("chosen_score", Json::Num(chosen_score)));
                pairs.push((
                    "runner_up",
                    runner_up.map(Json::Num).unwrap_or(Json::Null),
                ));
                pairs.push(("candidates", Json::Num(candidates as f64)));
            }
            Decision::MigrationCommit { from_effective, to_effective, restart_slots, .. } => {
                pairs.push(("from_effective", Json::Num(from_effective)));
                pairs.push(("to_effective", Json::Num(to_effective)));
                pairs.push(("restart_slots", Json::Num(restart_slots as f64)));
            }
            Decision::MigrationAbort { guard, current_effective, candidate_effective, .. } => {
                pairs.push(("guard", Json::Str(guard.name().to_string())));
                pairs.push(("current_effective", Json::Num(current_effective)));
                pairs.push(("candidate_effective", Json::Num(candidate_effective)));
            }
            Decision::FaultKill { server, workers, .. } => {
                pairs.push(("server", Json::Num(server as f64)));
                pairs.push(("workers", Json::Num(workers as f64)));
            }
            Decision::RecoveryPlace { wait_slots, effective, .. } => {
                pairs.push(("wait_slots", Json::Num(wait_slots as f64)));
                pairs.push(("effective", Json::Num(effective)));
            }
            Decision::RecoveryDefer { guard, wait_slots, .. } => {
                pairs.push(("guard", Json::Str(guard.name().to_string())));
                pairs.push(("wait_slots", Json::Num(wait_slots as f64)));
            }
            Decision::LinkChange { link, factor, .. } => {
                pairs.push(("link", Json::Num(link as f64)));
                pairs.push(("factor", Json::Num(factor)));
            }
        }
        Json::obj(pairs)
    }

    /// One human-readable report line.
    pub fn render(&self) -> String {
        match *self {
            Decision::Reject { job, at, reason, projected, theta } => format!(
                "t={at} {job}: REJECT ({}) projected effective degree {projected:.2} vs θ={theta}",
                reason.name()
            ),
            Decision::Placement { job, at, chosen_score, runner_up, candidates } => {
                match runner_up {
                    Some(r) => format!(
                        "t={at} {job}: PLACE score {chosen_score:.2} beat runner-up {r:.2} \
                         ({candidates} candidates)"
                    ),
                    None => format!(
                        "t={at} {job}: PLACE score {chosen_score:.2} (sole candidate)"
                    ),
                }
            }
            Decision::MigrationCommit { job, at, from_effective, to_effective, restart_slots } => {
                format!(
                    "t={at} {job}: MIGRATE effective {from_effective:.2} -> {to_effective:.2} \
                     (restart {restart_slots} slots)"
                )
            }
            Decision::MigrationAbort { job, at, guard, current_effective, candidate_effective } => {
                format!(
                    "t={at} {job}: KEEP ({} guard) current {current_effective:.2} vs candidate \
                     {candidate_effective:.2}",
                    guard.name()
                )
            }
            Decision::FaultKill { job, at, server, workers } => format!(
                "t={at} {job}: KILLED by fault on server {server} ({workers} workers lost)"
            ),
            Decision::RecoveryPlace { job, at, wait_slots, effective } => format!(
                "t={at} {job}: RECOVER after {wait_slots} slots, effective degree {effective:.2}"
            ),
            Decision::RecoveryDefer { job, at, guard, wait_slots } => format!(
                "t={at} {job}: WAIT ({} guard) {wait_slots} slots in recovery queue",
                guard.name()
            ),
            Decision::LinkChange { link, at, factor } => {
                if factor >= 1.0 {
                    format!("t={at} l{link}: RESTORED to pristine capacity")
                } else {
                    format!("t={at} l{link}: DEGRADED to {factor:.2} of capacity")
                }
            }
        }
    }
}

// ---- the global recorder -------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);
static RECORDS: Mutex<Vec<Decision>> = Mutex::new(Vec::new());

/// Arm the recorder (clears any previous records).
pub fn arm() {
    RECORDS.lock().expect("explain log poisoned").clear();
    ARMED.store(true, Ordering::Release);
}

/// Disarm and drain: returns everything recorded since [`arm`].
pub fn disarm() -> Vec<Decision> {
    ARMED.store(false, Ordering::Release);
    std::mem::take(&mut *RECORDS.lock().expect("explain log poisoned"))
}

/// Whether the recorder is armed — the hooks' fast path.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Append one decision if armed (drops silently otherwise).
pub fn record(d: Decision) {
    if !armed() {
        return;
    }
    RECORDS.lock().expect("explain log poisoned").push(d);
}

/// JSON report over a drained record set.
pub fn to_json(records: &[Decision]) -> Json {
    Json::obj(vec![
        ("decisions", Json::arr(records.iter().map(Decision::to_json).collect())),
        ("count", Json::Num(records.len() as f64)),
    ])
}

/// Human-readable report (one line per decision plus a tally).
pub fn render_report(records: &[Decision]) -> String {
    let mut out = String::new();
    let mut tally: std::collections::BTreeMap<&'static str, usize> = Default::default();
    for d in records {
        out.push_str(&d.render());
        out.push('\n');
        *tally.entry(d.kind()).or_insert(0) += 1;
    }
    out.push_str(&format!("{} decisions audited", records.len()));
    for (k, n) in tally {
        out.push_str(&format!("; {k}: {n}"));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global; these tests only exercise the
    // record types and report rendering directly, leaving arm/disarm to
    // the single-threaded integration test (tests/obs_passivity.rs).

    fn samples() -> Vec<Decision> {
        vec![
            Decision::Reject {
                job: JobId(3),
                at: 10,
                reason: RejectReason::Theta,
                projected: 4.0,
                theta: 3.0,
            },
            Decision::Placement {
                job: JobId(4),
                at: 12,
                chosen_score: 1.0,
                runner_up: Some(2.0),
                candidates: 3,
            },
            Decision::MigrationCommit {
                job: JobId(4),
                at: 20,
                from_effective: 3.0,
                to_effective: 1.0,
                restart_slots: 2,
            },
            Decision::MigrationAbort {
                job: JobId(5),
                at: 20,
                guard: MigrationGuard::PaysForItself,
                current_effective: 2.0,
                candidate_effective: 1.0,
            },
            Decision::FaultKill { job: JobId(4), at: 25, server: 1, workers: 8 },
            Decision::RecoveryDefer {
                job: JobId(4),
                at: 25,
                guard: RecoveryGuard::NoCapacity,
                wait_slots: 0,
            },
            Decision::RecoveryPlace { job: JobId(4), at: 31, wait_slots: 6, effective: 2.0 },
            Decision::LinkChange { link: 3, at: 40, factor: 0.25 },
        ]
    }

    #[test]
    fn disarmed_record_is_dropped() {
        assert!(!armed());
        record(samples().remove(0));
        // arm() clears, so an immediately-armed drain sees nothing
        arm();
        assert!(disarm().is_empty());
    }

    #[test]
    fn json_report_carries_the_driving_numbers() {
        let records = samples();
        let json = to_json(&records);
        assert_eq!(json.req("count").unwrap().as_u64().unwrap(), 8);
        let rows = json.req("decisions").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].req("kind").unwrap().as_str().unwrap(), "reject");
        assert_eq!(rows[0].req("reason").unwrap().as_str().unwrap(), "theta");
        assert_eq!(rows[0].req("projected").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(rows[1].req("runner_up").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(rows[2].req("restart_slots").unwrap().as_u64().unwrap(), 2);
        assert_eq!(rows[3].req("guard").unwrap().as_str().unwrap(), "pays_for_itself");
        assert_eq!(rows[4].req("kind").unwrap().as_str().unwrap(), "fault_kill");
        assert_eq!(rows[4].req("server").unwrap().as_u64().unwrap(), 1);
        assert_eq!(rows[4].req("workers").unwrap().as_u64().unwrap(), 8);
        assert_eq!(rows[5].req("guard").unwrap().as_str().unwrap(), "no_capacity");
        assert_eq!(rows[6].req("wait_slots").unwrap().as_u64().unwrap(), 6);
        assert_eq!(rows[6].req("effective").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(rows[7].req("kind").unwrap().as_str().unwrap(), "link_change");
        assert_eq!(rows[7].req("link").unwrap().as_u64().unwrap(), 3);
        assert_eq!(rows[7].req("factor").unwrap().as_f64().unwrap(), 0.25);
        // fabric-level records carry no job id
        assert!(rows[7].get("job").is_none());
        // dump parses back
        assert_eq!(Json::parse(&json.to_string()).unwrap(), json);
    }

    #[test]
    fn text_report_tallies_by_kind() {
        let report = render_report(&samples());
        assert!(report.contains("REJECT (theta)"));
        assert!(report.contains("MIGRATE effective 3.00 -> 1.00"));
        assert!(report.contains("KEEP (pays_for_itself guard)"));
        assert!(report.contains("KILLED by fault on server 1"));
        assert!(report.contains("WAIT (no_capacity guard)"));
        assert!(report.contains("RECOVER after 6 slots"));
        assert!(report.contains("DEGRADED to 0.25 of capacity"));
        assert!(report.contains("8 decisions audited"));
        assert!(report.contains("reject: 1"));
        assert!(report.contains("fault_kill: 1"));
        // restore line
        let restore = Decision::LinkChange { link: 0, at: 9, factor: 1.0 };
        assert!(restore.render().contains("RESTORED to pristine capacity"));
    }
}
