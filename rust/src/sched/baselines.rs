//! Baseline scheduling policies from the paper's §7 evaluation:
//! First-Fit (FF) [17], List-Scheduling (LS) [17], Random (RAND) [19] —
//! plus a GADGET-style locality-first comparator [22] that packs each ring
//! into the fewest servers (it assumes reserved bandwidth, i.e. it is
//! *blind* to contention).

use super::accounting::GpuLedger;
use super::estimator::Estimator;
use super::{Plan, PlannedJob};
use crate::cluster::{Cluster, GpuId, JobPlacement};
use crate::contention::ContentionParams;
use crate::jobs::JobSpec;
use crate::util::Rng;
use crate::Result;
use anyhow::bail;

/// Placement rule of one baseline for a single job; `None` = infeasible
/// under the limit θ.
type PlaceFn<'x> = dyn FnMut(&Cluster, &GpuLedger, &JobSpec, f64, f64) -> Option<Vec<GpuId>> + 'x;

/// Schedule all jobs in arrival order with a per-GPU execution-time limit
/// θ, using `place` for each job. Returns `None` if any job is infeasible.
fn try_schedule_with(
    cluster: &Cluster,
    jobs: &[JobSpec],
    est: &Estimator<'_>,
    theta: f64,
    place: &mut PlaceFn<'_>,
) -> Option<(f64, Vec<PlannedJob>)> {
    let mut ledger = GpuLedger::new(cluster);
    let mut entries = Vec::with_capacity(jobs.len());
    let mut makespan = 0.0f64;
    for job in jobs {
        let rho = est.rho(job);
        let gpus = place(cluster, &ledger, job, rho.rho_lower, theta)?;
        debug_assert_eq!(gpus.len(), job.gpus);
        let (start, finish) = ledger.commit(&gpus, rho.rho_lower);
        makespan = makespan.max(finish);
        entries.push(PlannedJob {
            job: job.id,
            placement: JobPlacement::new(gpus),
            est_start: start,
            est_finish: finish,
        });
    }
    Some((makespan, entries))
}

/// Bisect the tightest feasible θ ∈ [1, T] for a policy (the paper defines
/// a per-policy limit θ_u^f) and return the best plan found. Candidates
/// are scored by *evaluating* them through the contention model (Eq. 6–9)
/// — same Fig. 3 search-evaluate loop the SJF-BCO implementation uses —
/// so feasibility ("fits the horizon") refers to the realized makespan.
fn bisect(
    name: &str,
    cluster: &Cluster,
    jobs: &[JobSpec],
    params: &ContentionParams,
    horizon: u64,
    place: &mut PlaceFn<'_>,
) -> Result<Plan> {
    validate(cluster, jobs)?;
    if jobs.is_empty() {
        return Ok(Plan::new(name, Vec::new()));
    }
    let est = Estimator::new(cluster, params);
    // §Perf: one PlanScorer for the whole θ bisection — candidates replay
    // on the tracker + dirty-set engine with scratch reused per candidate
    // (same unification as SJF-BCO's κ sweep).
    let mut scorer = crate::sim::PlanScorer::new(cluster, jobs, params);
    let (mut left, mut right) = (1u64, horizon);
    let mut best: Option<(f64, Plan)> = None;
    while left <= right {
        let theta = (left + right) / 2;
        match try_schedule_with(cluster, jobs, &est, theta as f64, place) {
            Some((_ledger_makespan, entries)) => {
                let mut plan = Plan::new(name, entries);
                plan.theta = Some(theta as f64);
                let makespan = scorer.makespan(&plan) as f64;
                if makespan < horizon as f64 {
                    // ties update: prefer the tightest feasible θ
                    if best.as_ref().map_or(true, |(m, _)| makespan <= *m) {
                        best = Some((makespan, plan));
                    }
                    right = theta - 1;
                } else {
                    left = theta + 1;
                }
            }
            None => left = theta + 1,
        }
    }
    match best {
        Some((_, plan)) => Ok(plan),
        None => bail!("{name}: no feasible schedule within horizon T={horizon}"),
    }
}

fn validate(cluster: &Cluster, jobs: &[JobSpec]) -> Result<()> {
    for j in jobs {
        if let Err(e) = j.validate() {
            bail!("invalid job: {e}");
        }
        if j.gpus > cluster.num_gpus() {
            bail!("{} requests {} GPUs > cluster size {}", j.id, j.gpus, cluster.num_gpus());
        }
    }
    Ok(())
}

/// **First-Fit**: walk servers in id order, GPUs in index order; take the
/// first `G_j` eligible GPUs. Packs jobs into the lowest-numbered servers.
pub fn first_fit(
    cluster: &Cluster,
    jobs: &[JobSpec],
    params: &ContentionParams,
    horizon: u64,
) -> Result<Plan> {
    bisect("first-fit", cluster, jobs, params, horizon, &mut |c, led, job, rho, theta| {
        let mut picked = Vec::with_capacity(job.gpus);
        for g in c.all_gpus() {
            if led.eligible(g, rho, theta) {
                picked.push(g);
                if picked.len() == job.gpus {
                    return Some(picked);
                }
            }
        }
        None
    })
}

/// **List-Scheduling**: take the `G_j` eligible GPUs with the least
/// accumulated execution time, cluster-wide. Balances load but may spread
/// rings over many servers (high overhead — paper §7).
pub fn list_scheduling(
    cluster: &Cluster,
    jobs: &[JobSpec],
    params: &ContentionParams,
    horizon: u64,
) -> Result<Plan> {
    bisect("list-scheduling", cluster, jobs, params, horizon, &mut |c, led, job, rho, theta| {
        let mut eligible: Vec<GpuId> =
            c.all_gpus().filter(|g| led.eligible(*g, rho, theta)).collect();
        if eligible.len() < job.gpus {
            return None;
        }
        // §Perf: top-G_j selection instead of a full sort
        let cmp = |a: &GpuId, b: &GpuId| {
            led.busy(*a)
                .partial_cmp(&led.busy(*b))
                .unwrap()
                .then(a.server.cmp(&b.server))
                .then(a.index.cmp(&b.index))
        };
        if eligible.len() > job.gpus {
            eligible.select_nth_unstable_by(job.gpus - 1, cmp);
            eligible.truncate(job.gpus);
        }
        Some(eligible)
    })
}

/// **Random**: uniformly random eligible GPUs with the loose limit
/// θ = T (paper §7 sets θ_u^RAND = T to avoid unbounded retries).
pub fn random_policy(
    cluster: &Cluster,
    jobs: &[JobSpec],
    params: &ContentionParams,
    horizon: u64,
    seed: u64,
) -> Result<Plan> {
    validate(cluster, jobs)?;
    let est = Estimator::new(cluster, params);
    let mut rng = Rng::seed_from_u64(seed);
    let theta = horizon as f64;
    let mut place = |c: &Cluster, led: &GpuLedger, job: &JobSpec, rho: f64, th: f64| {
        let mut eligible: Vec<GpuId> =
            c.all_gpus().filter(|g| led.eligible(*g, rho, th)).collect();
        if eligible.len() < job.gpus {
            return None;
        }
        rng.shuffle(&mut eligible);
        Some(eligible[..job.gpus].to_vec())
    };
    match try_schedule_with(cluster, jobs, &est, theta, &mut place) {
        Some((_, entries)) => {
            let mut plan = Plan::new("random", entries);
            plan.theta = Some(theta);
            Ok(plan)
        }
        None => bail!("random: no feasible schedule within horizon T={horizon}"),
    }
}

/// **GADGET-style locality-first** [22]: pack each ring into the fewest
/// servers (best-fit into a single server when possible; otherwise
/// greedily take the servers with the most eligible GPUs, rack-major when
/// the fabric has a rack tier so the ring also crosses the fewest ToR
/// uplinks). GADGET assumes per-job reserved bandwidth, so it optimises
/// locality only and is blind to the contention its placements cause.
pub fn gadget_locality(
    cluster: &Cluster,
    jobs: &[JobSpec],
    params: &ContentionParams,
    horizon: u64,
) -> Result<Plan> {
    bisect("gadget-locality", cluster, jobs, params, horizon, &mut |c, led, job, rho, theta| {
        // eligible GPUs grouped per server
        let mut per_server: Vec<(usize, Vec<GpuId>)> = c
            .server_ids()
            .map(|s| {
                let mut gs: Vec<GpuId> =
                    c.gpus_of(s).filter(|g| led.eligible(*g, rho, theta)).collect();
                gs.sort_by(|a, b| led.busy(*a).partial_cmp(&led.busy(*b)).unwrap());
                (s.0, gs)
            })
            .collect();
        // best fit: the single server with the fewest eligible GPUs that
        // still fits the whole ring
        if let Some((_, gs)) = per_server
            .iter()
            .filter(|(_, gs)| gs.len() >= job.gpus)
            .min_by_key(|(s, gs)| (gs.len(), *s))
        {
            return Some(gs[..job.gpus].to_vec());
        }
        // Otherwise minimise span: fill pod-major (3-tier fabrics), then
        // from the rack with the most eligible GPUs (rack tiers only —
        // flat fabrics skip straight to the seed rule), and within it the
        // fullest servers first — the ring crosses the fewest pod, then
        // ToR, uplinks.
        let topo = c.topology();
        let rack_eligible: Option<Vec<usize>> = topo.has_racks().then(|| {
            let mut re = vec![0usize; topo.num_racks()];
            for (s, gs) in &per_server {
                re[topo.rack_index(crate::cluster::ServerId(*s))] += gs.len();
            }
            re
        });
        let pod_eligible: Option<Vec<usize>> =
            (topo.has_pods() && rack_eligible.is_some()).then(|| {
                let re = rack_eligible.as_ref().expect("guarded");
                let mut pe = vec![0usize; topo.num_pods()];
                for (r, &n) in re.iter().enumerate() {
                    pe[topo.pod_of_rack(r)] += n;
                }
                pe
            });
        per_server.sort_by(|a, b| {
            let pod_key = match &pod_eligible {
                Some(pe) => {
                    let (pa, pb) = (
                        topo.pod_index(crate::cluster::ServerId(a.0)),
                        topo.pod_index(crate::cluster::ServerId(b.0)),
                    );
                    pe[pb].cmp(&pe[pa]).then(pa.cmp(&pb))
                }
                None => std::cmp::Ordering::Equal,
            };
            let rack_key = match &rack_eligible {
                Some(re) => {
                    let (ra, rb) = (
                        topo.rack_index(crate::cluster::ServerId(a.0)),
                        topo.rack_index(crate::cluster::ServerId(b.0)),
                    );
                    re[rb].cmp(&re[ra]).then(ra.cmp(&rb))
                }
                None => std::cmp::Ordering::Equal,
            };
            pod_key.then(rack_key).then(b.1.len().cmp(&a.1.len())).then(a.0.cmp(&b.0))
        });
        let mut picked = Vec::with_capacity(job.gpus);
        for (_, gs) in per_server {
            for g in gs {
                picked.push(g);
                if picked.len() == job.gpus {
                    return Some(picked);
                }
            }
        }
        None
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerId;
    use crate::jobs::JobId;
    use crate::trace::TraceGenerator;

    fn setup() -> (Cluster, ContentionParams, Vec<JobSpec>) {
        (
            Cluster::uniform(4, 8, 1.0, 25.0),
            ContentionParams::paper(),
            TraceGenerator::tiny().generate(7),
        )
    }

    #[test]
    fn all_baselines_schedule_everything() {
        let (c, p, jobs) = setup();
        for plan in [
            first_fit(&c, &jobs, &p, 100_000).unwrap(),
            list_scheduling(&c, &jobs, &p, 100_000).unwrap(),
            random_policy(&c, &jobs, &p, 100_000, 3).unwrap(),
            gadget_locality(&c, &jobs, &p, 100_000).unwrap(),
        ] {
            assert_eq!(plan.entries.len(), jobs.len(), "{}", plan.policy);
            for e in &plan.entries {
                let spec = jobs.iter().find(|j| j.id == e.job).unwrap();
                assert_eq!(e.placement.num_workers(), spec.gpus);
            }
        }
    }

    #[test]
    fn first_fit_packs_low_servers() {
        let (c, p, _) = setup();
        let jobs = vec![JobSpec::synthetic(JobId(0), 4)];
        let plan = first_fit(&c, &jobs, &p, 100_000).unwrap();
        let placement = &plan.entries[0].placement;
        assert_eq!(placement.span(), 1);
        assert_eq!(placement.gpus_on(ServerId(0)), 4);
    }

    #[test]
    fn gadget_minimises_span() {
        let (c, p, _) = setup();
        // 8-GPU job on 8-GPU servers: gadget must use exactly one server
        let jobs = vec![JobSpec::synthetic(JobId(0), 8)];
        let plan = gadget_locality(&c, &jobs, &p, 100_000).unwrap();
        assert_eq!(plan.entries[0].placement.span(), 1);
        // 12-GPU job: minimal span is 2
        let jobs = vec![JobSpec::synthetic(JobId(0), 12)];
        let plan = gadget_locality(&c, &jobs, &p, 100_000).unwrap();
        assert_eq!(plan.entries[0].placement.span(), 2);
    }

    #[test]
    fn gadget_fills_pod_major_on_three_tier_fabrics() {
        use crate::topology::Topology;
        let p = ContentionParams::paper();
        // capacities [4,4,2,2,3,3,3,3], racks of 2, pods of 2 racks:
        // rack capacities [8,4,6,6], pod capacities [12,12]. A 10-GPU
        // ring filled rack-major would take rack 0 (8 eligible) then
        // rack 2 (6) — crossing into pod 1. Pod-major fill stays inside
        // pod 0: rack 0 (8) + rack 1 (2 of 4).
        let c = Cluster::new(&[4, 4, 2, 2, 3, 3, 3, 3], 1.0, 25.0)
            .with_topology(Topology::pods(8, 2, 2, 2.0, 2.0));
        let jobs = vec![JobSpec::synthetic(JobId(0), 10)];
        let plan = gadget_locality(&c, &jobs, &p, 100_000).unwrap();
        let placement = &plan.entries[0].placement;
        assert!(
            placement.servers().all(|s| s.0 <= 3),
            "ring must stay below pod 0's switch, got {:?}",
            placement.servers().collect::<Vec<_>>()
        );
        // the rack-only twin reproduces the old rack-major fill, which
        // crosses pods' worth of servers (rack 0 then rack 2)
        let racked = Cluster::new(&[4, 4, 2, 2, 3, 3, 3, 3], 1.0, 25.0)
            .with_topology(Topology::racks(8, 2, 2.0));
        let plan = gadget_locality(&racked, &jobs, &p, 100_000).unwrap();
        assert!(
            plan.entries[0].placement.servers().any(|s| s.0 >= 4),
            "rack-major fill reaches servers 4+"
        );
    }

    #[test]
    fn random_is_seed_deterministic() {
        let (c, p, jobs) = setup();
        let a = random_policy(&c, &jobs, &p, 100_000, 11).unwrap();
        let b = random_policy(&c, &jobs, &p, 100_000, 11).unwrap();
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.placement, y.placement);
        }
    }

    #[test]
    fn infeasible_horizon_errors() {
        let (c, p, _) = setup();
        // horizon 1 slot but jobs need many slots of execution time
        let mut jobs = TraceGenerator::tiny().generate(0);
        for j in &mut jobs {
            j.iterations = 100_000;
        }
        assert!(first_fit(&c, &jobs, &p, 1).is_err());
        assert!(random_policy(&c, &jobs, &p, 1, 0).is_err());
    }

    #[test]
    fn ls_balances_busy_time() {
        let (c, p, _) = setup();
        // many 1-GPU jobs: LS should spread them across all GPUs
        let jobs: Vec<_> = (0..32).map(|i| JobSpec::synthetic(JobId(i), 1)).collect();
        let plan = list_scheduling(&c, &jobs, &p, 100_000).unwrap();
        let mut used = std::collections::HashSet::new();
        for e in &plan.entries {
            used.insert(e.placement.gpus()[0].global);
        }
        assert_eq!(used.len(), 32, "LS uses every GPU once before reusing");
    }
}
