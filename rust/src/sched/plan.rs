//! Schedule plans: the output of every scheduling policy.

use crate::cluster::JobPlacement;
use crate::jobs::JobId;

/// One job's entry in a plan: its placement (`y_j`, fixed over the job's
/// lifetime under gang scheduling) and the planner's *estimates* of start
/// and finish (in slots) from per-GPU execution-time accounting `U_s^g`.
#[derive(Debug, Clone)]
pub struct PlannedJob {
    pub job: JobId,
    pub placement: JobPlacement,
    /// Estimated start slot `a_j(y^k)` under the ρ̂/u accounting.
    pub est_start: f64,
    /// Estimated completion slot `T_j` under the ρ̂/u accounting.
    pub est_finish: f64,
}

/// A full schedule for a job set: entries in *dispatch order* — the order
/// in which the planner committed jobs to GPUs. The simulator replays this
/// order: a job starts once all GPUs of its placement are free, with
/// earlier entries winning contested GPUs.
#[derive(Debug, Clone)]
pub struct Plan {
    pub policy: String,
    /// The execution-time limit θ̃_u selected by bisection (SJF-BCO only).
    pub theta: Option<f64>,
    /// The server-span threshold κ selected (SJF-BCO only).
    pub kappa: Option<usize>,
    pub entries: Vec<PlannedJob>,
}

impl Plan {
    pub fn new(policy: impl Into<String>, entries: Vec<PlannedJob>) -> Self {
        Plan { policy: policy.into(), theta: None, kappa: None, entries }
    }

    /// Planner-estimated makespan: `max_j (a_j + ρ̂_j)`.
    pub fn est_makespan(&self) -> f64 {
        self.entries.iter().map(|e| e.est_finish).fold(0.0, f64::max)
    }

    /// Entry for a given job, if scheduled.
    pub fn entry(&self, job: JobId) -> Option<&PlannedJob> {
        self.entries.iter().find(|e| e.job == job)
    }

    /// Maximum server span over all placements.
    pub fn max_span(&self) -> usize {
        self.entries.iter().map(|e| e.placement.span()).max().unwrap_or(0)
    }

    /// Number of jobs whose placements are spread across servers.
    pub fn num_spread(&self) -> usize {
        self.entries.iter().filter(|e| e.placement.is_spread()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ServerId};

    #[test]
    fn plan_aggregates() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let colo =
            JobPlacement::new(vec![c.global_gpu(ServerId(0), 0), c.global_gpu(ServerId(0), 1)]);
        let spread =
            JobPlacement::new(vec![c.global_gpu(ServerId(0), 2), c.global_gpu(ServerId(1), 0)]);
        let plan = Plan::new(
            "test",
            vec![
                PlannedJob { job: JobId(0), placement: colo, est_start: 0.0, est_finish: 10.0 },
                PlannedJob { job: JobId(1), placement: spread, est_start: 0.0, est_finish: 25.0 },
            ],
        );
        assert_eq!(plan.est_makespan(), 25.0);
        assert_eq!(plan.max_span(), 2);
        assert_eq!(plan.num_spread(), 1);
        assert!(plan.entry(JobId(1)).is_some());
        assert!(plan.entry(JobId(7)).is_none());
    }
}
