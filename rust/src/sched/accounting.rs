//! Per-GPU execution-time ledger `U_s^g` (paper Alg. 1–3).
//!
//! Two quantities are tracked per GPU:
//!
//! * `busy` — the paper's `U_s^g`: the sum of `ρ̂_j/u` of jobs committed to
//!   this GPU. This is the quantity checked against the limit θ_u
//!   (Alg. 2 Line 2, Alg. 3 Line 5) and what Lemma 2 equates to θ̃_u.
//! * `free_at` — the earliest slot at which the GPU is available,
//!   *including* gang-synchronisation idling (a job starts at
//!   `max free_at` over its gang). Used to compute the planner's estimated
//!   start/finish times; the gap between `free_at` and `busy` is exactly
//!   the idle time bounded by Lemma 3.

use crate::cluster::{Cluster, GpuId};

/// GPU ledger for one planning pass.
#[derive(Debug, Clone)]
pub struct GpuLedger {
    busy: Vec<f64>,
    free_at: Vec<f64>,
    /// Per-server count of GPUs with `U > 0` — the FA-FFP "warm server"
    /// tie-break key, maintained incrementally on commit so the
    /// per-candidate placement path reads it in O(1) per server instead
    /// of recounting every GPU per job per κ.
    warm: Vec<usize>,
}

impl GpuLedger {
    pub fn new(cluster: &Cluster) -> Self {
        let n = cluster.num_gpus();
        GpuLedger {
            busy: vec![0.0; n],
            free_at: vec![0.0; n],
            warm: vec![0; cluster.num_servers()],
        }
    }

    /// `U_s^g` for a GPU.
    pub fn busy(&self, g: GpuId) -> f64 {
        self.busy[g.global]
    }

    /// Earliest availability (with gang idle).
    pub fn free_at(&self, g: GpuId) -> f64 {
        self.free_at[g.global]
    }

    /// Eligibility check of Alg. 2 Line 2 / Alg. 3 Line 5:
    /// `U_s^g + ρ̂/u ≤ θ_u`.
    pub fn eligible(&self, g: GpuId, rho_over_u: f64, theta: f64) -> bool {
        self.busy[g.global] + rho_over_u <= theta + 1e-9
    }

    /// Mean `U` over a server's GPUs — the LBSGF server key
    /// `Σ_g U_s^g / O_s` (Alg. 3 Line 2).
    pub fn server_load(&self, cluster: &Cluster, s: crate::cluster::ServerId) -> f64 {
        let cap = cluster.capacity(s) as f64;
        cluster.gpus_of(s).map(|g| self.busy[g.global]).sum::<f64>() / cap
    }

    /// Number of GPUs on a server that have ever been assigned work —
    /// used as the fragmentation-awareness tie-break (prefer already-warm
    /// servers when packing small jobs). O(1) from the maintained tally.
    pub fn server_occupancy(&self, _cluster: &Cluster, s: crate::cluster::ServerId) -> usize {
        self.warm[s.0]
    }

    /// The full per-server warm-GPU tally (`warm[s] = #{g on s : U > 0}`)
    /// — handed to [`fa_ffp_select_warm`](super::fa_ffp_select_warm) so
    /// the planner's per-candidate path skips the per-GPU recount.
    pub fn warm_per_server(&self) -> &[usize] {
        &self.warm
    }

    /// Commit a gang to a set of GPUs: the job starts at
    /// `max_g free_at(g)` and runs for `rho_over_u` estimated slots.
    /// Returns (est_start, est_finish).
    pub fn commit(&mut self, gpus: &[GpuId], rho_over_u: f64) -> (f64, f64) {
        let start = gpus.iter().map(|g| self.free_at[g.global]).fold(0.0, f64::max);
        let finish = start + rho_over_u;
        for g in gpus {
            if self.busy[g.global] == 0.0 && rho_over_u > 0.0 {
                self.warm[g.server.0] += 1; // cold → warm transition
            }
            self.busy[g.global] += rho_over_u;
            self.free_at[g.global] = finish;
        }
        (start, finish)
    }

    /// Max `U_s^g` over all GPUs — `Ŵ_max` of Lemma 2.
    pub fn max_busy(&self) -> f64 {
        self.busy.iter().copied().fold(0.0, f64::max)
    }

    /// Max `free_at` over all GPUs — the planner's estimated makespan
    /// including gang idle.
    pub fn max_free_at(&self) -> f64 {
        self.free_at.iter().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerId;

    #[test]
    fn commit_updates_busy_and_free() {
        let c = Cluster::uniform(2, 2, 1.0, 25.0);
        let mut led = GpuLedger::new(&c);
        let g0 = c.global_gpu(ServerId(0), 0);
        let g1 = c.global_gpu(ServerId(0), 1);
        let (s, f) = led.commit(&[g0, g1], 10.0);
        assert_eq!((s, f), (0.0, 10.0));
        assert_eq!(led.busy(g0), 10.0);
        assert_eq!(led.free_at(g1), 10.0);

        // second job only on g1 starts when g1 frees
        let (s2, f2) = led.commit(&[g1], 5.0);
        assert_eq!((s2, f2), (10.0, 15.0));
        assert_eq!(led.busy(g1), 15.0);

        // gang across g0 (free at 10) and a fresh gpu: idles the fresh one
        let g2 = c.global_gpu(ServerId(1), 0);
        let (s3, _) = led.commit(&[g0, g2], 3.0);
        assert_eq!(s3, 10.0);
        assert_eq!(led.busy(g2), 3.0, "busy excludes gang idle (paper U)");
        assert_eq!(led.free_at(g2), 13.0, "free_at includes gang idle");
    }

    #[test]
    fn eligibility_is_against_busy_not_free_at() {
        let c = Cluster::uniform(1, 2, 1.0, 25.0);
        let mut led = GpuLedger::new(&c);
        let g0 = c.global_gpu(ServerId(0), 0);
        let g1 = c.global_gpu(ServerId(0), 1);
        led.commit(&[g0], 8.0);
        led.commit(&[g0, g1], 2.0); // g1 busy=2, free_at=10
        assert!(led.eligible(g1, 5.0, 7.0), "busy 2 + 5 <= 7");
        assert!(!led.eligible(g0, 5.0, 7.0), "busy 8 + 5 > 7");
    }

    #[test]
    fn server_load_averages() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut led = GpuLedger::new(&c);
        led.commit(&[c.global_gpu(ServerId(0), 0)], 8.0);
        assert!((led.server_load(&c, ServerId(0)) - 2.0).abs() < 1e-12);
        assert_eq!(led.server_load(&c, ServerId(1)), 0.0);
        assert_eq!(led.server_occupancy(&c, ServerId(0)), 1);
    }

    #[test]
    fn warm_tally_tracks_cold_to_warm_transitions_only() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut led = GpuLedger::new(&c);
        assert_eq!(led.warm_per_server(), &[0, 0]);
        let g00 = c.global_gpu(ServerId(0), 0);
        let g01 = c.global_gpu(ServerId(0), 1);
        let g10 = c.global_gpu(ServerId(1), 0);
        led.commit(&[g00, g10], 3.0);
        assert_eq!(led.warm_per_server(), &[1, 1]);
        // re-committing an already-warm GPU must not double count
        led.commit(&[g00, g01], 2.0);
        assert_eq!(led.warm_per_server(), &[2, 1]);
        // the tally agrees with the per-GPU recount definition
        for s in c.server_ids() {
            let recount = c.gpus_of(s).filter(|g| led.busy(*g) > 0.0).count();
            assert_eq!(led.server_occupancy(&c, s), recount, "{s:?}");
        }
    }

    #[test]
    fn max_trackers() {
        let c = Cluster::uniform(1, 2, 1.0, 25.0);
        let mut led = GpuLedger::new(&c);
        assert_eq!(led.max_busy(), 0.0);
        led.commit(&[c.global_gpu(ServerId(0), 0)], 4.0);
        led.commit(&[c.global_gpu(ServerId(0), 0), c.global_gpu(ServerId(0), 1)], 2.0);
        assert_eq!(led.max_busy(), 6.0);
        assert_eq!(led.max_free_at(), 6.0);
    }
}
