//! Execution-time estimation ρ̂_j(y^k) and its l/u bounds (paper §5.3).
//!
//! The exact processing time ρ_j(y^k) is intractable at planning time
//! because it depends on which jobs *later* end up co-running (Eq. 6). The
//! paper instead works with an estimate bounded as
//! `ρ̂_j(y^k) ∈ [l·ρ_j(y^k), u·ρ_j(y^k)]` and schedules with the
//! conservative `ρ̂_j(y^k)/u ≤ ρ_j(y^k)`.
//!
//! We realise this concretely from the τ bounds of §5.1:
//!
//! * `τ_lo` — fully co-located, contention-free (the best case);
//! * `τ_hi` — span `G_j`, worst-case contention `p = max_s O_s`;
//! * `τ̂ = sqrt(τ_lo · τ_hi)` — geometric midpoint, our ρ̂ basis.
//!
//! With ρ̂ = F_j·τ̂, u = τ̂/τ_lo and l = τ̂/τ_hi, so that
//! `ρ̂/u = F_j·τ_lo` is a *guaranteed* lower bound on any realised
//! execution time and `u/l = τ_hi/τ_lo` is the ratio entering the
//! approximation factor of Theorem 5.

use crate::cluster::Cluster;
use crate::contention::ContentionParams;
use crate::jobs::JobSpec;

/// Per-job execution-time estimates used by all planners.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RhoEstimate {
    /// `ρ̂_j` — the nominal estimate (slots).
    pub rho_hat: f64,
    /// `ρ̂_j / u = F_j · τ_lo` — conservative lower bound (slots). This is
    /// the quantity added to GPU ledgers `U_s^g` in Algorithms 1–3.
    pub rho_lower: f64,
    /// `F_j · τ_hi` — worst-case execution time (slots).
    pub rho_upper: f64,
}

impl RhoEstimate {
    /// `u = ρ̂ / (ρ̂/u)` — the over-estimation factor.
    pub fn u(&self) -> f64 {
        self.rho_hat / self.rho_lower
    }

    /// `l` such that `l·ρ_upper = ρ̂`.
    pub fn l(&self) -> f64 {
        self.rho_hat / self.rho_upper
    }
}

/// Estimator bound to one cluster + parameter set.
#[derive(Debug, Clone, Copy)]
pub struct Estimator<'a> {
    pub cluster: &'a Cluster,
    pub params: &'a ContentionParams,
}

impl<'a> Estimator<'a> {
    pub fn new(cluster: &'a Cluster, params: &'a ContentionParams) -> Self {
        Estimator { cluster, params }
    }

    /// Estimate ρ̂ and its bounds for one job (placement-independent, as in
    /// the paper's §7 where ρ̂ is drawn per job from the τ·F product).
    pub fn rho(&self, job: &JobSpec) -> RhoEstimate {
        let (tau_lo, tau_hi) = self.params.tau_bounds(self.cluster, job);
        debug_assert!(tau_lo > 0.0 && tau_hi >= tau_lo);
        let tau_mid = (tau_lo * tau_hi).sqrt();
        let f = job.iterations as f64;
        RhoEstimate { rho_hat: f * tau_mid, rho_lower: f * tau_lo, rho_upper: f * tau_hi }
    }

    /// The worst-case estimate ratio `φ·u/l` of Lemma 4 / Theorem 5 for a
    /// job set: `max_j ρ_upper/ρ_lower` (since our ρ̂ construction makes
    /// `φ·u/l = max_j τ_hi/τ_lo`).
    pub fn worst_ratio(&self, jobs: &[JobSpec]) -> f64 {
        jobs.iter()
            .map(|j| {
                let r = self.rho(j);
                r.rho_upper / r.rho_lower
            })
            .fold(1.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::JobId;

    fn setup() -> (Cluster, ContentionParams) {
        (Cluster::uniform(4, 8, 1.0, 25.0), ContentionParams::paper())
    }

    #[test]
    fn bounds_ordering() {
        let (c, p) = setup();
        let est = Estimator::new(&c, &p);
        for gpus in [1, 2, 4, 8, 16] {
            let job = JobSpec::synthetic(JobId(0), gpus);
            let r = est.rho(&job);
            assert!(r.rho_lower <= r.rho_hat && r.rho_hat <= r.rho_upper);
            assert!(r.u() >= 1.0);
            assert!(r.l() <= 1.0);
        }
    }

    #[test]
    fn single_gpu_job_has_tight_bounds() {
        let (c, p) = setup();
        let est = Estimator::new(&c, &p);
        let job = JobSpec::synthetic(JobId(0), 1);
        let r = est.rho(&job);
        // no comm, no overhead: lower == upper
        assert!((r.rho_upper - r.rho_lower).abs() < 1e-9);
        assert!((r.u() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rho_scales_with_iterations() {
        let (c, p) = setup();
        let est = Estimator::new(&c, &p);
        let mut a = JobSpec::synthetic(JobId(0), 4);
        a.iterations = 1000;
        let mut b = a.clone();
        b.iterations = 2000;
        let ra = est.rho(&a);
        let rb = est.rho(&b);
        assert!((rb.rho_hat / ra.rho_hat - 2.0).abs() < 1e-9);
    }

    #[test]
    fn worst_ratio_at_least_one() {
        let (c, p) = setup();
        let est = Estimator::new(&c, &p);
        let jobs: Vec<_> = (0..5).map(|i| JobSpec::synthetic(JobId(i), 1 + i)).collect();
        assert!(est.worst_ratio(&jobs) >= 1.0);
    }
}
