//! Scheduling policies: the paper's SJF-BCO (Alg. 1–3) and the §7
//! baselines, all producing a [`Plan`] that the simulator or the live
//! coordinator executes.

mod accounting;
mod baselines;
mod estimator;
mod plan;
mod sjf_bco;

pub use accounting::GpuLedger;
pub use baselines::{first_fit, gadget_locality, list_scheduling, random_policy};
pub use estimator::{Estimator, RhoEstimate};
pub use plan::{Plan, PlannedJob};
pub use sjf_bco::{
    fa_ffp_select, fa_ffp_select_warm, lbsgf_select, lbsgf_select_ctx, sjf_bco, PlacementCtx,
    SjfBcoConfig,
};

use crate::cluster::Cluster;
use crate::contention::ContentionParams;
use crate::jobs::JobSpec;
use crate::Result;

/// The scheduling policies available from the CLI / benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Paper contribution: smallest-job-first with balanced contention and
    /// overhead (Alg. 1).
    SjfBco,
    /// First-Fit [17].
    FirstFit,
    /// List-Scheduling (least-loaded GPUs first) [17].
    ListScheduling,
    /// Random placement [19].
    Random,
    /// GADGET-style locality-first packing (reserved-bandwidth assumption,
    /// contention-blind) [22].
    Gadget,
}

impl Policy {
    pub const ALL: [Policy; 5] =
        [Policy::SjfBco, Policy::FirstFit, Policy::ListScheduling, Policy::Random, Policy::Gadget];

    pub fn name(self) -> &'static str {
        match self {
            Policy::SjfBco => "SJF-BCO",
            Policy::FirstFit => "FF",
            Policy::ListScheduling => "LS",
            Policy::Random => "RAND",
            Policy::Gadget => "GADGET",
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Policy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sjf-bco" | "sjfbco" | "sjf_bco" => Ok(Policy::SjfBco),
            "ff" | "first-fit" | "firstfit" | "first_fit" => Ok(Policy::FirstFit),
            "ls" | "list-scheduling" | "list" => Ok(Policy::ListScheduling),
            "rand" | "random" => Ok(Policy::Random),
            "gadget" => Ok(Policy::Gadget),
            other => anyhow::bail!(
                "unknown policy '{other}' (expected sjf-bco|ff|ls|rand|gadget)"
            ),
        }
    }
}

/// Schedule `jobs` on `cluster` under `policy` with default tunables.
/// `horizon` is the scheduling horizon `T` in slots.
pub fn schedule(
    policy: Policy,
    cluster: &Cluster,
    jobs: &[JobSpec],
    params: &ContentionParams,
    horizon: u64,
) -> Result<Plan> {
    match policy {
        Policy::SjfBco => sjf_bco(cluster, jobs, params, horizon, SjfBcoConfig::default()),
        Policy::FirstFit => first_fit(cluster, jobs, params, horizon),
        Policy::ListScheduling => list_scheduling(cluster, jobs, params, horizon),
        Policy::Random => random_policy(cluster, jobs, params, horizon, 0x5eed),
        Policy::Gadget => gadget_locality(cluster, jobs, params, horizon),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceGenerator;

    #[test]
    fn dispatcher_covers_all_policies() {
        let cluster = Cluster::uniform(4, 8, 1.0, 25.0);
        let params = ContentionParams::paper();
        let jobs = TraceGenerator::tiny().generate(0);
        for policy in Policy::ALL {
            let plan = schedule(policy, &cluster, &jobs, &params, 100_000).unwrap();
            assert_eq!(plan.entries.len(), jobs.len(), "{policy}");
        }
    }

    #[test]
    fn policy_names_unique() {
        let mut names: Vec<_> = Policy::ALL.iter().map(|p| p.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), Policy::ALL.len());
    }
}
