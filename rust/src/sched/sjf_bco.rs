//! SJF-BCO — Smallest Job First with Balanced Contention and Overhead
//! (paper Algorithm 1), with its two placement subroutines:
//!
//! * **FA-FFP** (Algorithm 2, "fragment-aware first-fit packing") for small
//!   jobs (`G_j ≤ κ`): pick the `G_j` eligible GPUs with least accumulated
//!   execution time `U_s^g`, tie-breaking towards servers that already host
//!   work (packing — avoids fragmenting fresh servers with small jobs).
//! * **LBSGF** (Algorithm 3, "least busy server-GPU first") for large jobs
//!   (`G_j > κ`): restrict attention to the `m` least-loaded servers whose
//!   joint capacity covers `λ_j · G_j`, then take the least-busy eligible
//!   GPUs inside them (opens fresh servers — bounds contention + overhead
//!   for big rings).
//!
//! Algorithm 1 wraps both in a bisection search for the tightest per-GPU
//! execution-time limit θ_u (Problem 14) crossed with a sweep over the
//! size threshold κ, and returns the (θ, κ) plan with the smallest
//! estimated makespan.

use super::accounting::GpuLedger;
use super::estimator::Estimator;
use super::{Plan, PlannedJob};
use crate::cluster::{Cluster, GpuId, JobPlacement};
use crate::contention::ContentionParams;
use crate::jobs::{sort_smallest_first, JobSpec};
use crate::Result;
use anyhow::bail;

/// Tunables of Algorithm 1.
#[derive(Debug, Clone, Copy)]
pub struct SjfBcoConfig {
    /// Fixed κ (server-span threshold). `None` sweeps κ as in Alg. 1
    /// Line 7. The sweep visits the *distinct job sizes* (plus 1 and n_g):
    /// the branch `G_j ≤ κ` only changes at those values, so intermediate
    /// κ are redundant (perf: 6 values instead of 32 on the paper mix).
    pub kappa: Option<usize>,
    /// λ_j ≥ 1 (Alg. 3): server over-provisioning factor; larger λ lets
    /// LBSGF draw from more servers (less contention, more overhead).
    pub lambda: f64,
}

impl Default for SjfBcoConfig {
    fn default() -> Self {
        SjfBcoConfig { kappa: None, lambda: 1.0 }
    }
}

/// Run SJF-BCO (Algorithm 1) and return the best plan found.
pub fn sjf_bco(
    cluster: &Cluster,
    jobs: &[JobSpec],
    params: &ContentionParams,
    horizon: u64,
    config: SjfBcoConfig,
) -> Result<Plan> {
    if jobs.is_empty() {
        return Ok(Plan::new("sjf-bco", Vec::new()));
    }
    if config.lambda < 1.0 {
        bail!("lambda must be >= 1 (Alg. 3)");
    }
    for j in jobs {
        if let Err(e) = j.validate() {
            bail!("invalid job: {e}");
        }
        if j.gpus > cluster.num_gpus() {
            bail!("{} requests {} GPUs but the cluster only has {}", j.id, j.gpus, cluster.num_gpus());
        }
    }

    // Alg. 1 Line 3: sort jobs by G_j non-decreasing.
    let mut sorted: Vec<JobSpec> = jobs.to_vec();
    sort_smallest_first(&mut sorted);
    let est = Estimator::new(cluster, params);

    let kappas: Vec<usize> = match config.kappa {
        Some(k) => vec![k],
        None => {
            // distinct job sizes; always include 1 and n_g endpoints
            let mut ks: Vec<usize> = sorted.iter().map(|j| j.gpus).collect();
            ks.push(1);
            ks.sort_unstable();
            ks.dedup();
            ks
        }
    };

    // Alg. 1 Lines 4–23: bisection on θ_u over [1, T].
    //
    // Candidate (θ, κ) schedules are scored by *evaluating* them through
    // the analytical model (Eq. 6–9) — the paper's Fig. 3 framework:
    // "search a schedule, then τ_j[t] can be efficiently evaluated to
    // estimate the makespan" — rather than by the placement-blind ρ̂
    // ledger estimate alone. §Perf: one [`PlanScorer`] serves the whole
    // (θ × κ) search — every candidate replays on the persistent
    // tracker + dirty-set engine with its scratch buffers reused, instead
    // of a fresh snapshot-rebuilding simulator per candidate; the
    // loop-invariant placement context (per-rack capacities) is likewise
    // hoisted out of the per-job, per-candidate path.
    let mut scorer = crate::sim::PlanScorer::new(cluster, jobs, params);
    let ctx = PlacementCtx::new(cluster);
    let (mut left, mut right) = (1u64, horizon);
    let mut best: Option<(f64, Plan)> = None; // (evaluated makespan, plan)
    let mut rounds = 0u64;
    while left <= right {
        let theta = (left + right) / 2;
        rounds += 1;
        crate::obs::metrics::incr(crate::obs::metrics::Counter::BisectionRounds);
        let _round_span = crate::obs::trace::span("bco.bisect_round", "planner")
            .arg("theta", theta as f64)
            .arg("kappas", kappas.len() as f64);
        // inner κ sweep (Lines 7–18)
        let mut best_for_theta: Option<(f64, Plan)> = None;
        for &kappa in &kappas {
            if let Some((_ledger_makespan, entries)) =
                try_schedule(cluster, &ctx, &sorted, &est, theta as f64, kappa, config.lambda)
            {
                let mut plan = Plan::new("sjf-bco", entries);
                plan.theta = Some(theta as f64);
                plan.kappa = Some(kappa);
                let makespan = scorer.makespan(&plan) as f64;
                let better = best_for_theta.as_ref().map_or(true, |(m, _)| makespan < *m);
                if better {
                    best_for_theta = Some((makespan, plan));
                }
            }
        }
        match best_for_theta {
            // Found a feasible schedule at this θ whose makespan fits the
            // horizon: record if globally better, then tighten θ (Line 21).
            // Ties update too: the bisection walks θ downward, so on equal
            // makespans the *tightest* feasible θ̃_u wins (Lemma 2).
            Some((makespan, plan)) if makespan < horizon as f64 => {
                if best.as_ref().map_or(true, |(m, _)| makespan <= *m) {
                    best = Some((makespan, plan));
                }
                right = theta - 1;
            }
            // Infeasible (or exceeds horizon): relax θ (Line 23).
            _ => left = theta + 1,
        }
    }
    crate::obs::metrics::record(crate::obs::metrics::Hist::RoundsPerBisection, rounds);

    match best {
        Some((_, plan)) => Ok(plan),
        None => bail!(
            "SJF-BCO found no feasible schedule within horizon T={horizon} \
             (total demand exceeds cluster-time capacity?)"
        ),
    }
}

/// One (θ, κ) attempt: schedule every job, smallest first. Returns the
/// estimated makespan and the plan entries, or `None` if some job cannot
/// be placed under the θ limit (Alg. 1 Lines 14–15).
fn try_schedule(
    cluster: &Cluster,
    ctx: &PlacementCtx,
    sorted: &[JobSpec],
    est: &Estimator<'_>,
    theta: f64,
    kappa: usize,
    lambda: f64,
) -> Option<(f64, Vec<PlannedJob>)> {
    let mut ledger = GpuLedger::new(cluster);
    let mut entries = Vec::with_capacity(sorted.len());
    let mut makespan = 0.0f64;
    for job in sorted {
        let rho = est.rho(job);
        let gpus = if job.gpus <= kappa {
            fa_ffp(cluster, &ledger, job, rho.rho_lower, theta)
        } else {
            lbsgf(cluster, ctx, &ledger, job, rho.rho_lower, theta, lambda)
        }?;
        let (start, finish) = ledger.commit(&gpus, rho.rho_lower);
        makespan = makespan.max(finish);
        entries.push(PlannedJob {
            job: job.id,
            placement: JobPlacement::new(gpus),
            est_start: start,
            est_finish: finish,
        });
    }
    Some((makespan, entries))
}

/// Algorithm 2 — Fragment-Aware First-Fit Packing, as a *single-job*
/// selection subroutine over arbitrary eligibility/load views.
///
/// Picks the `gpus_needed` least-busy eligible GPUs (Line 4),
/// tie-breaking towards servers that already host work per `warm` (the
/// "fragment-aware" packing bias), then — when the cluster's fabric has a
/// rack tier — towards *racks* that already host work (keeping small
/// rings below their ToR instead of opening fresh racks and crossing the
/// spine), then by (server, index) for determinism. On a flat fabric the
/// rack tie-break is skipped entirely, so the seed behaviour is
/// unchanged. `warm` is separate from `busy` because the two notions
/// diverge for online callers: the batch planner calls this through
/// [`fa_ffp`] with the ledger's `U + ρ̂/u ≤ θ` eligibility and
/// `warm = U > 0`; the [`online`](crate::online) policies pass "GPU
/// currently free" eligibility, *cumulative* busy history as the load
/// key, and `warm = currently occupied` — cumulative history would mark
/// every server warm once each GPU has run anything, silencing the bias.
pub fn fa_ffp_select(
    cluster: &Cluster,
    gpus_needed: usize,
    eligible: impl Fn(GpuId) -> bool,
    busy: impl Fn(GpuId) -> f64,
    warm: impl Fn(GpuId) -> bool,
) -> Option<Vec<GpuId>> {
    // occupancy per server from the per-GPU predicate; callers that
    // already maintain the tally (ledger, online occupancy) use
    // [`fa_ffp_select_warm`] and skip this O(N) recount
    let occ: Vec<usize> = cluster
        .server_ids()
        .map(|s| cluster.gpus_of(s).filter(|g| warm(*g)).count())
        .collect();
    fa_ffp_select_warm(cluster, gpus_needed, eligible, busy, &occ)
}

/// [`fa_ffp_select`] with the per-server warm tally precomputed:
/// `warm_per_server[s]` = number of warm GPUs on server `s`. The batch
/// ledger ([`GpuLedger::warm_per_server`]) and the online loop (occupied
/// = capacity − free, O(S) from maintained counts) both keep this tally
/// incrementally, hoisting the recount out of the per-candidate path.
pub fn fa_ffp_select_warm(
    cluster: &Cluster,
    gpus_needed: usize,
    eligible: impl Fn(GpuId) -> bool,
    busy: impl Fn(GpuId) -> f64,
    warm_per_server: &[usize],
) -> Option<Vec<GpuId>> {
    debug_assert_eq!(warm_per_server.len(), cluster.num_servers());
    let occ = warm_per_server;
    let mut candidates: Vec<GpuId> = cluster.all_gpus().filter(|g| eligible(*g)).collect();
    if candidates.len() < gpus_needed {
        return None; // Alg. 2 Lines 8–10: no capacity under θ
    }
    // warm occupancy per rack — only when a rack tier exists (on a flat
    // fabric every server is its own rack and the tie-break is redundant)
    let topo = cluster.topology();
    let rack_occ: Option<Vec<usize>> = topo.has_racks().then(|| {
        let mut ro = vec![0usize; topo.num_racks()];
        for s in cluster.server_ids() {
            ro[topo.rack_index(s)] += occ[s.0];
        }
        ro
    });
    // …and per pod, one tier up (3-tier fabrics only): after rack
    // locality, prefer pods that already host work so small rings stay
    // below one pod switch instead of opening a fresh pod and crossing
    // the spine.
    let pod_occ: Option<Vec<usize>> = (topo.has_pods() && rack_occ.is_some()).then(|| {
        let ro = rack_occ.as_ref().expect("guarded");
        let mut po = vec![0usize; topo.num_pods()];
        for (r, &w) in ro.iter().enumerate() {
            po[topo.pod_of_rack(r)] += w;
        }
        po
    });
    let cmp = |a: &GpuId, b: &GpuId| {
        busy(*a)
            .partial_cmp(&busy(*b))
            .unwrap()
            .then(occ[b.server.0].cmp(&occ[a.server.0])) // prefer warm servers
            .then(match &rack_occ {
                // …then warm racks (rack-local before crossing the spine)
                Some(ro) => ro[topo.rack_index(b.server)].cmp(&ro[topo.rack_index(a.server)]),
                None => std::cmp::Ordering::Equal,
            })
            .then(match &pod_occ {
                // …then warm pods (pod-local after rack-local)
                Some(po) => po[topo.pod_index(b.server)].cmp(&po[topo.pod_index(a.server)]),
                None => std::cmp::Ordering::Equal,
            })
            .then(a.server.cmp(&b.server))
            .then(a.index.cmp(&b.index))
    };
    // §Perf: selection instead of a full sort — only the top-G_j least
    // loaded GPUs matter, and placements are order-insensitive.
    if candidates.len() > gpus_needed {
        candidates.select_nth_unstable_by(gpus_needed - 1, cmp);
        candidates.truncate(gpus_needed);
    }
    Some(candidates)
}

/// Ledger-eligibility wrapper of [`fa_ffp_select`] used by Algorithm 1:
/// eligible = GPUs with `U + ρ̂/u ≤ θ`, load key = `U_s^g`, warm tally
/// read straight from the ledger's incremental per-server counts.
pub(crate) fn fa_ffp(
    cluster: &Cluster,
    ledger: &GpuLedger,
    job: &JobSpec,
    rho_over_u: f64,
    theta: f64,
) -> Option<Vec<GpuId>> {
    fa_ffp_select_warm(
        cluster,
        job.gpus,
        |g| ledger.eligible(g, rho_over_u, theta),
        |g| ledger.busy(g),
        ledger.warm_per_server(),
    )
}

/// Algorithm 3 — Least Busy Server-GPU First, as a *single-job* selection
/// subroutine over arbitrary eligibility/load views.
///
/// Sort servers by average load `Σ_g busy / O_s`, take the `m` least
/// loaded whose capacities sum to `≥ λ · gpus_needed` (Line 2), then pick
/// the `gpus_needed` least-busy eligible GPUs within them (Lines 4–7).
///
/// Topology generalization: when the fabric has a rack tier and a single
/// rack's capacity covers the over-provisioned pool `λ · G_j`, the server
/// pool is restricted to the least-loaded such rack — the ring then never
/// crosses an (oversubscribed) ToR uplink. On a 3-tier fabric, if no rack
/// covers the pool, the least-loaded covering **pod** is tried next (the
/// ring crosses ToRs but stays below one pod switch). If a restricted
/// pool cannot yield `G_j` eligible GPUs, selection falls back to the
/// cluster-wide rule, so feasibility never shrinks. Flat fabrics skip
/// every restriction and behave exactly as the seed.
pub fn lbsgf_select(
    cluster: &Cluster,
    gpus_needed: usize,
    lambda: f64,
    eligible: impl Fn(GpuId) -> bool,
    busy: impl Fn(GpuId) -> f64,
) -> Option<Vec<GpuId>> {
    lbsgf_select_ctx(cluster, &PlacementCtx::new(cluster), gpus_needed, lambda, eligible, busy)
}

/// [`lbsgf_select`] with the loop-invariant [`PlacementCtx`] precomputed
/// — the form the planner's bisection uses so per-rack (and per-pod)
/// capacities are tallied once per `sjf_bco` call, not per job per κ per
/// θ.
pub fn lbsgf_select_ctx(
    cluster: &Cluster,
    ctx: &PlacementCtx,
    gpus_needed: usize,
    lambda: f64,
    eligible: impl Fn(GpuId) -> bool,
    busy: impl Fn(GpuId) -> f64,
) -> Option<Vec<GpuId>> {
    let need = (lambda * gpus_needed as f64).ceil() as usize;
    let topo = cluster.topology();
    if topo.has_racks() {
        if let Some(rack) = least_loaded_covering_group(
            cluster,
            &ctx.rack_cap,
            |s| topo.rack_index(s),
            need,
            &busy,
        ) {
            if let Some(sel) =
                lbsgf_pool(cluster, gpus_needed, need, &eligible, &busy, Pool::Rack(rack))
            {
                return Some(sel);
            }
        }
        // No rack covers the pool — or the covering rack's GPUs were
        // θ-ineligible: either way, keep the ring below one pod switch if
        // a pod can (pod-locality after rack-locality) before spreading
        // cluster-wide across the spine.
        if topo.has_pods() {
            if let Some(pod) = least_loaded_covering_group(
                cluster,
                &ctx.pod_cap,
                |s| topo.pod_index(s),
                need,
                &busy,
            ) {
                if let Some(sel) =
                    lbsgf_pool(cluster, gpus_needed, need, &eligible, &busy, Pool::Pod(pod))
                {
                    return Some(sel);
                }
            }
        }
    }
    lbsgf_pool(cluster, gpus_needed, need, &eligible, &busy, Pool::All)
}

/// Loop-invariant placement context: cluster-shape tallies (per-rack and
/// per-pod GPU capacities) that every candidate placement of a planner
/// run shares. Computed once per planner invocation and threaded through
/// the per-candidate path, which previously re-derived them per job per κ.
#[derive(Debug, Clone)]
pub struct PlacementCtx {
    /// `rack_cap[r]` = Σ capacities of rack `r`'s servers; empty on a
    /// flat fabric (no rack pool restriction applies there).
    rack_cap: Vec<usize>,
    /// `pod_cap[p]` = Σ capacities of pod `p`'s racks; empty without a
    /// pod tier.
    pod_cap: Vec<usize>,
}

impl PlacementCtx {
    pub fn new(cluster: &Cluster) -> Self {
        let topo = cluster.topology();
        let mut rack_cap = vec![0usize; topo.num_racks()];
        if topo.has_racks() {
            for s in cluster.server_ids() {
                rack_cap[topo.rack_index(s)] += cluster.capacity(s);
            }
        }
        let mut pod_cap = vec![0usize; topo.num_pods()];
        if topo.has_pods() {
            for (r, &cap) in rack_cap.iter().enumerate() {
                pod_cap[topo.pod_of_rack(r)] += cap;
            }
        }
        PlacementCtx { rack_cap, pod_cap }
    }

    /// Total GPU capacity of one rack.
    pub fn rack_capacity(&self, rack: usize) -> usize {
        self.rack_cap[rack]
    }

    /// Total GPU capacity of one pod.
    pub fn pod_capacity(&self, pod: usize) -> usize {
        self.pod_cap[pod]
    }
}

/// Server-pool restriction for [`lbsgf_pool`]: the whole cluster, one
/// rack, or one pod.
#[derive(Debug, Clone, Copy)]
enum Pool {
    All,
    Rack(usize),
    Pod(usize),
}

impl Pool {
    fn admits(self, topo: &crate::topology::Topology, s: crate::cluster::ServerId) -> bool {
        match self {
            Pool::All => true,
            Pool::Rack(r) => topo.rack_index(s) == r,
            Pool::Pod(p) => topo.pod_index(s) == p,
        }
    }
}

/// The least-loaded server group (rack or pod) whose total GPU capacity
/// covers `need`, if any: load = mean per-GPU busy time over the group,
/// ties by group id. `group_cap` is the hoisted per-group capacity tally
/// ([`PlacementCtx`]) and `group_of` the server → group projection —
/// single `O(S + groups)` pass, on the per-job placement path of the
/// planner's bisection loop.
fn least_loaded_covering_group(
    cluster: &Cluster,
    group_cap: &[usize],
    group_of: impl Fn(crate::cluster::ServerId) -> usize,
    need: usize,
    busy: &impl Fn(GpuId) -> f64,
) -> Option<usize> {
    let mut load = vec![0.0f64; group_cap.len()];
    for s in cluster.server_ids() {
        load[group_of(s)] += cluster.gpus_of(s).map(busy).sum::<f64>();
    }
    let mut best: Option<(f64, usize)> = None;
    for (group, &cap) in group_cap.iter().enumerate() {
        if cap < need {
            continue;
        }
        let avg = load[group] / cap as f64;
        if best.map_or(true, |(b, _)| avg < b) {
            best = Some((avg, group));
        }
    }
    best.map(|(_, g)| g)
}

/// The core of Alg. 3 over an optionally rack- or pod-restricted server
/// pool.
fn lbsgf_pool(
    cluster: &Cluster,
    gpus_needed: usize,
    need: usize,
    eligible: &impl Fn(GpuId) -> bool,
    busy: &impl Fn(GpuId) -> f64,
    pool: Pool,
) -> Option<Vec<GpuId>> {
    let topo = cluster.topology();
    let server_load = |s: crate::cluster::ServerId| -> f64 {
        cluster.gpus_of(s).map(busy).sum::<f64>() / cluster.capacity(s) as f64
    };
    let mut servers: Vec<_> =
        cluster.server_ids().filter(|s| pool.admits(topo, *s)).collect();
    servers.sort_by(|a, b| {
        server_load(*a).partial_cmp(&server_load(*b)).unwrap().then(a.cmp(b))
    });
    let mut selected = Vec::new();
    let mut cap = 0usize;
    for s in servers {
        selected.push(s);
        cap += cluster.capacity(s);
        if cap >= need {
            break;
        }
    }
    // (if λ G_j exceeds the pool's capacity, every pool server is selected)
    //
    // Alg. 3 Lines 4–5: within each selected server (already in
    // least-loaded order) sort GPUs by U non-decreasing, then *append* —
    // the candidate list is server-major: all of the quietest server's
    // eligible GPUs come first. "Pick top-G_j workers" then fills whole
    // quiet servers before touching busier ones, which keeps the ring
    // span small AND lands it on low-contention servers. This is the λ
    // mechanism of Fig. 7: a larger λ widens the candidate pool, so a
    // tight θ_u stays feasible (fresh servers can be opened) and the
    // bisection settles at a smaller execution-time limit.
    let mut candidates: Vec<GpuId> = Vec::new();
    for s in &selected {
        let mut gs: Vec<GpuId> = cluster.gpus_of(*s).filter(|g| eligible(*g)).collect();
        gs.sort_by(|a, b| {
            busy(*a).partial_cmp(&busy(*b)).unwrap().then(a.index.cmp(&b.index))
        });
        candidates.extend(gs);
    }
    if candidates.len() < gpus_needed {
        return None; // Alg. 3 Lines 11–13
    }
    Some(candidates[..gpus_needed].to_vec())
}

/// Ledger-eligibility wrapper of [`lbsgf_select_ctx`] used by Algorithm 1.
pub(crate) fn lbsgf(
    cluster: &Cluster,
    ctx: &PlacementCtx,
    ledger: &GpuLedger,
    job: &JobSpec,
    rho_over_u: f64,
    theta: f64,
    lambda: f64,
) -> Option<Vec<GpuId>> {
    lbsgf_select_ctx(
        cluster,
        ctx,
        job.gpus,
        lambda,
        |g| ledger.eligible(g, rho_over_u, theta),
        |g| ledger.busy(g),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceGenerator;

    fn setup() -> (Cluster, ContentionParams) {
        (Cluster::uniform(4, 8, 1.0, 25.0), ContentionParams::paper())
    }

    #[test]
    fn empty_jobset_gives_empty_plan() {
        let (c, p) = setup();
        let plan = sjf_bco(&c, &[], &p, 100, SjfBcoConfig::default()).unwrap();
        assert!(plan.entries.is_empty());
    }

    #[test]
    fn schedules_every_job_exactly_once() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate(1);
        let plan = sjf_bco(&c, &jobs, &p, 100_000, SjfBcoConfig::default()).unwrap();
        assert_eq!(plan.entries.len(), jobs.len());
        let mut seen: Vec<_> = plan.entries.iter().map(|e| e.job).collect();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), jobs.len());
        // gang scheduling: every placement has exactly G_j GPUs
        for e in &plan.entries {
            let spec = jobs.iter().find(|j| j.id == e.job).unwrap();
            assert_eq!(e.placement.num_workers(), spec.gpus);
        }
        assert!(plan.theta.is_some());
        assert!(plan.kappa.is_some());
    }

    #[test]
    fn dispatch_order_is_smallest_first() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate(2);
        let plan = sjf_bco(&c, &jobs, &p, 100_000, SjfBcoConfig::default()).unwrap();
        let sizes: Vec<_> = plan.entries.iter().map(|e| e.placement.num_workers()).collect();
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        assert_eq!(sizes, sorted);
    }

    #[test]
    fn respects_theta_limit() {
        // Lemma 2: max busy time equals the tightest θ̃_u the bisection
        // settles on — in particular no GPU exceeds it.
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate(3);
        let plan = sjf_bco(&c, &jobs, &p, 100_000, SjfBcoConfig::default()).unwrap();
        let theta = plan.theta.unwrap();
        // replay the ledger
        let est = Estimator::new(&c, &p);
        let mut ledger = GpuLedger::new(&c);
        for e in &plan.entries {
            let spec = jobs.iter().find(|j| j.id == e.job).unwrap();
            ledger.commit(e.placement.gpus(), est.rho(spec).rho_lower);
        }
        assert!(ledger.max_busy() <= theta + 1e-6);
    }

    #[test]
    fn oversized_job_is_rejected() {
        let (c, p) = setup();
        let job = JobSpec::synthetic(crate::jobs::JobId(0), 1000);
        assert!(sjf_bco(&c, &[job], &p, 1000, SjfBcoConfig::default()).is_err());
    }

    #[test]
    fn fixed_kappa_one_forces_lbsgf_for_multigpu() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate(4);
        let cfg = SjfBcoConfig { kappa: Some(1), lambda: 1.0 };
        let plan = sjf_bco(&c, &jobs, &p, 100_000, cfg).unwrap();
        assert_eq!(plan.kappa, Some(1));
        assert_eq!(plan.entries.len(), jobs.len());
    }

    #[test]
    fn lambda_below_one_rejected() {
        let (c, p) = setup();
        let cfg = SjfBcoConfig { kappa: None, lambda: 0.5 };
        assert!(sjf_bco(&c, &TraceGenerator::tiny().generate(0), &p, 1000, cfg).is_err());
    }

    #[test]
    fn fa_ffp_packs_warm_servers_on_ties() {
        let (c, p) = setup();
        let est = Estimator::new(&c, &p);
        let mut ledger = GpuLedger::new(&c);
        // warm up server 2 with a tiny committed job
        let warm = c.global_gpu(crate::cluster::ServerId(2), 0);
        ledger.commit(&[warm], 1e-6);
        let job = JobSpec::synthetic(crate::jobs::JobId(1), 2);
        let rho = est.rho(&job);
        let gpus = fa_ffp(&c, &ledger, &job, rho.rho_lower, 1e9).unwrap();
        // all fresh GPUs tie at busy=0; tie-break prefers warm server 2
        assert!(gpus.iter().all(|g| g.server.0 == 2), "picked {gpus:?}");
    }

    #[test]
    fn lbsgf_limits_server_span_via_lambda() {
        let (c, p) = setup();
        let est = Estimator::new(&c, &p);
        let ledger = GpuLedger::new(&c);
        let job = JobSpec::synthetic(crate::jobs::JobId(0), 8);
        let rho = est.rho(&job);
        // λ = 1: 8 GPUs fit on one 8-GPU server → span 1
        let gpus =
            lbsgf(&c, &PlacementCtx::new(&c), &ledger, &job, rho.rho_lower, 1e9, 1.0).unwrap();
        let placement = JobPlacement::new(gpus);
        assert_eq!(placement.span(), 1);
    }

    #[test]
    fn fa_ffp_prefers_warm_racks_when_servers_tie() {
        use crate::cluster::ServerId;
        use crate::topology::Topology;
        // 4 servers x 2 GPUs, racks {0,1} and {2,3}. Server 3 is fully
        // occupied: every *candidate* server has zero warm occupancy, so
        // the server tie-break is silent and the rack tie-break must pull
        // the job into rack 1 (server 2) instead of server 0.
        let c = Cluster::uniform(4, 2, 1.0, 25.0)
            .with_topology(Topology::racks(4, 2, 2.0));
        let occupied = |g: crate::cluster::GpuId| g.server == ServerId(3);
        let gpus = fa_ffp_select(
            &c,
            2,
            |g| !occupied(g),
            |_| 0.0,
            occupied,
        )
        .unwrap();
        assert!(gpus.iter().all(|g| g.server == ServerId(2)), "picked {gpus:?}");

        // sanity: on the flat fabric the same tie falls through to the
        // lowest server id (the seed rule).
        let flat = Cluster::uniform(4, 2, 1.0, 25.0);
        let gpus = fa_ffp_select(&flat, 2, |g| !occupied(g), |_| 0.0, occupied).unwrap();
        assert!(gpus.iter().all(|g| g.server == ServerId(0)), "picked {gpus:?}");
    }

    #[test]
    fn fa_ffp_prefers_warm_pods_when_servers_and_racks_tie() {
        use crate::cluster::ServerId;
        use crate::topology::Topology;
        // 8 servers x 2 GPUs, racks of 2, pods of 2 racks (pod 0 =
        // servers 0-3, pod 1 = servers 4-7). Rack 3 (servers 6, 7) is
        // fully occupied: every candidate server AND every candidate rack
        // has zero warm occupancy, so only the pod tie-break can pull the
        // job into pod 1 (servers 4/5) instead of server 0.
        let c = Cluster::uniform(8, 2, 1.0, 25.0)
            .with_topology(Topology::pods(8, 2, 2, 2.0, 2.0));
        let occupied = |g: crate::cluster::GpuId| g.server == ServerId(6) || g.server == ServerId(7);
        let gpus = fa_ffp_select(&c, 2, |g| !occupied(g), |_| 0.0, occupied).unwrap();
        assert!(
            gpus.iter().all(|g| g.server == ServerId(4)),
            "pod tie-break must pick pod 1's coolest server, picked {gpus:?}"
        );
        // sanity: without a pod tier the same tie falls through to the
        // lowest server id (the rack-fabric rule).
        let racked = Cluster::uniform(8, 2, 1.0, 25.0)
            .with_topology(Topology::racks(8, 2, 2.0));
        let gpus = fa_ffp_select(&racked, 2, |g| !occupied(g), |_| 0.0, occupied).unwrap();
        assert!(gpus.iter().all(|g| g.server == ServerId(0)), "picked {gpus:?}");
    }

    #[test]
    fn lbsgf_restricts_to_a_covering_pod_when_no_rack_covers() {
        use crate::cluster::ServerId;
        use crate::topology::Topology;
        // 8 servers x 2 GPUs: racks of 2 hold 4 GPUs, pods of 2 racks
        // hold 8. A 6-GPU ring (λ = 1) exceeds every rack but fits a pod;
        // pod 0 (servers 0-3) is busy, so the pool must restrict to pod 1.
        let c = Cluster::uniform(8, 2, 1.0, 25.0)
            .with_topology(Topology::pods(8, 2, 2, 2.0, 2.0));
        let busy = |g: crate::cluster::GpuId| if g.server.0 <= 3 { 10.0 } else { 0.0 };
        let gpus = lbsgf_select(&c, 6, 1.0, |_| true, busy).unwrap();
        let pl = JobPlacement::new(gpus);
        assert!(
            pl.servers().all(|s| s.0 >= 4),
            "ring must stay in pod 1, got {:?}",
            pl.servers().collect::<Vec<_>>()
        );
        // pod capacity tallies feed the restriction
        let ctx = PlacementCtx::new(&c);
        assert_eq!(ctx.pod_capacity(0), 8);
        assert_eq!(ctx.rack_capacity(0), 4);
    }

    #[test]
    fn lbsgf_restricts_to_a_covering_rack() {
        use crate::cluster::ServerId;
        use crate::topology::Topology;
        // capacities [2,4,4,4], racks {0,1} (cap 6) and {2,3} (cap 8):
        // an 8-GPU ring fits below rack 1's ToR, so LBSGF must stay there
        // instead of taking the flat least-loaded prefix {0,1,2} that
        // crosses the spine.
        let c = Cluster::new(&[2, 4, 4, 4], 1.0, 25.0)
            .with_topology(Topology::custom_racks(&[2, 2], &[2.0, 2.0]));
        let gpus = lbsgf_select(&c, 8, 1.0, |_| true, |_| 0.0).unwrap();
        let pl = JobPlacement::new(gpus);
        assert!(
            pl.servers().all(|s| s == ServerId(2) || s == ServerId(3)),
            "ring must stay in rack 1, got span over {:?}",
            pl.servers().collect::<Vec<_>>()
        );
        // flat fabric keeps the seed prefix rule (servers 0,1,2)
        let flat = Cluster::new(&[2, 4, 4, 4], 1.0, 25.0);
        let gpus = lbsgf_select(&flat, 8, 1.0, |_| true, |_| 0.0).unwrap();
        let pl = JobPlacement::new(gpus);
        assert!(pl.servers().any(|s| s == ServerId(0)), "flat rule unchanged");
    }

    #[test]
    fn lbsgf_falls_back_to_the_cluster_when_the_rack_pool_is_ineligible() {
        use crate::cluster::ServerId;
        use crate::topology::Topology;
        // racks {0,1} (cap 8, covers the ring) and {2} (cap 4). Server 1
        // is fully loaded AND ineligible under θ, so the rack-restricted
        // pool yields only 4 eligible GPUs — the selection must fall back
        // to the global rule (whose load-sorted prefix is {0, 2}) and
        // still place all 8 workers.
        let c = Cluster::uniform(3, 4, 1.0, 25.0)
            .with_topology(Topology::custom_racks(&[2, 1], &[2.0, 2.0]));
        let busy = |g: crate::cluster::GpuId| if g.server == ServerId(1) { 100.0 } else { 0.0 };
        let gpus = lbsgf_select(&c, 8, 1.0, |g| g.server != ServerId(1), busy).unwrap();
        assert_eq!(gpus.len(), 8);
        let pl = JobPlacement::new(gpus);
        assert_eq!(pl.gpus_on(ServerId(0)), 4);
        assert_eq!(pl.gpus_on(ServerId(2)), 4);
    }

    #[test]
    fn bisection_tightens_theta() {
        // A generous horizon should not inflate θ: the returned θ must be
        // near the minimal feasible limit, not near T.
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate(5);
        let plan_a = sjf_bco(&c, &jobs, &p, 50_000, SjfBcoConfig::default()).unwrap();
        let plan_b = sjf_bco(&c, &jobs, &p, 500_000, SjfBcoConfig::default()).unwrap();
        let (ta, tb) = (plan_a.theta.unwrap(), plan_b.theta.unwrap());
        // bisection granularity differs, but both should land well below T
        assert!(ta < 25_000.0, "theta {ta} not tightened");
        assert!(tb < 25_000.0, "theta {tb} not tightened");
    }
}
