//! Hierarchical network fabric: the tree of shared links above the servers.
//!
//! The paper's contention model (Eq. 6) counts the active rings crossing a
//! *server uplink*, which implicitly assumes every uplink attaches to one
//! flat, non-blocking switch. Real multi-tenant clusters are rack- and
//! pod-structured and oversubscribed: servers attach to a top-of-rack
//! (ToR) switch, ToRs uplink into a pod (aggregation) switch, pods into
//! the spine — and each tier typically carries less capacity than the sum
//! of the links below it.
//!
//! This module models that fabric as a tree of links (identified by
//! [`LinkId`], tiered per [`LinkTier`]):
//!
//! * **tier 0** — one uplink per server (the links of Eq. 6),
//! * **tier 1** — one uplink per rack (ToR → pod/spine), present only
//!   when the topology has a rack tier,
//! * **tier 2** — one uplink per pod (pod → spine), present only when the
//!   topology has a pod tier,
//! * the spine itself is the root and owns no uplink: a ring confined to
//!   the cluster never crosses it.
//!
//! A job's ring **crosses** link `ℓ` iff the servers in `ℓ`'s subtree hold
//! some but not all of the job's workers — `0 < Σ_{s ∈ sub(ℓ)} y_js < G_j`.
//! For a server uplink the subtree is the server itself and this is exactly
//! the Eq. 6 indicator `1{0 < y_js < G_j}`; for rack and pod uplinks it is
//! the natural generalization up the tree. The per-link contention count
//! is the number of active rings crossing the link, and a job's effective
//! contention is taken at its [`Bottleneck`] — the crossed link maximizing
//! `count × multiplier`, where the multiplier depends on the fabric's
//! [`ContentionModel`]:
//!
//! * [`EffectiveDegree`](ContentionModel::EffectiveDegree) — the per-link
//!   oversubscription *factor* `o_ℓ ≥ 1` (an `o`-times oversubscribed
//!   link serving `n` rings behaves like a full-rate link serving `n·o`);
//! * [`MaxMinFair`](ContentionModel::MaxMinFair) — the per-link capacity
//!   *ratio* `c_ref / c_ℓ` from the fabric's absolute [`LinkCapacity`]s
//!   (`n` rings splitting `c_ℓ` max-min get `c_ℓ / n` each, so the
//!   implied contention against the reference link is `n · c_ref / c_ℓ`
//!   — see [`crate::net`] for the allocator and the equivalence
//!   argument).
//!
//! Capacities derived from a scalar oversubscription spec store
//! `ratio = o_ℓ` exactly, so on such fabrics the two models are
//! bit-identical; they diverge only under absolute-speed specs — above
//! all *relief links* (`c_ℓ > c_ref`, ratio < 1), which degree counting
//! cannot express.
//!
//! **Eq. 6 is the exact 1-tier special case**: with [`Topology::flat`]
//! (no rack tier, every multiplier 1.0) the only links are the server
//! uplinks, `count × 1.0` reduces to the Eq. 6 count, and the bottleneck
//! degree equals the paper's `p_j[t]` bit for bit under *both* models —
//! the property tests in `tests/topology_equivalence.rs` and
//! `tests/net_equivalence.rs` enforce this.

use crate::cluster::JobPlacement;
use crate::cluster::ServerId;
use crate::net::{ContentionModel, LinkCapacity, DEFAULT_UPLINK_GBPS};
use crate::Result;
use anyhow::bail;

/// Index of a link in the topology (dense; see [`Topology`] for layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

impl std::fmt::Display for LinkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "l{}", self.0)
    }
}

/// Which tier of the fabric a link belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkTier {
    /// Server → ToR (the links of Eq. 6).
    ServerUplink,
    /// ToR → pod switch (or straight to the spine without a pod tier).
    RackUplink,
    /// Pod switch → spine.
    PodUplink,
}

/// The bottleneck link of one job's ring in the current slot: Eq. 6's
/// `p_j[t]` generalized to a multi-tier fabric.
///
/// `p` is the number of active rings crossing the bottleneck link
/// (including the job itself) and `oversub` that link's share multiplier
/// under the fabric's [`ContentionModel`] — the oversubscription factor
/// for `EffectiveDegree`, the capacity ratio `c_ref / c_ℓ` for
/// `MaxMinFair` (the same float whenever the capacity mirrors the
/// factor). The *effective* contention degree driving Eq. 7 is
/// `p × oversub`; equivalently, the ring's allocated bandwidth share is
/// `c_ref / (p × oversub)`. On a flat topology `oversub == 1.0` and `p`
/// is exactly the paper's `p_j[t]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bottleneck {
    /// Active-ring count on the bottleneck link (`p_j[t]` when flat).
    pub p: usize,
    /// Share multiplier of that link (1.0 when flat).
    pub oversub: f64,
    /// The bottleneck link itself; `None` for co-located jobs (no link
    /// crossed).
    pub link: Option<LinkId>,
}

impl Bottleneck {
    /// A co-located job: crosses no link, contention degree 0.
    pub const NONE: Bottleneck = Bottleneck { p: 0, oversub: 1.0, link: None };

    /// The flat-fabric bottleneck with Eq. 6 degree `p` — the adapter the
    /// scalar [`ContentionParams::tau`](crate::contention::ContentionParams::tau)
    /// wrappers use, guaranteeing the 1-tier model is the same code path.
    pub fn flat(p: usize) -> Bottleneck {
        Bottleneck { p, oversub: 1.0, link: None }
    }

    /// Effective contention degree `p × oversub` feeding Eq. 7's
    /// `k_j = ξ1 · p_eff`. Multiplying by 1.0 is exact in IEEE arithmetic,
    /// so the flat case reproduces `p as f64` bit for bit.
    pub fn effective(&self) -> f64 {
        self.p as f64 * self.oversub
    }

    /// Severity order used to pick the bottleneck among crossed links:
    /// larger effective degree wins; on ties the larger raw count (more
    /// informative in reports). Remaining ties keep the first-visited
    /// link, which is deterministic.
    pub fn dominates(&self, other: &Bottleneck) -> bool {
        self.effective() > other.effective()
            || (self.effective() == other.effective() && self.p > other.p)
    }
}

/// The shared-link tree above the servers.
///
/// Link layout: ids `[0, num_servers)` are the server uplinks (tier 0,
/// link `s` belongs to server `s`); ids `[num_servers, num_servers +
/// num_racks)` are the rack uplinks (tier 1) when a rack tier exists;
/// ids above those are the pod uplinks (tier 2) when a pod tier exists.
///
/// Rack assignment must be nondecreasing in server id, and pod assignment
/// nondecreasing in rack id — this lets every crossing query run in
/// `O(span)` with no allocation by grouping a placement's sorted server
/// list into rack and pod runs.
#[derive(Debug, Clone)]
pub struct Topology {
    num_servers: usize,
    /// Rack id per server (nondecreasing); empty ⇒ flat fabric (no rack
    /// tier, Eq. 6 exactly).
    rack_of: Vec<usize>,
    num_racks: usize,
    /// Pod id per *rack* (nondecreasing); empty ⇒ no pod tier.
    pod_of: Vec<usize>,
    num_pods: usize,
    /// Oversubscription factor per link, indexed by [`LinkId`] — the
    /// `EffectiveDegree` multiplier. For absolute-speed fabrics this is
    /// the capacity ratio clamped to ≥ 1 (degree counting cannot express
    /// relief links).
    oversub: Vec<f64>,
    /// Absolute capacity per link — the `MaxMinFair` multiplier source.
    capacity: Vec<LinkCapacity>,
    /// Reference (server-uplink) speed the ratios are taken against.
    ref_gbps: f64,
    /// How consumers evaluate contention at a link.
    model: ContentionModel,
    /// Pristine `(oversub, capacity)` per link, snapshotted lazily on the
    /// first fault-injected degradation so restoration is bit-exact.
    /// Empty on a never-degraded fabric (and in every clone of one).
    pristine: Vec<(f64, LinkCapacity)>,
}

impl Topology {
    /// The paper's implicit 1-tier fabric: server uplinks only, no
    /// oversubscription. Eq. 6 exactly.
    pub fn flat(num_servers: usize) -> Self {
        assert!(num_servers > 0, "topology needs at least one server");
        Topology {
            num_servers,
            rack_of: Vec::new(),
            num_racks: 0,
            pod_of: Vec::new(),
            num_pods: 0,
            oversub: vec![1.0; num_servers],
            capacity: vec![LinkCapacity::reference(DEFAULT_UPLINK_GBPS); num_servers],
            ref_gbps: DEFAULT_UPLINK_GBPS,
            model: ContentionModel::EffectiveDegree,
            pristine: Vec::new(),
        }
    }

    /// A homogeneous rack tier: consecutive groups of `servers_per_rack`
    /// servers share a ToR whose spine uplink is oversubscribed by
    /// `oversub` (1.0 = non-blocking). The last rack may be smaller.
    pub fn racks(num_servers: usize, servers_per_rack: usize, oversub: f64) -> Self {
        assert!(num_servers > 0, "topology needs at least one server");
        assert!(servers_per_rack >= 1, "racks must hold at least one server");
        assert!(oversub >= 1.0, "oversubscription factor must be >= 1");
        let num_racks = (num_servers + servers_per_rack - 1) / servers_per_rack;
        let rack_of = (0..num_servers).map(|s| s / servers_per_rack).collect();
        let mut ov = vec![1.0; num_servers];
        ov.extend(std::iter::repeat(oversub).take(num_racks));
        let mut capacity =
            vec![LinkCapacity::reference(DEFAULT_UPLINK_GBPS); num_servers];
        capacity.extend(
            std::iter::repeat(LinkCapacity::from_oversub(DEFAULT_UPLINK_GBPS, oversub))
                .take(num_racks),
        );
        Topology {
            num_servers,
            rack_of,
            num_racks,
            pod_of: Vec::new(),
            num_pods: 0,
            oversub: ov,
            capacity,
            ref_gbps: DEFAULT_UPLINK_GBPS,
            model: ContentionModel::EffectiveDegree,
            pristine: Vec::new(),
        }
    }

    /// Heterogeneous racks: `rack_sizes[r]` consecutive servers in rack
    /// `r`, each rack uplink with its own oversubscription factor.
    pub fn custom_racks(rack_sizes: &[usize], rack_oversub: &[f64]) -> Self {
        assert!(!rack_sizes.is_empty(), "topology needs at least one rack");
        assert_eq!(rack_sizes.len(), rack_oversub.len(), "one factor per rack");
        assert!(rack_sizes.iter().all(|&n| n >= 1), "racks must hold servers");
        assert!(rack_oversub.iter().all(|&o| o >= 1.0), "oversubscription >= 1");
        let num_servers: usize = rack_sizes.iter().sum();
        let mut rack_of = Vec::with_capacity(num_servers);
        for (r, &n) in rack_sizes.iter().enumerate() {
            rack_of.extend(std::iter::repeat(r).take(n));
        }
        let mut oversub = vec![1.0; num_servers];
        oversub.extend_from_slice(rack_oversub);
        let mut capacity =
            vec![LinkCapacity::reference(DEFAULT_UPLINK_GBPS); num_servers];
        capacity.extend(
            rack_oversub.iter().map(|&o| LinkCapacity::from_oversub(DEFAULT_UPLINK_GBPS, o)),
        );
        Topology {
            num_servers,
            rack_of,
            num_racks: rack_sizes.len(),
            pod_of: Vec::new(),
            num_pods: 0,
            oversub,
            capacity,
            ref_gbps: DEFAULT_UPLINK_GBPS,
            model: ContentionModel::EffectiveDegree,
            pristine: Vec::new(),
        }
    }

    /// A homogeneous rack tier with **absolute link speeds**: server
    /// uplinks at `uplink_gbps` (the reference), ToR uplinks at
    /// `tor_gbps`. `tor_gbps > uplink_gbps` models a relief link the
    /// scalar-oversub form cannot express; the `EffectiveDegree`
    /// multiplier clamps its ratio at 1.
    // archlint: allow(release-panic) constructor fills link vectors it just sized (l < num_links by construction)
    pub fn racks_gbps(
        num_servers: usize,
        servers_per_rack: usize,
        uplink_gbps: f64,
        tor_gbps: f64,
    ) -> Self {
        assert!(uplink_gbps > 0.0 && tor_gbps > 0.0, "link speeds must be positive");
        let mut t = Topology::racks(num_servers, servers_per_rack, 1.0);
        t.ref_gbps = uplink_gbps;
        for l in 0..t.num_servers {
            t.capacity[l] = LinkCapacity::reference(uplink_gbps);
        }
        for r in 0..t.num_racks {
            let cap = LinkCapacity::from_gbps(uplink_gbps, tor_gbps);
            t.oversub[t.num_servers + r] = cap.ratio.max(1.0);
            t.capacity[t.num_servers + r] = cap;
        }
        t
    }

    /// A 3-tier fabric: racks of `servers_per_rack` servers, pods of
    /// `racks_per_pod` racks, with per-tier oversubscription factors.
    /// The last rack and last pod may be smaller.
    pub fn pods(
        num_servers: usize,
        servers_per_rack: usize,
        racks_per_pod: usize,
        tor_oversub: f64,
        pod_oversub: f64,
    ) -> Self {
        assert!(racks_per_pod >= 1, "pods must hold at least one rack");
        assert!(pod_oversub >= 1.0, "oversubscription factor must be >= 1");
        let mut t = Topology::racks(num_servers, servers_per_rack, tor_oversub);
        let num_pods = (t.num_racks + racks_per_pod - 1) / racks_per_pod;
        t.pod_of = (0..t.num_racks).map(|r| r / racks_per_pod).collect();
        t.num_pods = num_pods;
        t.oversub.extend(std::iter::repeat(pod_oversub).take(num_pods));
        t.capacity.extend(
            std::iter::repeat(LinkCapacity::from_oversub(DEFAULT_UPLINK_GBPS, pod_oversub))
                .take(num_pods),
        );
        t
    }

    /// A 3-tier fabric with absolute link speeds per tier.
    // archlint: allow(release-panic) constructor fills link vectors it just sized (l < num_links by construction)
    pub fn pods_gbps(
        num_servers: usize,
        servers_per_rack: usize,
        racks_per_pod: usize,
        uplink_gbps: f64,
        tor_gbps: f64,
        pod_gbps: f64,
    ) -> Self {
        assert!(
            uplink_gbps > 0.0 && tor_gbps > 0.0 && pod_gbps > 0.0,
            "link speeds must be positive"
        );
        let mut t = Topology::pods(num_servers, servers_per_rack, racks_per_pod, 1.0, 1.0);
        t.ref_gbps = uplink_gbps;
        for l in 0..t.num_servers {
            t.capacity[l] = LinkCapacity::reference(uplink_gbps);
        }
        for r in 0..t.num_racks {
            let cap = LinkCapacity::from_gbps(uplink_gbps, tor_gbps);
            t.oversub[t.num_servers + r] = cap.ratio.max(1.0);
            t.capacity[t.num_servers + r] = cap;
        }
        for p in 0..t.num_pods {
            let cap = LinkCapacity::from_gbps(uplink_gbps, pod_gbps);
            t.oversub[t.num_servers + t.num_racks + p] = cap.ratio.max(1.0);
            t.capacity[t.num_servers + t.num_racks + p] = cap;
        }
        t
    }

    /// Select the contention model consumers of this fabric evaluate
    /// under (builder style; default [`ContentionModel::EffectiveDegree`]).
    pub fn with_model(mut self, model: ContentionModel) -> Self {
        self.model = model;
        self
    }

    /// The active contention model.
    pub fn model(&self) -> ContentionModel {
        self.model
    }

    /// Number of servers (tier-0 leaves).
    pub fn num_servers(&self) -> usize {
        self.num_servers
    }

    /// Number of racks; 0 for a flat fabric.
    pub fn num_racks(&self) -> usize {
        self.num_racks
    }

    /// Number of pods; 0 without a pod tier.
    pub fn num_pods(&self) -> usize {
        self.num_pods
    }

    /// Total number of links in the tree.
    pub fn num_links(&self) -> usize {
        self.oversub.len()
    }

    /// Whether a rack tier exists. A flat fabric recovers Eq. 6 exactly;
    /// topology-aware placement tie-breaks are no-ops on it.
    pub fn has_racks(&self) -> bool {
        self.num_racks > 0
    }

    /// Whether a pod tier exists above the racks.
    pub fn has_pods(&self) -> bool {
        self.num_pods > 0
    }

    /// Oversubscription factor of one link (the `EffectiveDegree`
    /// multiplier; ≥ 1 always).
    pub fn oversub(&self, l: LinkId) -> f64 {
        self.oversub[l.0]
    }

    /// Absolute capacity of one link in Gbps.
    pub fn link_gbps(&self, l: LinkId) -> f64 {
        self.capacity[l.0].gbps
    }

    /// Capacity ratio `c_ref / c_ℓ` of one link (the `MaxMinFair`
    /// multiplier; may be < 1 for relief links).
    pub fn capacity_ratio(&self, l: LinkId) -> f64 {
        self.capacity[l.0].ratio
    }

    /// The reference (server-uplink) speed ratios are taken against.
    pub fn reference_gbps(&self) -> f64 {
        self.ref_gbps
    }

    /// The share multiplier a crossed link contributes under the active
    /// [`ContentionModel`]: the oversubscription factor for
    /// `EffectiveDegree`, the capacity ratio for `MaxMinFair`. Identical
    /// floats on every oversub-derived fabric — the bit-for-bit
    /// equivalence the `net` module documents.
    pub fn multiplier(&self, l: LinkId) -> f64 {
        match self.model {
            ContentionModel::EffectiveDegree => self.oversub[l.0],
            ContentionModel::MaxMinFair => self.capacity[l.0].ratio,
        }
    }

    /// Fault injection: link `l` drops to `factor` (0, 1] of its pristine
    /// capacity. Both per-link multiplier sources move together —
    /// capacity scales by `factor`, ratio and oversubscription by
    /// `1/factor` — so the change flows to every consumer through
    /// [`multiplier`](Self::multiplier) with no new seam, under either
    /// [`ContentionModel`]. Degradations don't compound: the factor is
    /// always against the pristine value (snapshotted on first use), and
    /// `factor == 1.0` restores it bit for bit. Out-of-range links are
    /// ignored (fault traces are validated against a cluster, but a
    /// capacity change must never panic mid-run).
    pub fn degrade_link(&mut self, l: LinkId, factor: f64) {
        debug_assert!(factor > 0.0 && factor <= 1.0, "degrade factor {factor} out of (0, 1]");
        if l.0 >= self.oversub.len() || !(factor > 0.0 && factor <= 1.0) {
            return;
        }
        if self.pristine.is_empty() {
            self.pristine = self
                .oversub
                .iter()
                .zip(self.capacity.iter())
                .map(|(&o, &c)| (o, c))
                .collect();
        }
        let Some(&(base_oversub, base_cap)) = self.pristine.get(l.0) else { return };
        if factor >= 1.0 {
            self.oversub[l.0] = base_oversub;
            self.capacity[l.0] = base_cap;
        } else {
            self.oversub[l.0] = base_oversub / factor;
            self.capacity[l.0] =
                LinkCapacity { gbps: base_cap.gbps * factor, ratio: base_cap.ratio / factor };
        }
    }

    /// Fault injection: link `l` returns to its pristine capacity
    /// (bit-identical multipliers to a never-degraded fabric).
    pub fn restore_link(&mut self, l: LinkId) {
        if !self.pristine.is_empty() {
            self.degrade_link(l, 1.0);
        }
    }

    /// Which tier a link belongs to.
    pub fn tier(&self, l: LinkId) -> LinkTier {
        if l.0 < self.num_servers {
            LinkTier::ServerUplink
        } else if l.0 < self.num_servers + self.num_racks {
            LinkTier::RackUplink
        } else {
            LinkTier::PodUplink
        }
    }

    /// The uplink of server `s` (tier 0 — the Eq. 6 link).
    pub fn server_uplink(&self, s: ServerId) -> LinkId {
        debug_assert!(s.0 < self.num_servers);
        LinkId(s.0)
    }

    /// The uplink of rack `r` (tier 1). Panics on a flat fabric.
    pub fn rack_uplink(&self, r: usize) -> LinkId {
        assert!(r < self.num_racks, "rack {r} out of range (flat fabric?)");
        LinkId(self.num_servers + r)
    }

    /// The spine uplink of pod `p` (tier 2). Panics without a pod tier.
    pub fn pod_uplink(&self, p: usize) -> LinkId {
        assert!(p < self.num_pods, "pod {p} out of range (no pod tier?)");
        LinkId(self.num_servers + self.num_racks + p)
    }

    /// Rack index of a server. On a flat fabric every server is its own
    /// "rack" — the natural degenerate grouping schedulers can rely on.
    pub fn rack_index(&self, s: ServerId) -> usize {
        if self.rack_of.is_empty() { s.0 } else { self.rack_of[s.0] }
    }

    /// Pod index of a rack. Without a pod tier every rack is its own
    /// "pod" (same degenerate rule as [`rack_index`](Self::rack_index)).
    pub fn pod_of_rack(&self, rack: usize) -> usize {
        // archlint: allow(release-panic) pod_of is sized num_racks at construction; rack ids are dense
        if self.pod_of.is_empty() { rack } else { self.pod_of[rack] }
    }

    /// Pod index of a server.
    pub fn pod_index(&self, s: ServerId) -> usize {
        self.pod_of_rack(self.rack_index(s))
    }

    /// Servers of one rack, in id order.
    pub fn servers_in_rack(&self, rack: usize) -> impl Iterator<Item = ServerId> + '_ {
        (0..self.num_servers)
            .filter(move |&s| self.rack_index(ServerId(s)) == rack)
            .map(ServerId)
    }

    /// Servers of one pod, in id order.
    pub fn servers_in_pod(&self, pod: usize) -> impl Iterator<Item = ServerId> + '_ {
        (0..self.num_servers)
            .filter(move |&s| self.pod_index(ServerId(s)) == pod)
            .map(ServerId)
    }

    /// Visit every link crossed by `placement`'s ring — the generalized
    /// Eq. 6 indicator `0 < Σ_{s ∈ sub(ℓ)} y_js < G_j` — in `O(span)` with
    /// no allocation. Co-located jobs cross nothing.
    // archlint: allow(release-panic) rack_of/pod_of are dense id maps sized at construction
    pub fn for_each_crossed(&self, placement: &JobPlacement, mut f: impl FnMut(LinkId)) {
        if !placement.is_spread() {
            return; // span 1: every subtree holds all or none of the workers
        }
        let total = placement.num_workers();
        if self.rack_of.is_empty() {
            // Flat: exactly the Eq. 6 server-uplink indicators.
            for s in placement.servers() {
                f(self.server_uplink(s));
            }
            return;
        }
        // Servers iterate in ascending id order, rack assignment is
        // nondecreasing in server id and pod assignment nondecreasing in
        // rack id, so used racks (and pods) form contiguous runs:
        // accumulate each run's worker count and emit its uplink when the
        // subtree holds a strict subset of the ring.
        let has_pods = !self.pod_of.is_empty();
        let mut cur_rack = usize::MAX;
        let mut in_rack = 0usize;
        let mut cur_pod = usize::MAX;
        let mut in_pod = 0usize;
        for s in placement.servers() {
            // a spread ring crosses every used server's uplink (y < G_j)
            f(self.server_uplink(s));
            let r = self.rack_of[s.0];
            if r != cur_rack {
                if cur_rack != usize::MAX && in_rack < total {
                    f(self.rack_uplink(cur_rack));
                }
                if has_pods {
                    let p = self.pod_of[r];
                    if p != cur_pod {
                        if cur_pod != usize::MAX && in_pod < total {
                            f(self.pod_uplink(cur_pod));
                        }
                        cur_pod = p;
                        in_pod = 0;
                    }
                }
                cur_rack = r;
                in_rack = 0;
            }
            in_rack += placement.gpus_on(s);
            if has_pods {
                in_pod += placement.gpus_on(s);
            }
        }
        if cur_rack != usize::MAX && in_rack < total {
            f(self.rack_uplink(cur_rack));
        }
        if has_pods && cur_pod != usize::MAX && in_pod < total {
            f(self.pod_uplink(cur_pod));
        }
    }

    /// All links crossed by a placement (allocating convenience wrapper of
    /// [`for_each_crossed`](Self::for_each_crossed)).
    pub fn crossed_links(&self, placement: &JobPlacement) -> Vec<LinkId> {
        let mut out = Vec::new();
        self.for_each_crossed(placement, |l| out.push(l));
        out
    }

    /// The bottleneck of a placement given per-link active-ring counts
    /// (`counts[l.0]`): the crossed link with the largest effective degree
    /// `count × multiplier` under the active [`ContentionModel`].
    /// [`Bottleneck::NONE`] for co-located jobs.
    pub fn bottleneck(&self, placement: &JobPlacement, counts: &[usize]) -> Bottleneck {
        debug_assert_eq!(counts.len(), self.num_links());
        let mut best = Bottleneck::NONE;
        self.for_each_crossed(placement, |l| {
            let cand =
                Bottleneck { p: counts[l.0], oversub: self.multiplier(l), link: Some(l) };
            if best.link.is_none() || cand.dominates(&best) {
                best = cand;
            }
        });
        best
    }

    /// Human-readable link name for logs and reports.
    pub fn describe(&self, l: LinkId) -> String {
        match self.tier(l) {
            LinkTier::ServerUplink => format!("uplink(s{})", l.0),
            LinkTier::RackUplink => format!("tor(r{})", l.0 - self.num_servers),
            LinkTier::PodUplink => {
                format!("pod(p{})", l.0 - self.num_servers - self.num_racks)
            }
        }
    }
}

/// CLI / config form of a topology, resolved against a cluster's server
/// count at build time:
///
/// * `flat`
/// * `rack:<servers_per_rack>[:<oversub>]` — scalar oversubscription
/// * `rack:<servers_per_rack>:<uplink_gbps>@<tor_gbps>` — absolute speeds
/// * `pod:<racks_per_pod>:<servers_per_rack>[:<tor_oversub>[:<pod_oversub>]]`
/// * `pod:<racks_per_pod>:<servers_per_rack>:<uplink>@<tor>@<pod>` (Gbps)
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopologySpec {
    /// 1-tier fabric (the paper's model).
    Flat,
    /// Homogeneous racks with an oversubscribed ToR uplink.
    Rack { servers_per_rack: usize, oversub: f64 },
    /// Homogeneous racks with absolute per-tier link speeds.
    RackGbps { servers_per_rack: usize, uplink_gbps: f64, tor_gbps: f64 },
    /// 3-tier fabric with per-tier oversubscription factors.
    Pod {
        racks_per_pod: usize,
        servers_per_rack: usize,
        tor_oversub: f64,
        pod_oversub: f64,
    },
    /// 3-tier fabric with absolute per-tier link speeds.
    PodGbps {
        racks_per_pod: usize,
        servers_per_rack: usize,
        uplink_gbps: f64,
        tor_gbps: f64,
        pod_gbps: f64,
    },
}

impl Default for TopologySpec {
    fn default() -> Self {
        TopologySpec::Flat
    }
}

impl TopologySpec {
    /// Materialise for a concrete cluster size.
    pub fn build(&self, num_servers: usize) -> Topology {
        match *self {
            TopologySpec::Flat => Topology::flat(num_servers),
            TopologySpec::Rack { servers_per_rack, oversub } => {
                Topology::racks(num_servers, servers_per_rack, oversub)
            }
            TopologySpec::RackGbps { servers_per_rack, uplink_gbps, tor_gbps } => {
                Topology::racks_gbps(num_servers, servers_per_rack, uplink_gbps, tor_gbps)
            }
            TopologySpec::Pod { racks_per_pod, servers_per_rack, tor_oversub, pod_oversub } => {
                Topology::pods(
                    num_servers,
                    servers_per_rack,
                    racks_per_pod,
                    tor_oversub,
                    pod_oversub,
                )
            }
            TopologySpec::PodGbps {
                racks_per_pod,
                servers_per_rack,
                uplink_gbps,
                tor_gbps,
                pod_gbps,
            } => Topology::pods_gbps(
                num_servers,
                servers_per_rack,
                racks_per_pod,
                uplink_gbps,
                tor_gbps,
                pod_gbps,
            ),
        }
    }
}

impl std::fmt::Display for TopologySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologySpec::Flat => f.write_str("flat"),
            TopologySpec::Rack { servers_per_rack, oversub } => {
                write!(f, "rack:{servers_per_rack}:{oversub}")
            }
            TopologySpec::RackGbps { servers_per_rack, uplink_gbps, tor_gbps } => {
                write!(f, "rack:{servers_per_rack}:{uplink_gbps}@{tor_gbps}")
            }
            TopologySpec::Pod { racks_per_pod, servers_per_rack, tor_oversub, pod_oversub } => {
                write!(f, "pod:{racks_per_pod}:{servers_per_rack}:{tor_oversub}:{pod_oversub}")
            }
            TopologySpec::PodGbps {
                racks_per_pod,
                servers_per_rack,
                uplink_gbps,
                tor_gbps,
                pod_gbps,
            } => write!(
                f,
                "pod:{racks_per_pod}:{servers_per_rack}:{uplink_gbps}@{tor_gbps}@{pod_gbps}"
            ),
        }
    }
}

fn parse_oversub(s: &str) -> Result<f64> {
    let o: f64 = s.parse().map_err(|_| anyhow::anyhow!("bad oversub '{s}'"))?;
    if !(o >= 1.0) {
        bail!("oversubscription factor must be >= 1, got {o}");
    }
    Ok(o)
}

fn parse_gbps(s: &str) -> Result<f64> {
    let g: f64 = s.parse().map_err(|_| anyhow::anyhow!("bad link speed '{s}'"))?;
    if !(g > 0.0) {
        bail!("link speed must be positive Gbps, got {g}");
    }
    Ok(g)
}

impl std::str::FromStr for TopologySpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        if s.eq_ignore_ascii_case("flat") {
            return Ok(TopologySpec::Flat);
        }
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["rack", spr, rest @ ..] if rest.len() <= 1 => {
                let servers_per_rack: usize =
                    spr.parse().map_err(|_| anyhow::anyhow!("bad rack size '{spr}'"))?;
                if servers_per_rack == 0 {
                    bail!("rack size must be >= 1");
                }
                match rest.first() {
                    None => Ok(TopologySpec::Rack { servers_per_rack, oversub: 1.0 }),
                    Some(tail) => match tail.split_once('@') {
                        // absolute-speed form: <uplink_gbps>@<tor_gbps>
                        Some((up, tor)) => Ok(TopologySpec::RackGbps {
                            servers_per_rack,
                            uplink_gbps: parse_gbps(up)?,
                            tor_gbps: parse_gbps(tor)?,
                        }),
                        None => Ok(TopologySpec::Rack {
                            servers_per_rack,
                            oversub: parse_oversub(tail)?,
                        }),
                    },
                }
            }
            ["pod", rpp, spr, rest @ ..] if rest.len() <= 2 => {
                let racks_per_pod: usize =
                    rpp.parse().map_err(|_| anyhow::anyhow!("bad pod size '{rpp}'"))?;
                let servers_per_rack: usize =
                    spr.parse().map_err(|_| anyhow::anyhow!("bad rack size '{spr}'"))?;
                if racks_per_pod == 0 {
                    bail!("pod size must be >= 1 rack");
                }
                if servers_per_rack == 0 {
                    bail!("rack size must be >= 1");
                }
                match rest {
                    [] => Ok(TopologySpec::Pod {
                        racks_per_pod,
                        servers_per_rack,
                        tor_oversub: 1.0,
                        pod_oversub: 1.0,
                    }),
                    [one] => match one.split_once('@') {
                        // absolute-speed form: <uplink>@<tor>@<pod>
                        Some((up, tail)) => {
                            let (tor, pod) = tail.split_once('@').ok_or_else(|| {
                                anyhow::anyhow!(
                                    "pod speeds need <uplink>@<tor>@<pod> Gbps, got '{one}'"
                                )
                            })?;
                            Ok(TopologySpec::PodGbps {
                                racks_per_pod,
                                servers_per_rack,
                                uplink_gbps: parse_gbps(up)?,
                                tor_gbps: parse_gbps(tor)?,
                                pod_gbps: parse_gbps(pod)?,
                            })
                        }
                        None => Ok(TopologySpec::Pod {
                            racks_per_pod,
                            servers_per_rack,
                            tor_oversub: parse_oversub(one)?,
                            pod_oversub: 1.0,
                        }),
                    },
                    [tor_o, pod_o] => Ok(TopologySpec::Pod {
                        racks_per_pod,
                        servers_per_rack,
                        tor_oversub: parse_oversub(tor_o)?,
                        pod_oversub: parse_oversub(pod_o)?,
                    }),
                    // archlint: allow(release-panic) match arm guarded by rest.len() <= 2 above
                    _ => unreachable!("guarded by rest.len() <= 2"),
                }
            }
            _ => bail!(
                "unknown topology '{s}' (expected flat | rack:<spr>[:<oversub>] | \
                 rack:<spr>:<up_gbps>@<tor_gbps> | pod:<rpp>:<spr>[:<tor_o>[:<pod_o>]] | \
                 pod:<rpp>:<spr>:<up>@<tor>@<pod>)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    fn place(c: &Cluster, pairs: &[(usize, usize)]) -> JobPlacement {
        JobPlacement::new(
            pairs.iter().map(|&(s, i)| c.global_gpu(ServerId(s), i)).collect(),
        )
    }

    #[test]
    fn flat_crossing_is_eq6() {
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        let t = Topology::flat(4);
        assert!(!t.has_racks());
        assert_eq!(t.num_links(), 4);
        // spread over servers 0 and 2: exactly those uplinks
        let pl = place(&c, &[(0, 0), (0, 1), (2, 0)]);
        assert_eq!(t.crossed_links(&pl), vec![LinkId(0), LinkId(2)]);
        // co-located: nothing
        assert!(t.crossed_links(&place(&c, &[(1, 0), (1, 1)])).is_empty());
    }

    #[test]
    fn rack_crossing_adds_tor_uplinks_only_across_racks() {
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        // racks {0,1} and {2,3}
        let t = Topology::racks(4, 2, 2.0);
        assert_eq!(t.num_racks(), 2);
        assert_eq!(t.num_links(), 6);
        // intra-rack spread (servers 0,1): server uplinks crossed, the
        // whole ring stays below the ToR — no rack uplink.
        let intra = place(&c, &[(0, 0), (1, 0)]);
        assert_eq!(t.crossed_links(&intra), vec![LinkId(0), LinkId(1)]);
        // cross-rack spread (servers 1,2): both server uplinks AND both
        // rack uplinks (rack runs flush after their last server).
        let cross = place(&c, &[(1, 0), (2, 0)]);
        let mut links = t.crossed_links(&cross);
        links.sort();
        assert_eq!(links, vec![LinkId(1), LinkId(2), t.rack_uplink(0), t.rack_uplink(1)]);
    }

    #[test]
    fn uneven_last_rack_and_custom_racks() {
        let t = Topology::racks(5, 2, 1.5);
        assert_eq!(t.num_racks(), 3);
        assert_eq!(t.rack_index(ServerId(4)), 2);
        assert_eq!(t.servers_in_rack(2).count(), 1);
        let h = Topology::custom_racks(&[3, 1], &[1.0, 4.0]);
        assert_eq!(h.num_servers(), 4);
        assert_eq!(h.oversub(h.rack_uplink(1)), 4.0);
        assert_eq!(h.rack_index(ServerId(2)), 0);
        assert_eq!(h.tier(LinkId(4)), LinkTier::RackUplink);
    }

    #[test]
    fn bottleneck_prefers_effective_degree() {
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        let t = Topology::racks(4, 2, 2.0);
        // one cross-rack job; counts: its server uplinks 1 each, rack
        // uplinks 1 each → effective 1·2 = 2 on the ToR beats 1·1.
        let pl = place(&c, &[(0, 0), (2, 0)]);
        let mut counts = vec![0usize; t.num_links()];
        t.for_each_crossed(&pl, |l| counts[l.0] += 1);
        let bn = t.bottleneck(&pl, &counts);
        assert_eq!(bn.link, Some(t.rack_uplink(0)));
        assert_eq!(bn.p, 1);
        assert_eq!(bn.oversub, 2.0);
        assert_eq!(bn.effective(), 2.0);
    }

    #[test]
    fn colocated_bottleneck_is_none() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let t = Topology::racks(2, 2, 8.0);
        let pl = place(&c, &[(0, 0), (0, 1)]);
        let counts = vec![0usize; t.num_links()];
        assert_eq!(t.bottleneck(&pl, &counts), Bottleneck::NONE);
    }

    #[test]
    fn spec_parsing_roundtrip() {
        assert_eq!("flat".parse::<TopologySpec>().unwrap(), TopologySpec::Flat);
        let r: TopologySpec = "rack:4:2.5".parse().unwrap();
        assert_eq!(r, TopologySpec::Rack { servers_per_rack: 4, oversub: 2.5 });
        assert_eq!(r.to_string().parse::<TopologySpec>().unwrap(), r);
        let d: TopologySpec = "rack:8".parse().unwrap();
        assert_eq!(d, TopologySpec::Rack { servers_per_rack: 8, oversub: 1.0 });
        assert!("rack:0:2".parse::<TopologySpec>().is_err());
        assert!("rack:4:0.5".parse::<TopologySpec>().is_err());
        assert!("mesh".parse::<TopologySpec>().is_err());
        assert!("rack:4:2:9".parse::<TopologySpec>().is_err());
    }

    #[test]
    fn gbps_spec_forms_parse_and_roundtrip() {
        let r: TopologySpec = "rack:4:25@100".parse().unwrap();
        assert_eq!(
            r,
            TopologySpec::RackGbps { servers_per_rack: 4, uplink_gbps: 25.0, tor_gbps: 100.0 }
        );
        assert_eq!(r.to_string().parse::<TopologySpec>().unwrap(), r);
        let p: TopologySpec = "pod:2:4:25@50@100".parse().unwrap();
        assert_eq!(
            p,
            TopologySpec::PodGbps {
                racks_per_pod: 2,
                servers_per_rack: 4,
                uplink_gbps: 25.0,
                tor_gbps: 50.0,
                pod_gbps: 100.0
            }
        );
        assert_eq!(p.to_string().parse::<TopologySpec>().unwrap(), p);
        assert!("rack:4:0@10".parse::<TopologySpec>().is_err());
        assert!("pod:2:4:25@50".parse::<TopologySpec>().is_err(), "pods need 3 speeds");
    }

    #[test]
    fn pod_spec_forms_parse_and_roundtrip() {
        let p: TopologySpec = "pod:2:4".parse().unwrap();
        assert_eq!(
            p,
            TopologySpec::Pod {
                racks_per_pod: 2,
                servers_per_rack: 4,
                tor_oversub: 1.0,
                pod_oversub: 1.0
            }
        );
        let p: TopologySpec = "pod:2:4:2.0:3.0".parse().unwrap();
        assert_eq!(
            p,
            TopologySpec::Pod {
                racks_per_pod: 2,
                servers_per_rack: 4,
                tor_oversub: 2.0,
                pod_oversub: 3.0
            }
        );
        assert_eq!(p.to_string().parse::<TopologySpec>().unwrap(), p);
        assert!("pod:0:4".parse::<TopologySpec>().is_err());
        assert!("pod:2:0".parse::<TopologySpec>().is_err());
        assert!("pod:2:4:0.5".parse::<TopologySpec>().is_err());
        assert!("pod:2:4:2:3:4".parse::<TopologySpec>().is_err());
    }

    #[test]
    fn spec_builds_matching_topology() {
        let t = TopologySpec::Rack { servers_per_rack: 3, oversub: 2.0 }.build(7);
        assert_eq!(t.num_racks(), 3);
        assert_eq!(t.num_servers(), 7);
        assert_eq!(TopologySpec::Flat.build(5).num_links(), 5);
        // a 3-tier build: 8 servers, racks of 2, pods of 2 racks
        let t = TopologySpec::Pod {
            racks_per_pod: 2,
            servers_per_rack: 2,
            tor_oversub: 2.0,
            pod_oversub: 4.0,
        }
        .build(8);
        assert_eq!((t.num_racks(), t.num_pods()), (4, 2));
        assert_eq!(t.num_links(), 8 + 4 + 2);
        assert_eq!(t.oversub(t.pod_uplink(1)), 4.0);
        assert_eq!(t.tier(t.pod_uplink(0)), LinkTier::PodUplink);
    }

    #[test]
    fn pod_tier_membership_and_uplinks() {
        // 12 servers, racks of 2 (6 racks), pods of 3 racks (2 pods)
        let t = Topology::pods(12, 2, 3, 2.0, 4.0);
        assert!(t.has_pods());
        assert_eq!(t.num_pods(), 2);
        assert_eq!(t.pod_index(ServerId(0)), 0);
        assert_eq!(t.pod_index(ServerId(5)), 0, "rack 2 is still pod 0");
        assert_eq!(t.pod_index(ServerId(6)), 1, "rack 3 starts pod 1");
        assert_eq!(t.servers_in_pod(0).count(), 6);
        assert_eq!(t.pod_of_rack(4), 1);
        assert_eq!(t.describe(t.pod_uplink(1)), "pod(p1)");
        // flat fabrics degrade to every-rack-its-own-pod
        let flat = Topology::flat(3);
        assert!(!flat.has_pods());
        assert_eq!(flat.pod_index(ServerId(2)), 2);
    }

    #[test]
    fn pod_crossing_adds_pod_uplinks_only_across_pods() {
        // 8 servers, racks of 2, pods of 2 racks: pod 0 = servers 0-3,
        // pod 1 = servers 4-7.
        let c = Cluster::uniform(8, 4, 1.0, 25.0);
        let t = Topology::pods(8, 2, 2, 2.0, 4.0);
        // cross-rack but intra-pod (servers 1, 2): rack uplinks crossed,
        // no pod uplink — the ring stays below pod 0's switch.
        let intra_pod = place(&c, &[(1, 0), (2, 0)]);
        let mut links = t.crossed_links(&intra_pod);
        links.sort();
        assert_eq!(
            links,
            vec![LinkId(1), LinkId(2), t.rack_uplink(0), t.rack_uplink(1)]
        );
        // cross-pod (servers 3, 4): server + rack + BOTH pod uplinks.
        let cross_pod = place(&c, &[(3, 0), (4, 0)]);
        let mut links = t.crossed_links(&cross_pod);
        links.sort();
        assert_eq!(
            links,
            vec![
                LinkId(3),
                LinkId(4),
                t.rack_uplink(1),
                t.rack_uplink(2),
                t.pod_uplink(0),
                t.pod_uplink(1)
            ]
        );
    }

    #[test]
    fn oversubscribed_pod_uplink_becomes_the_bottleneck() {
        let c = Cluster::uniform(8, 4, 1.0, 25.0);
        let t = Topology::pods(8, 2, 2, 1.0, 8.0);
        let pl = place(&c, &[(0, 0), (7, 0)]); // crosses both pod uplinks
        let mut counts = vec![0usize; t.num_links()];
        t.for_each_crossed(&pl, |l| counts[l.0] += 1);
        let bn = t.bottleneck(&pl, &counts);
        assert_eq!(bn.oversub, 8.0);
        assert!(
            bn.link == Some(t.pod_uplink(0)) || bn.link == Some(t.pod_uplink(1)),
            "bottleneck {:?}",
            bn.link
        );
    }

    #[test]
    fn capacities_mirror_oversub_specs_exactly() {
        let t = Topology::racks(4, 2, 2.5);
        for l in 0..t.num_links() {
            let l = LinkId(l);
            assert_eq!(t.capacity_ratio(l), t.oversub(l), "{l}: ratio is the factor itself");
            // the two model multipliers agree on oversub-derived fabrics
            assert_eq!(
                t.clone().with_model(ContentionModel::MaxMinFair).multiplier(l),
                t.multiplier(l),
                "{l}"
            );
        }
        assert_eq!(t.link_gbps(t.rack_uplink(0)), DEFAULT_UPLINK_GBPS / 2.5);
        assert_eq!(t.reference_gbps(), DEFAULT_UPLINK_GBPS);
    }

    #[test]
    fn relief_links_diverge_between_models() {
        // ToR at 4x the uplink speed: ratio 0.25, but the degree model
        // clamps its factor at 1 (it cannot express relief capacity).
        let t = Topology::racks_gbps(4, 2, 25.0, 100.0);
        let tor = t.rack_uplink(0);
        assert_eq!(t.capacity_ratio(tor), 0.25);
        assert_eq!(t.oversub(tor), 1.0, "clamped for degree counting");
        assert_eq!(t.link_gbps(tor), 100.0);
        assert_eq!(t.reference_gbps(), 25.0);
        let mm = t.clone().with_model(ContentionModel::MaxMinFair);
        assert_eq!(mm.multiplier(tor), 0.25);
        assert_eq!(t.multiplier(tor), 1.0);
        // a skinny ToR (half the uplink speed) is expressible both ways
        // and the multipliers agree
        let skinny = Topology::racks_gbps(4, 2, 25.0, 12.5);
        assert_eq!(skinny.oversub(skinny.rack_uplink(0)), 2.0);
        assert_eq!(skinny.capacity_ratio(skinny.rack_uplink(0)), 2.0);
    }

    #[test]
    fn maxmin_bottleneck_shifts_where_degree_counting_cannot() {
        // Relief ToR (4x uplink capacity): 3 rings on the ToR vs 2 on a
        // server uplink. Degree counting bottlenecks on the raw count 3;
        // the share model discounts the fat link (3 x 0.25 = 0.75) and
        // keeps the bottleneck at the skinny uplink (2 x 1.0 = 2).
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        let t = Topology::racks_gbps(4, 2, 25.0, 100.0);
        let pl = place(&c, &[(0, 0), (2, 0)]); // crosses s0 uplink + both ToRs
        let mut counts = vec![0usize; t.num_links()];
        counts[0] = 2; // server 0 uplink: 2 rings
        counts[t.rack_uplink(0).0] = 3; // ToR 0: 3 rings
        counts[2] = 1;
        counts[t.rack_uplink(1).0] = 3;
        let degree_bn = t.bottleneck(&pl, &counts);
        assert_eq!(degree_bn.p, 3, "degree counting picks the crowded ToR");
        let mm = t.clone().with_model(ContentionModel::MaxMinFair);
        let share_bn = mm.bottleneck(&pl, &counts);
        assert_eq!(share_bn.link, Some(t.server_uplink(ServerId(0))));
        assert_eq!((share_bn.p, share_bn.oversub), (2, 1.0));
    }

    #[test]
    fn degrade_and_restore_are_bit_exact() {
        let pristine = Topology::racks(8, 4, 3.0);
        let mut t = pristine.clone();
        let l = t.rack_uplink(0);
        let (o0, g0, r0) = (t.oversub(l), t.link_gbps(l), t.capacity_ratio(l));
        t.degrade_link(l, 0.25);
        assert_eq!(t.oversub(l), o0 / 0.25);
        assert_eq!(t.link_gbps(l), g0 * 0.25);
        assert_eq!(t.capacity_ratio(l), r0 / 0.25);
        assert_eq!(t.multiplier(l), o0 / 0.25, "EffectiveDegree sees the degradation");
        // degradations replace, never compound: a second factor is still
        // taken against the pristine value
        t.degrade_link(l, 0.5);
        assert_eq!(t.oversub(l), o0 / 0.5);
        // restore is bit-identical to never having degraded
        t.restore_link(l);
        assert_eq!((t.oversub(l), t.link_gbps(l), t.capacity_ratio(l)), (o0, g0, r0));
        assert_eq!(t.multiplier(l), pristine.multiplier(l));
        // other links are untouched throughout
        let other = t.server_uplink(ServerId(2));
        assert_eq!(t.multiplier(other), pristine.multiplier(other));
        // restore on a never-degraded fabric is a no-op
        let mut fresh = pristine.clone();
        fresh.restore_link(l);
        assert_eq!(fresh.multiplier(l), pristine.multiplier(l));
    }

    #[test]
    fn degraded_link_moves_the_bottleneck_under_both_models() {
        // 2 racks of 2 servers, no oversubscription: a ring across the
        // racks sees multiplier 1.0 everywhere. Degrade rack 0's uplink
        // to half capacity and it becomes the bottleneck at equal counts.
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        for model in [ContentionModel::EffectiveDegree, ContentionModel::MaxMinFair] {
            let mut t = Topology::racks(4, 2, 1.0).with_model(model);
            let pl = place(&c, &[(0, 0), (2, 0)]);
            let counts = vec![2usize; t.num_links()];
            let before = t.bottleneck(&pl, &counts);
            assert_eq!(before.oversub, 1.0);
            t.degrade_link(t.rack_uplink(0), 0.5);
            let after = t.bottleneck(&pl, &counts);
            assert_eq!(after.link, Some(t.rack_uplink(0)), "{model:?}");
            assert_eq!(after.oversub, 2.0, "{model:?}");
            t.restore_link(t.rack_uplink(0));
            assert_eq!(t.bottleneck(&pl, &counts), before, "{model:?}");
        }
    }

    #[test]
    fn degrade_out_of_range_or_bad_factor_is_ignored_in_release() {
        let mut t = Topology::flat(2);
        let snapshot = (t.oversub(LinkId(0)), t.link_gbps(LinkId(0)));
        t.degrade_link(LinkId(99), 0.5);
        if !cfg!(debug_assertions) {
            t.degrade_link(LinkId(0), 0.0);
            t.degrade_link(LinkId(0), -1.0);
        }
        assert_eq!((t.oversub(LinkId(0)), t.link_gbps(LinkId(0))), snapshot);
    }
}
