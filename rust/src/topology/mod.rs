//! Hierarchical network fabric: the tree of shared links above the servers.
//!
//! The paper's contention model (Eq. 6) counts the active rings crossing a
//! *server uplink*, which implicitly assumes every uplink attaches to one
//! flat, non-blocking switch. Real multi-tenant clusters are rack-structured
//! and oversubscribed: servers attach to a top-of-rack (ToR) switch, and
//! ToR uplinks into the spine typically carry less capacity than the sum of
//! the server links below them (an *oversubscription factor* `o_ℓ ≥ 1`).
//!
//! This module models that fabric as a tree of links (identified by
//! [`LinkId`], tiered per [`LinkTier`]):
//!
//! * **tier 0** — one uplink per server (the links of Eq. 6),
//! * **tier 1** — one uplink per rack (ToR → spine), present only when the
//!   topology actually has a rack tier,
//! * the spine itself is the root and owns no uplink: a ring confined to
//!   the cluster never crosses it.
//!
//! A job's ring **crosses** link `ℓ` iff the servers in `ℓ`'s subtree hold
//! some but not all of the job's workers — `0 < Σ_{s ∈ sub(ℓ)} y_js < G_j`.
//! For a server uplink the subtree is the server itself and this is exactly
//! the Eq. 6 indicator `1{0 < y_js < G_j}`; for a rack uplink it is the
//! natural generalization one tier up. The per-link contention count is the
//! number of active rings crossing the link, and a job's effective
//! contention is taken at its [`Bottleneck`] — the crossed link maximizing
//! `count × oversub` (an `o`-times oversubscribed link serving `n` rings
//! behaves like a full-rate link serving `n·o`).
//!
//! Every inter-server link is modeled at the reference capacity `b^e`
//! scaled down by its factor, so a ToR uplink — even at `o = 1` —
//! *aggregates* all cross-rack rings of its rack onto one shared link.
//! The truly non-blocking fabric is therefore the flat topology (no ToR
//! tier); per-link absolute capacities are a tracked follow-on.
//!
//! **Eq. 6 is the exact 1-tier special case**: with [`Topology::flat`]
//! (no rack tier, all oversubscription 1.0) the only links are the server
//! uplinks, `count × 1.0` reduces to the Eq. 6 count, and the bottleneck
//! degree equals the paper's `p_j[t]` bit for bit — the flat-equivalence
//! property test in `tests/topology_equivalence.rs` enforces this.
//!
//! Follow-ons tracked in ROADMAP: heterogeneous per-link speeds (absolute
//! capacities instead of a scalar factor) and job-level bandwidth shares.

use crate::cluster::ServerId;
use crate::cluster::JobPlacement;
use crate::Result;
use anyhow::bail;

/// Index of a link in the topology (dense; see [`Topology`] for layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

impl std::fmt::Display for LinkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "l{}", self.0)
    }
}

/// Which tier of the fabric a link belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkTier {
    /// Server → ToR (the links of Eq. 6).
    ServerUplink,
    /// ToR → spine.
    RackUplink,
}

/// The bottleneck link of one job's ring in the current slot: Eq. 6's
/// `p_j[t]` generalized to a multi-tier fabric.
///
/// `p` is the number of active rings crossing the bottleneck link
/// (including the job itself) and `oversub` that link's oversubscription
/// factor; the *effective* contention degree driving Eq. 7 is
/// `p × oversub`. On a flat topology `oversub == 1.0` and `p` is exactly
/// the paper's `p_j[t]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bottleneck {
    /// Active-ring count on the bottleneck link (`p_j[t]` when flat).
    pub p: usize,
    /// Oversubscription factor of that link (1.0 when flat).
    pub oversub: f64,
    /// The bottleneck link itself; `None` for co-located jobs (no link
    /// crossed).
    pub link: Option<LinkId>,
}

impl Bottleneck {
    /// A co-located job: crosses no link, contention degree 0.
    pub const NONE: Bottleneck = Bottleneck { p: 0, oversub: 1.0, link: None };

    /// The flat-fabric bottleneck with Eq. 6 degree `p` — the adapter the
    /// scalar [`ContentionParams::tau`](crate::contention::ContentionParams::tau)
    /// wrappers use, guaranteeing the 1-tier model is the same code path.
    pub fn flat(p: usize) -> Bottleneck {
        Bottleneck { p, oversub: 1.0, link: None }
    }

    /// Effective contention degree `p × oversub` feeding Eq. 7's
    /// `k_j = ξ1 · p_eff`. Multiplying by 1.0 is exact in IEEE arithmetic,
    /// so the flat case reproduces `p as f64` bit for bit.
    pub fn effective(&self) -> f64 {
        self.p as f64 * self.oversub
    }

    /// Severity order used to pick the bottleneck among crossed links:
    /// larger effective degree wins; on ties the larger raw count (more
    /// informative in reports). Remaining ties keep the first-visited
    /// link, which is deterministic.
    pub fn dominates(&self, other: &Bottleneck) -> bool {
        self.effective() > other.effective()
            || (self.effective() == other.effective() && self.p > other.p)
    }
}

/// The shared-link tree above the servers.
///
/// Link layout: ids `[0, num_servers)` are the server uplinks (tier 0,
/// link `s` belongs to server `s`); ids `[num_servers, num_links)` are the
/// rack uplinks (tier 1, one per rack) when a rack tier exists.
///
/// Rack assignment must be nondecreasing in server id (rack 0 holds the
/// lowest-numbered servers, and so on) — this lets every crossing query
/// run in `O(span)` with no allocation by grouping a placement's sorted
/// server list into rack runs.
#[derive(Debug, Clone)]
pub struct Topology {
    num_servers: usize,
    /// Rack id per server (nondecreasing); empty ⇒ flat fabric (no rack
    /// tier, Eq. 6 exactly).
    rack_of: Vec<usize>,
    num_racks: usize,
    /// Oversubscription factor per link, indexed by [`LinkId`].
    oversub: Vec<f64>,
}

impl Topology {
    /// The paper's implicit 1-tier fabric: server uplinks only, no
    /// oversubscription. Eq. 6 exactly.
    pub fn flat(num_servers: usize) -> Self {
        assert!(num_servers > 0, "topology needs at least one server");
        Topology {
            num_servers,
            rack_of: Vec::new(),
            num_racks: 0,
            oversub: vec![1.0; num_servers],
        }
    }

    /// A homogeneous rack tier: consecutive groups of `servers_per_rack`
    /// servers share a ToR whose spine uplink is oversubscribed by
    /// `oversub` (1.0 = non-blocking). The last rack may be smaller.
    pub fn racks(num_servers: usize, servers_per_rack: usize, oversub: f64) -> Self {
        assert!(num_servers > 0, "topology needs at least one server");
        assert!(servers_per_rack >= 1, "racks must hold at least one server");
        assert!(oversub >= 1.0, "oversubscription factor must be >= 1");
        let num_racks = (num_servers + servers_per_rack - 1) / servers_per_rack;
        let rack_of = (0..num_servers).map(|s| s / servers_per_rack).collect();
        let mut ov = vec![1.0; num_servers];
        ov.extend(std::iter::repeat(oversub).take(num_racks));
        Topology { num_servers, rack_of, num_racks, oversub: ov }
    }

    /// Heterogeneous racks: `rack_sizes[r]` consecutive servers in rack
    /// `r`, each rack uplink with its own oversubscription factor.
    pub fn custom_racks(rack_sizes: &[usize], rack_oversub: &[f64]) -> Self {
        assert!(!rack_sizes.is_empty(), "topology needs at least one rack");
        assert_eq!(rack_sizes.len(), rack_oversub.len(), "one factor per rack");
        assert!(rack_sizes.iter().all(|&n| n >= 1), "racks must hold servers");
        assert!(rack_oversub.iter().all(|&o| o >= 1.0), "oversubscription >= 1");
        let num_servers: usize = rack_sizes.iter().sum();
        let mut rack_of = Vec::with_capacity(num_servers);
        for (r, &n) in rack_sizes.iter().enumerate() {
            rack_of.extend(std::iter::repeat(r).take(n));
        }
        let mut oversub = vec![1.0; num_servers];
        oversub.extend_from_slice(rack_oversub);
        Topology { num_servers, rack_of, num_racks: rack_sizes.len(), oversub }
    }

    /// Number of servers (tier-0 leaves).
    pub fn num_servers(&self) -> usize {
        self.num_servers
    }

    /// Number of racks; 0 for a flat fabric.
    pub fn num_racks(&self) -> usize {
        self.num_racks
    }

    /// Total number of links in the tree.
    pub fn num_links(&self) -> usize {
        self.oversub.len()
    }

    /// Whether a rack tier exists. A flat fabric recovers Eq. 6 exactly;
    /// topology-aware placement tie-breaks are no-ops on it.
    pub fn has_racks(&self) -> bool {
        self.num_racks > 0
    }

    /// Oversubscription factor of one link.
    pub fn oversub(&self, l: LinkId) -> f64 {
        self.oversub[l.0]
    }

    /// Which tier a link belongs to.
    pub fn tier(&self, l: LinkId) -> LinkTier {
        if l.0 < self.num_servers { LinkTier::ServerUplink } else { LinkTier::RackUplink }
    }

    /// The uplink of server `s` (tier 0 — the Eq. 6 link).
    pub fn server_uplink(&self, s: ServerId) -> LinkId {
        debug_assert!(s.0 < self.num_servers);
        LinkId(s.0)
    }

    /// The spine uplink of rack `r` (tier 1). Panics on a flat fabric.
    pub fn rack_uplink(&self, r: usize) -> LinkId {
        assert!(r < self.num_racks, "rack {r} out of range (flat fabric?)");
        LinkId(self.num_servers + r)
    }

    /// Rack index of a server. On a flat fabric every server is its own
    /// "rack" — the natural degenerate grouping schedulers can rely on.
    pub fn rack_index(&self, s: ServerId) -> usize {
        if self.rack_of.is_empty() { s.0 } else { self.rack_of[s.0] }
    }

    /// Servers of one rack, in id order.
    pub fn servers_in_rack(&self, rack: usize) -> impl Iterator<Item = ServerId> + '_ {
        (0..self.num_servers)
            .filter(move |&s| self.rack_index(ServerId(s)) == rack)
            .map(ServerId)
    }

    /// Visit every link crossed by `placement`'s ring — the generalized
    /// Eq. 6 indicator `0 < Σ_{s ∈ sub(ℓ)} y_js < G_j` — in `O(span)` with
    /// no allocation. Co-located jobs cross nothing.
    pub fn for_each_crossed(&self, placement: &JobPlacement, mut f: impl FnMut(LinkId)) {
        if !placement.is_spread() {
            return; // span 1: every subtree holds all or none of the workers
        }
        let total = placement.num_workers();
        if self.rack_of.is_empty() {
            // Flat: exactly the Eq. 6 server-uplink indicators.
            for s in placement.servers() {
                f(self.server_uplink(s));
            }
            return;
        }
        // Servers iterate in ascending id order and rack assignment is
        // nondecreasing, so used racks form contiguous runs: accumulate
        // each run's worker count and emit its uplink when the rack holds
        // a strict subset of the ring.
        let mut cur_rack = usize::MAX;
        let mut in_rack = 0usize;
        for s in placement.servers() {
            // a spread ring crosses every used server's uplink (y < G_j)
            f(self.server_uplink(s));
            let r = self.rack_of[s.0];
            if r != cur_rack {
                if cur_rack != usize::MAX && in_rack < total {
                    f(self.rack_uplink(cur_rack));
                }
                cur_rack = r;
                in_rack = 0;
            }
            in_rack += placement.gpus_on(s);
        }
        if cur_rack != usize::MAX && in_rack < total {
            f(self.rack_uplink(cur_rack));
        }
    }

    /// All links crossed by a placement (allocating convenience wrapper of
    /// [`for_each_crossed`](Self::for_each_crossed)).
    pub fn crossed_links(&self, placement: &JobPlacement) -> Vec<LinkId> {
        let mut out = Vec::new();
        self.for_each_crossed(placement, |l| out.push(l));
        out
    }

    /// The bottleneck of a placement given per-link active-ring counts
    /// (`counts[l.0]`): the crossed link with the largest effective degree
    /// `count × oversub`. [`Bottleneck::NONE`] for co-located jobs.
    pub fn bottleneck(&self, placement: &JobPlacement, counts: &[usize]) -> Bottleneck {
        debug_assert_eq!(counts.len(), self.num_links());
        let mut best = Bottleneck::NONE;
        self.for_each_crossed(placement, |l| {
            let cand =
                Bottleneck { p: counts[l.0], oversub: self.oversub(l), link: Some(l) };
            if best.link.is_none() || cand.dominates(&best) {
                best = cand;
            }
        });
        best
    }

    /// Human-readable link name for logs and reports.
    pub fn describe(&self, l: LinkId) -> String {
        match self.tier(l) {
            LinkTier::ServerUplink => format!("uplink(s{})", l.0),
            LinkTier::RackUplink => format!("tor(r{})", l.0 - self.num_servers),
        }
    }
}

/// CLI / config form of a topology, resolved against a cluster's server
/// count at build time: `flat` or `rack:<servers_per_rack>:<oversub>`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopologySpec {
    /// 1-tier fabric (the paper's model).
    Flat,
    /// Homogeneous racks with an oversubscribed ToR uplink.
    Rack { servers_per_rack: usize, oversub: f64 },
}

impl Default for TopologySpec {
    fn default() -> Self {
        TopologySpec::Flat
    }
}

impl TopologySpec {
    /// Materialise for a concrete cluster size.
    pub fn build(&self, num_servers: usize) -> Topology {
        match *self {
            TopologySpec::Flat => Topology::flat(num_servers),
            TopologySpec::Rack { servers_per_rack, oversub } => {
                Topology::racks(num_servers, servers_per_rack, oversub)
            }
        }
    }
}

impl std::fmt::Display for TopologySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologySpec::Flat => f.write_str("flat"),
            TopologySpec::Rack { servers_per_rack, oversub } => {
                write!(f, "rack:{servers_per_rack}:{oversub}")
            }
        }
    }
}

impl std::str::FromStr for TopologySpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        if s.eq_ignore_ascii_case("flat") {
            return Ok(TopologySpec::Flat);
        }
        let mut parts = s.split(':');
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some("rack"), Some(spr), oversub, None) => {
                let servers_per_rack: usize =
                    spr.parse().map_err(|_| anyhow::anyhow!("bad rack size '{spr}'"))?;
                if servers_per_rack == 0 {
                    bail!("rack size must be >= 1");
                }
                let oversub: f64 = match oversub {
                    None => 1.0,
                    Some(o) => o.parse().map_err(|_| anyhow::anyhow!("bad oversub '{o}'"))?,
                };
                if !(oversub >= 1.0) {
                    bail!("oversubscription factor must be >= 1, got {oversub}");
                }
                Ok(TopologySpec::Rack { servers_per_rack, oversub })
            }
            _ => bail!(
                "unknown topology '{s}' (expected flat | rack:<servers_per_rack>:<oversub>)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    fn place(c: &Cluster, pairs: &[(usize, usize)]) -> JobPlacement {
        JobPlacement::new(
            pairs.iter().map(|&(s, i)| c.global_gpu(ServerId(s), i)).collect(),
        )
    }

    #[test]
    fn flat_crossing_is_eq6() {
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        let t = Topology::flat(4);
        assert!(!t.has_racks());
        assert_eq!(t.num_links(), 4);
        // spread over servers 0 and 2: exactly those uplinks
        let pl = place(&c, &[(0, 0), (0, 1), (2, 0)]);
        assert_eq!(t.crossed_links(&pl), vec![LinkId(0), LinkId(2)]);
        // co-located: nothing
        assert!(t.crossed_links(&place(&c, &[(1, 0), (1, 1)])).is_empty());
    }

    #[test]
    fn rack_crossing_adds_tor_uplinks_only_across_racks() {
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        // racks {0,1} and {2,3}
        let t = Topology::racks(4, 2, 2.0);
        assert_eq!(t.num_racks(), 2);
        assert_eq!(t.num_links(), 6);
        // intra-rack spread (servers 0,1): server uplinks crossed, the
        // whole ring stays below the ToR — no rack uplink.
        let intra = place(&c, &[(0, 0), (1, 0)]);
        assert_eq!(t.crossed_links(&intra), vec![LinkId(0), LinkId(1)]);
        // cross-rack spread (servers 1,2): both server uplinks AND both
        // rack uplinks (rack runs flush after their last server).
        let cross = place(&c, &[(1, 0), (2, 0)]);
        let mut links = t.crossed_links(&cross);
        links.sort();
        assert_eq!(links, vec![LinkId(1), LinkId(2), t.rack_uplink(0), t.rack_uplink(1)]);
    }

    #[test]
    fn uneven_last_rack_and_custom_racks() {
        let t = Topology::racks(5, 2, 1.5);
        assert_eq!(t.num_racks(), 3);
        assert_eq!(t.rack_index(ServerId(4)), 2);
        assert_eq!(t.servers_in_rack(2).count(), 1);
        let h = Topology::custom_racks(&[3, 1], &[1.0, 4.0]);
        assert_eq!(h.num_servers(), 4);
        assert_eq!(h.oversub(h.rack_uplink(1)), 4.0);
        assert_eq!(h.rack_index(ServerId(2)), 0);
        assert_eq!(h.tier(LinkId(4)), LinkTier::RackUplink);
    }

    #[test]
    fn bottleneck_prefers_effective_degree() {
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        let t = Topology::racks(4, 2, 2.0);
        // one cross-rack job; counts: its server uplinks 1 each, rack
        // uplinks 1 each → effective 1·2 = 2 on the ToR beats 1·1.
        let pl = place(&c, &[(0, 0), (2, 0)]);
        let mut counts = vec![0usize; t.num_links()];
        t.for_each_crossed(&pl, |l| counts[l.0] += 1);
        let bn = t.bottleneck(&pl, &counts);
        assert_eq!(bn.link, Some(t.rack_uplink(0)));
        assert_eq!(bn.p, 1);
        assert_eq!(bn.oversub, 2.0);
        assert_eq!(bn.effective(), 2.0);
    }

    #[test]
    fn colocated_bottleneck_is_none() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let t = Topology::racks(2, 2, 8.0);
        let pl = place(&c, &[(0, 0), (0, 1)]);
        let counts = vec![0usize; t.num_links()];
        assert_eq!(t.bottleneck(&pl, &counts), Bottleneck::NONE);
    }

    #[test]
    fn spec_parsing_roundtrip() {
        assert_eq!("flat".parse::<TopologySpec>().unwrap(), TopologySpec::Flat);
        let r: TopologySpec = "rack:4:2.5".parse().unwrap();
        assert_eq!(r, TopologySpec::Rack { servers_per_rack: 4, oversub: 2.5 });
        assert_eq!(r.to_string().parse::<TopologySpec>().unwrap(), r);
        let d: TopologySpec = "rack:8".parse().unwrap();
        assert_eq!(d, TopologySpec::Rack { servers_per_rack: 8, oversub: 1.0 });
        assert!("rack:0:2".parse::<TopologySpec>().is_err());
        assert!("rack:4:0.5".parse::<TopologySpec>().is_err());
        assert!("mesh".parse::<TopologySpec>().is_err());
        assert!("rack:4:2:9".parse::<TopologySpec>().is_err());
    }

    #[test]
    fn spec_builds_matching_topology() {
        let t = TopologySpec::Rack { servers_per_rack: 3, oversub: 2.0 }.build(7);
        assert_eq!(t.num_racks(), 3);
        assert_eq!(t.num_servers(), 7);
        assert_eq!(TopologySpec::Flat.build(5).num_links(), 5);
    }
}
