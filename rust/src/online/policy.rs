//! Pluggable non-clairvoyant online policies.
//!
//! Non-clairvoyance is enforced **by the API**: a policy's only inputs
//! are the jobs that have already arrived ([`QueuedJob`], every
//! `spec.arrival ≤ now`) and the current cluster occupancy
//! ([`ClusterView`]). There is no handle to the trace, to future
//! arrivals, or to remaining execution times of running jobs — the
//! information set of GADGET-style online RAR schedulers.

use crate::cluster::{Cluster, ClusterState, GpuId, JobPlacement};
use crate::jobs::{JobId, JobSpec};
use crate::sched::{fa_ffp_select_warm, lbsgf_select};
use crate::Result;

/// One waiting job as a policy sees it.
#[derive(Debug, Clone, Copy)]
pub struct QueuedJob<'a> {
    pub spec: &'a JobSpec,
    /// Slots waited so far (`now − arrival`).
    pub waited: u64,
}

/// Read-only view of the cluster at the current instant.
#[derive(Debug, Clone, Copy)]
pub struct ClusterView<'a> {
    pub cluster: &'a Cluster,
    state: &'a ClusterState,
    /// Cumulative busy slots per GPU since t = 0 (the online analogue of
    /// the ledger's `U_s^g` — a *historical* load key, not future info).
    busy_history: &'a [f64],
    pub now: u64,
}

impl<'a> ClusterView<'a> {
    pub fn new(
        cluster: &'a Cluster,
        state: &'a ClusterState,
        busy_history: &'a [f64],
        now: u64,
    ) -> Self {
        debug_assert_eq!(busy_history.len(), cluster.num_gpus());
        ClusterView { cluster, state, busy_history, now }
    }

    /// Is this GPU free right now?
    pub fn is_free(&self, g: GpuId) -> bool {
        self.state.is_free(g)
    }

    /// Total free GPUs.
    pub fn total_free(&self) -> usize {
        self.state.total_free()
    }

    /// Cumulative busy slots of one GPU.
    pub fn busy_history(&self, g: GpuId) -> f64 {
        self.busy_history[g.global]
    }

    /// Currently-occupied GPU count per server (`capacity − free`),
    /// assembled in O(S) from the maintained free counts — the warm
    /// tally [`fa_ffp_select_warm`](crate::sched::fa_ffp_select_warm)
    /// takes, replacing the per-GPU occupancy recount per dispatch.
    pub fn occupied_per_server(&self) -> Vec<usize> {
        self.cluster
            .server_ids()
            .map(|s| self.cluster.capacity(s) - self.state.free_on(s))
            .collect()
    }
}

/// A non-clairvoyant scheduling policy.
///
/// On every event the loop calls [`dispatch`](Self::dispatch) repeatedly:
/// each call may start **one** queued job (returning its id and a
/// placement of exactly `G_j` currently-free GPUs), or decline with
/// `None` to wait for the next event. The loop validates the returned
/// placement (gang size, GPUs actually free, job actually queued).
pub trait OnlinePolicy {
    fn name(&self) -> &'static str;

    fn dispatch(
        &mut self,
        queue: &[QueuedJob<'_>],
        view: &ClusterView<'_>,
    ) -> Option<(JobId, JobPlacement)>;
}

impl<P: OnlinePolicy + ?Sized> OnlinePolicy for Box<P> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn dispatch(
        &mut self,
        queue: &[QueuedJob<'_>],
        view: &ClusterView<'_>,
    ) -> Option<(JobId, JobPlacement)> {
        (**self).dispatch(queue, view)
    }
}

/// First-fit over the currently free GPUs, in (server, index) order.
fn first_fit_free(view: &ClusterView<'_>, gpus_needed: usize) -> Option<Vec<GpuId>> {
    let mut picked = Vec::with_capacity(gpus_needed);
    for g in view.cluster.all_gpus() {
        if view.is_free(g) {
            picked.push(g);
            if picked.len() == gpus_needed {
                return Some(picked);
            }
        }
    }
    None
}

/// **FIFO** — strict arrival order with head-of-line blocking: only the
/// head of the queue may start; if its gang does not fit, nothing starts
/// until the next completion.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl OnlinePolicy for Fifo {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn dispatch(
        &mut self,
        queue: &[QueuedJob<'_>],
        view: &ClusterView<'_>,
    ) -> Option<(JobId, JobPlacement)> {
        let head = queue.first()?;
        let gpus = first_fit_free(view, head.spec.gpus)?;
        Some((head.spec.id, JobPlacement::new(gpus)))
    }
}

/// **Online first-fit** — walk the queue in arrival order and start the
/// first job whose gang fits the free GPUs (no head-of-line blocking,
/// no size preference).
#[derive(Debug, Clone, Copy, Default)]
pub struct OnlineFirstFit;

impl OnlinePolicy for OnlineFirstFit {
    fn name(&self) -> &'static str {
        "ON-FF"
    }

    fn dispatch(
        &mut self,
        queue: &[QueuedJob<'_>],
        view: &ClusterView<'_>,
    ) -> Option<(JobId, JobPlacement)> {
        for q in queue {
            if let Some(gpus) = first_fit_free(view, q.spec.gpus) {
                return Some((q.spec.id, JobPlacement::new(gpus)));
            }
        }
        None
    }
}

/// **FIFO + backfill** — arrival order, but when the head's gang does not
/// fit, *strictly smaller* jobs may jump ahead (EASY-style backfill
/// without reservations: a non-clairvoyant scheduler cannot predict when
/// the head will fit, so only jobs that cannot delay it by definition —
/// smaller ones that fit *now* — are promoted).
#[derive(Debug, Clone, Copy, Default)]
pub struct FifoBackfill;

impl OnlinePolicy for FifoBackfill {
    fn name(&self) -> &'static str {
        "BACKFILL"
    }

    fn dispatch(
        &mut self,
        queue: &[QueuedJob<'_>],
        view: &ClusterView<'_>,
    ) -> Option<(JobId, JobPlacement)> {
        let head = queue.first()?;
        if let Some(gpus) = first_fit_free(view, head.spec.gpus) {
            return Some((head.spec.id, JobPlacement::new(gpus)));
        }
        for q in queue.iter().skip(1) {
            if q.spec.gpus < head.spec.gpus {
                if let Some(gpus) = first_fit_free(view, q.spec.gpus) {
                    return Some((q.spec.id, JobPlacement::new(gpus)));
                }
            }
        }
        None
    }
}

/// **Online SJF-BCO** — the paper's Algorithm 1 greedy core made
/// non-clairvoyant: whenever capacity frees, start the *smallest queued
/// job* (by `G_j`, then requested iterations, then id), placed with the
/// same two subroutines as the batch planner — FA-FFP (Alg. 2) for small
/// jobs (`G_j ≤ κ`), LBSGF (Alg. 3) for large ones — over the free GPUs,
/// with cumulative historical busy time as the load key.
#[derive(Debug, Clone, Copy)]
pub struct OnlineSjfBco {
    /// Server-span threshold κ selecting FA-FFP vs LBSGF. The batch
    /// planner sweeps κ over job sizes; online we fix it (default 8, the
    /// paper mix's modal large-job size).
    pub kappa: usize,
    /// λ ≥ 1 over-provisioning of LBSGF's server pool.
    pub lambda: f64,
}

impl Default for OnlineSjfBco {
    fn default() -> Self {
        OnlineSjfBco { kappa: 8, lambda: 1.0 }
    }
}

impl OnlinePolicy for OnlineSjfBco {
    fn name(&self) -> &'static str {
        "ON-SJF-BCO"
    }

    fn dispatch(
        &mut self,
        queue: &[QueuedJob<'_>],
        view: &ClusterView<'_>,
    ) -> Option<(JobId, JobPlacement)> {
        let q = queue
            .iter()
            .min_by_key(|q| (q.spec.gpus, q.spec.iterations, q.spec.id))?;
        let free = |g: GpuId| view.is_free(g);
        let load = |g: GpuId| view.busy_history(g);
        // "warm" must be *current* occupancy, not cumulative history —
        // history marks every server warm once each GPU has run anything.
        // The per-server tally comes straight from the maintained free
        // counts (O(S)), not a per-GPU recount.
        let occ = view.occupied_per_server();
        let gpus = if q.spec.gpus <= self.kappa {
            fa_ffp_select_warm(view.cluster, q.spec.gpus, free, load, &occ)
        } else {
            // LBSGF restricts to the least-loaded servers by *capacity*;
            // under live occupancy those may not hold enough free GPUs,
            // so fall back to cluster-wide FA-FFP rather than stall.
            lbsgf_select(view.cluster, q.spec.gpus, self.lambda, free, load)
                .or_else(|| fa_ffp_select_warm(view.cluster, q.spec.gpus, free, load, &occ))
        }?;
        Some((q.spec.id, JobPlacement::new(gpus)))
    }
}

/// θ-style **admission control** for the overload regime, composing with
/// every [`OnlinePolicy`] (FIFO, ON-FF, BACKFILL, ON-SJF-BCO alike): the
/// event loop consults it once per *arrival*, before the job may enter
/// the pending queue.
///
/// Two independent guards, both inactive at their defaults so the
/// control-free loop is reproduced bit for bit (`theta = ∞`,
/// `queue_cap = usize::MAX` — enforced by the equivalence tests):
///
/// * **θ-threshold** — reject an arrival whose *projected* admission
///   would push any fabric link's effective degree `count × oversub`
///   (generalized Eq. 6, evaluated speculatively by
///   [`ContentionTracker::whatif_bottleneck`](super::ContentionTracker::whatif_bottleneck))
///   strictly past `theta`. The projection places the job with the same
///   FA-FFP selection the dispatch policies use — over the free GPUs when
///   a gang fits, else over all GPUs (the structural lower bound on the
///   contention it must cause). Under the
///   [`MaxMinFair`](crate::net::ContentionModel::MaxMinFair) model the
///   multiplier is the capacity ratio, so the effective degree is the
///   reciprocal of the job's projected **bandwidth share** — `θ` then
///   reads as a floor `c_ref / θ` on the share an admitted ring must
///   receive (see
///   [`ContentionTracker::whatif_share_gbps`](super::ContentionTracker::whatif_share_gbps)).
/// * **queue cap** — unconditionally reject once the pending queue holds
///   `queue_cap` jobs: under `λ > capacity` no threshold on contention
///   bounds the backlog, only a cap does.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionControl {
    /// Largest tolerated projected effective degree `count × oversub` at
    /// any link the arrival's ring would cross. `f64::INFINITY` disables
    /// the threshold.
    pub theta: f64,
    /// Hard cap on the pending-queue length. `usize::MAX` disables it.
    pub queue_cap: usize,
}

impl Default for AdmissionControl {
    fn default() -> Self {
        AdmissionControl { theta: f64::INFINITY, queue_cap: usize::MAX }
    }
}

impl AdmissionControl {
    /// Is any guard armed? When false the event loop skips the admission
    /// branch entirely (bit-for-bit equivalence with the control-free
    /// loop).
    pub fn is_active(&self) -> bool {
        self.theta.is_finite() || self.queue_cap != usize::MAX
    }

    /// The queue-cap guard: would an arrival overflow the pending queue?
    pub fn queue_full(&self, pending_len: usize) -> bool {
        pending_len >= self.queue_cap
    }

    /// The θ guard against a projected bottleneck: `None` projection means
    /// the job can never be placed (G_j exceeds the cluster) — under
    /// admission control that is a rejection, not an unbounded wait.
    pub fn theta_exceeded(&self, projected: Option<crate::topology::Bottleneck>) -> bool {
        if !self.theta.is_finite() {
            return false;
        }
        match projected {
            Some(bn) => bn.effective() > self.theta,
            None => true,
        }
    }
}

/// Completion-event **preemption/migration** policy, composing with every
/// [`OnlinePolicy`]: when completions free a server (or rack), up to
/// `max_moves` running jobs may be re-placed onto the freed capacity —
/// but only when the move *strictly* lowers the job's bottleneck
/// effective degree AND the projected completion improves net of the
/// checkpoint-restart penalty
/// ([`kernel::migration_pays`](crate::sim::kernel::migration_pays)).
/// Disabled by default: the control-free loop is reproduced bit for bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationControl {
    /// Master switch; off reproduces the no-migration loop exactly.
    pub enabled: bool,
    /// At most this many re-placements per completion event (K).
    pub max_moves: usize,
    /// Checkpoint-restart penalty in slots: the migrated job makes no
    /// progress for this long after the move. Fault recovery charges the
    /// same penalty when it re-places a killed gang (same checkpoint
    /// model), whether or not migration is enabled.
    pub restart_slots: u64,
}

impl Default for MigrationControl {
    fn default() -> Self {
        MigrationControl { enabled: false, max_moves: 2, restart_slots: 10 }
    }
}

/// The online policies available from the CLI / benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OnlinePolicyKind {
    SjfBco,
    Fifo,
    FirstFit,
    Backfill,
}

impl OnlinePolicyKind {
    pub const ALL: [OnlinePolicyKind; 4] = [
        OnlinePolicyKind::SjfBco,
        OnlinePolicyKind::Fifo,
        OnlinePolicyKind::FirstFit,
        OnlinePolicyKind::Backfill,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OnlinePolicyKind::SjfBco => "ON-SJF-BCO",
            OnlinePolicyKind::Fifo => "FIFO",
            OnlinePolicyKind::FirstFit => "ON-FF",
            OnlinePolicyKind::Backfill => "BACKFILL",
        }
    }

    /// Instantiate the policy with default tunables.
    pub fn build(self) -> Box<dyn OnlinePolicy> {
        match self {
            OnlinePolicyKind::SjfBco => Box::new(OnlineSjfBco::default()),
            OnlinePolicyKind::Fifo => Box::new(Fifo),
            OnlinePolicyKind::FirstFit => Box::new(OnlineFirstFit),
            OnlinePolicyKind::Backfill => Box::new(FifoBackfill),
        }
    }
}

impl std::fmt::Display for OnlinePolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for OnlinePolicyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sjf-bco" | "sjfbco" | "sjf_bco" | "on-sjf-bco" => Ok(OnlinePolicyKind::SjfBco),
            "fifo" => Ok(OnlinePolicyKind::Fifo),
            "ff" | "first-fit" | "firstfit" | "first_fit" | "on-ff" => {
                Ok(OnlinePolicyKind::FirstFit)
            }
            "backfill" | "fifo-backfill" => Ok(OnlinePolicyKind::Backfill),
            other => anyhow::bail!(
                "unknown online policy '{other}' (expected sjf-bco|fifo|ff|backfill)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerId;

    fn view_fixture(
        cluster: &Cluster,
        taken: &[(usize, usize)],
    ) -> (ClusterState, Vec<f64>) {
        let mut state = ClusterState::new(cluster);
        if !taken.is_empty() {
            let pl = JobPlacement::new(
                taken.iter().map(|&(s, i)| cluster.global_gpu(ServerId(s), i)).collect(),
            );
            state.allocate(JobId(99), &pl);
        }
        (state, vec![0.0; cluster.num_gpus()])
    }

    #[test]
    fn fifo_blocks_behind_big_head() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        // 6 of 8 GPUs taken: a 4-GPU head cannot fit, a 2-GPU job could
        let (state, hist) = view_fixture(&c, &[(0, 0), (0, 1), (0, 2), (0, 3), (1, 0), (1, 1)]);
        let view = ClusterView::new(&c, &state, &hist, 10);
        let big = JobSpec::synthetic(JobId(0), 4);
        let small = JobSpec::synthetic(JobId(1), 2);
        let queue =
            [QueuedJob { spec: &big, waited: 5 }, QueuedJob { spec: &small, waited: 1 }];
        assert!(Fifo.dispatch(&queue, &view).is_none(), "FIFO must block");
        let (job, pl) = FifoBackfill.dispatch(&queue, &view).expect("backfill promotes");
        assert_eq!(job, JobId(1));
        assert_eq!(pl.num_workers(), 2);
        let (job, _) = OnlineFirstFit.dispatch(&queue, &view).expect("first fit skips");
        assert_eq!(job, JobId(1));
    }

    #[test]
    fn backfill_never_promotes_equal_or_larger_jobs() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let (state, hist) = view_fixture(&c, &[(0, 0), (0, 1), (0, 2), (0, 3), (1, 0), (1, 1)]);
        let view = ClusterView::new(&c, &state, &hist, 10);
        let head = JobSpec::synthetic(JobId(0), 4);
        let peer = JobSpec::synthetic(JobId(1), 4); // same size: would fit? no (only 2 free)
        let equal_small = JobSpec::synthetic(JobId(2), 2);
        // make the "equal" job the same size as the head: must NOT jump
        let mut same = equal_small.clone();
        same.gpus = 4;
        let queue = [
            QueuedJob { spec: &head, waited: 0 },
            QueuedJob { spec: &peer, waited: 0 },
            QueuedJob { spec: &same, waited: 0 },
        ];
        assert!(FifoBackfill.dispatch(&queue, &view).is_none());
    }

    #[test]
    fn sjf_picks_smallest_and_packs() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let (state, hist) = view_fixture(&c, &[]);
        let view = ClusterView::new(&c, &state, &hist, 0);
        let big = JobSpec::synthetic(JobId(0), 4);
        let small = JobSpec::synthetic(JobId(1), 2);
        let queue =
            [QueuedJob { spec: &big, waited: 0 }, QueuedJob { spec: &small, waited: 0 }];
        let mut policy = OnlineSjfBco::default();
        let (job, pl) = policy.dispatch(&queue, &view).unwrap();
        assert_eq!(job, JobId(1), "smallest job first");
        assert_eq!(pl.span(), 1, "FA-FFP packs a 2-GPU ring onto one server");
    }

    #[test]
    fn sjf_large_job_uses_lbsgf_with_fallback() {
        let c = Cluster::uniform(4, 4, 1.0, 25.0);
        let (state, hist) = view_fixture(&c, &[(0, 0)]);
        let view = ClusterView::new(&c, &state, &hist, 0);
        let big = JobSpec::synthetic(JobId(0), 12);
        let queue = [QueuedJob { spec: &big, waited: 0 }];
        let mut policy = OnlineSjfBco { kappa: 4, lambda: 1.0 };
        let (_, pl) = policy.dispatch(&queue, &view).expect("12 free GPUs exist");
        assert_eq!(pl.num_workers(), 12);
    }

    #[test]
    fn nothing_fits_returns_none_for_all_policies() {
        let c = Cluster::uniform(1, 2, 1.0, 25.0);
        let (state, hist) = view_fixture(&c, &[(0, 0), (0, 1)]);
        let view = ClusterView::new(&c, &state, &hist, 0);
        let j = JobSpec::synthetic(JobId(0), 1);
        let queue = [QueuedJob { spec: &j, waited: 0 }];
        for kind in OnlinePolicyKind::ALL {
            assert!(kind.build().dispatch(&queue, &view).is_none(), "{kind}");
        }
    }

    #[test]
    fn admission_defaults_are_inert() {
        let a = AdmissionControl::default();
        assert!(!a.is_active());
        assert!(!a.queue_full(1_000_000));
        assert!(!a.theta_exceeded(Some(crate::topology::Bottleneck::flat(1_000))));
        assert!(!a.theta_exceeded(None), "theta off ignores unplaceable jobs too");
    }

    #[test]
    fn admission_guards_fire_independently() {
        use crate::topology::Bottleneck;
        let a = AdmissionControl { theta: 4.0, queue_cap: 3 };
        assert!(a.is_active());
        assert!(!a.queue_full(2));
        assert!(a.queue_full(3), "cap is inclusive: len == cap rejects");
        // θ compares the *effective* degree count × oversub
        assert!(!a.theta_exceeded(Some(Bottleneck::flat(4))), "4 × 1.0 = θ: admitted");
        assert!(a.theta_exceeded(Some(Bottleneck::flat(5))));
        assert!(
            a.theta_exceeded(Some(Bottleneck { p: 3, oversub: 2.0, link: None })),
            "3 × 2.0 > 4"
        );
        assert!(!a.theta_exceeded(Some(Bottleneck::NONE)), "co-located projection");
        assert!(a.theta_exceeded(None), "unplaceable jobs are rejected under θ");
        // queue cap alone also arms the control
        assert!(AdmissionControl { theta: f64::INFINITY, queue_cap: 8 }.is_active());
    }

    #[test]
    fn migration_default_is_off() {
        let m = MigrationControl::default();
        assert!(!m.enabled);
        assert!(m.max_moves >= 1);
    }

    #[test]
    fn kind_parsing_roundtrip() {
        for kind in OnlinePolicyKind::ALL {
            let back: OnlinePolicyKind = match kind {
                OnlinePolicyKind::SjfBco => "sjf-bco".parse().unwrap(),
                OnlinePolicyKind::Fifo => "fifo".parse().unwrap(),
                OnlinePolicyKind::FirstFit => "ff".parse().unwrap(),
                OnlinePolicyKind::Backfill => "backfill".parse().unwrap(),
            };
            assert_eq!(back, kind);
        }
        assert!("nope".parse::<OnlinePolicyKind>().is_err());
    }
}
