//! Non-clairvoyant **online** scheduling of a live arrival stream.
//!
//! The paper's SJF-BCO solves the batch setting — every job waits at
//! t = 0 and the planner sees them all (§4.1). Production clusters serve
//! a continuous stream instead, and an online scheduler must decide at
//! each *event* (job arrival, job completion) using only what has already
//! happened. This subsystem provides that event loop:
//!
//! * [`queue::PendingQueue`] — the live queue of arrived-but-waiting jobs;
//! * [`policy::OnlinePolicy`] — pluggable decision rules
//!   ([`policy::OnlineSjfBco`], [`policy::Fifo`],
//!   [`policy::OnlineFirstFit`], [`policy::FifoBackfill`]) whose API
//!   admits no future knowledge;
//! * [`tracker::ContentionTracker`] — generalized Eq. 6 per-link counts
//!   (server uplinks + ToR uplinks of the cluster's
//!   [`Topology`](crate::topology::Topology)) maintained incrementally in
//!   `O(path)` per admit/complete instead of a full `O(jobs × span)`
//!   snapshot rebuild per event;
//! * [`OnlineScheduler`] — the loop itself, advancing time with the same
//!   [`sim::kernel`](crate::sim::kernel) period arithmetic as the offline
//!   replay engine, so online and clairvoyant runs are directly
//!   comparable slot for slot.
//!
//! The clairvoyant-vs-online comparison lives in
//! [`experiments::online`](crate::experiments::online); the `online` CLI
//! subcommand drives Poisson traces through both.

pub mod event;
pub mod policy;
pub mod queue;
pub mod tracker;

pub use event::{EventKind, EventLog, OnlineEvent};
pub use policy::{
    ClusterView, Fifo, FifoBackfill, OnlineFirstFit, OnlinePolicy, OnlinePolicyKind,
    OnlineSjfBco, QueuedJob,
};
pub use queue::PendingQueue;
pub use tracker::ContentionTracker;

use crate::cluster::{Cluster, ClusterState, JobPlacement};
use crate::contention::ContentionParams;
use crate::jobs::{JobId, JobSpec};
use crate::sim::kernel::{self, RatePoint};
use crate::sim::{JobRecord, SimOutcome};
use std::collections::HashMap;

/// Loop options (mirrors [`SimOptions`](crate::sim::SimOptions)).
#[derive(Debug, Clone, Copy)]
pub struct OnlineOptions {
    /// Safety horizon: stop after this many slots even if jobs remain.
    pub max_slots: u64,
    /// Fall back to fractional progress `1/τ` when `φ` floors to zero.
    pub fractional_progress: bool,
}

impl Default for OnlineOptions {
    fn default() -> Self {
        OnlineOptions { max_slots: 1_000_000, fractional_progress: false }
    }
}

/// Result of one online run: the standard simulation outcome plus the
/// realized event sequence.
#[derive(Debug, Clone)]
pub struct OnlineOutcome {
    pub policy: String,
    pub outcome: SimOutcome,
    pub events: EventLog,
}

struct Running<'a> {
    job: JobId,
    spec: &'a JobSpec,
    placement: JobPlacement,
    start: u64,
    progress: f64,
    tau_sum: f64,
    tau_slots: u64,
    max_p: usize,
}

/// Event-driven non-clairvoyant scheduler over one cluster + job stream.
///
/// The job slice supplies the arrival stream (its `arrival` fields); jobs
/// are revealed to the policy only once their arrival slot is reached.
pub struct OnlineScheduler<'a> {
    cluster: &'a Cluster,
    jobs: &'a [JobSpec],
    params: &'a ContentionParams,
    options: OnlineOptions,
}

impl<'a> OnlineScheduler<'a> {
    pub fn new(cluster: &'a Cluster, jobs: &'a [JobSpec], params: &'a ContentionParams) -> Self {
        OnlineScheduler { cluster, jobs, params, options: OnlineOptions::default() }
    }

    pub fn with_options(mut self, options: OnlineOptions) -> Self {
        self.options = options;
        self
    }

    /// Run the stream to completion (or the safety horizon) under one
    /// policy and report realized makespan / JCTs / waits under live
    /// contention.
    pub fn run(&self, policy: &mut dyn OnlinePolicy) -> OnlineOutcome {
        // Arrival stream in (arrival, id) order — the only place the full
        // trace exists; the policy never sees past `next_arrival`.
        let mut order: Vec<&JobSpec> = self.jobs.iter().collect();
        order.sort_by_key(|j| (j.arrival, j.id));
        let spec_of: HashMap<JobId, &JobSpec> = self.jobs.iter().map(|j| (j.id, j)).collect();

        let mut state = ClusterState::new(self.cluster);
        let mut tracker = ContentionTracker::new(self.cluster);
        let mut pending = PendingQueue::new();
        let mut events = EventLog::default();
        let mut busy_history = vec![0.0f64; self.cluster.num_gpus()];
        let mut running: Vec<Running<'a>> = Vec::new();
        let mut records: Vec<JobRecord> = Vec::with_capacity(self.jobs.len());
        let mut busy_gpu_slots: u64 = 0;
        let mut next_arrival = 0usize;
        let mut t: u64 = 0;

        loop {
            // 1) Reveal arrivals due by now.
            while next_arrival < order.len() && order[next_arrival].arrival <= t {
                let spec = order[next_arrival];
                pending.push(spec.id, spec.arrival);
                events.push(spec.arrival, spec.id, EventKind::Arrival);
                next_arrival += 1;
            }

            // Horizon guard sits *before* dispatch so no job can start at
            // t == max_slots only to be truncated with a zero-length record.
            if t >= self.options.max_slots {
                break;
            }

            // 2) Let the policy start jobs until it declines. Each accepted
            //    dispatch is validated: the job must be queued and the
            //    placement must be a free gang of exactly G_j GPUs
            //    (ClusterState::allocate asserts freeness).
            while !pending.is_empty() {
                let queued: Vec<QueuedJob<'_>> = pending
                    .iter()
                    .map(|(job, arrival)| QueuedJob { spec: spec_of[&job], waited: t - arrival })
                    .collect();
                let view = ClusterView::new(self.cluster, &state, &busy_history, t);
                let Some((job, placement)) = policy.dispatch(&queued, &view) else { break };
                assert!(pending.remove(job), "policy dispatched {job} which is not queued");
                let spec = spec_of[&job];
                assert_eq!(
                    placement.num_workers(),
                    spec.gpus,
                    "gang scheduling: placement must have exactly G_j GPUs"
                );
                state.allocate(job, &placement);
                tracker.admit(job, &placement);
                events.push(t, job, EventKind::Start);
                running.push(Running {
                    job,
                    spec,
                    placement,
                    start: t,
                    progress: 0.0,
                    tau_sum: 0.0,
                    tau_slots: 0,
                    max_p: 0,
                });
            }

            if running.is_empty() {
                if pending.is_empty() && next_arrival >= order.len() {
                    break; // all done
                }
                match order.get(next_arrival) {
                    // Idle (or stuck) until the next arrival reveals work.
                    Some(spec) if spec.arrival < self.options.max_slots => {
                        t = spec.arrival;
                        continue;
                    }
                    // Queue non-empty but the policy can never place it
                    // (e.g. a job larger than the cluster): truncate.
                    _ => break,
                }
            }

            // 3) Constant-rate period: the bottleneck link from the
            //    incremental tracker, τ/φ from the shared simulation
            //    kernel.
            let rates: Vec<RatePoint> = running
                .iter()
                .map(|r| {
                    kernel::rate_point(
                        self.params,
                        self.cluster,
                        r.spec,
                        &r.placement,
                        tracker.bottleneck(r.job),
                        self.options.fractional_progress,
                    )
                })
                .collect();

            // 4) Jump to the next event: completion, arrival or horizon.
            let mut dt = u64::MAX;
            for (r, rate) in running.iter().zip(&rates) {
                let remaining = r.spec.iterations as f64 - r.progress;
                dt = dt.min(kernel::slots_until_done(remaining, rate.inc));
            }
            if let Some(spec) = order.get(next_arrival) {
                debug_assert!(spec.arrival > t, "due arrivals were revealed in step 1");
                dt = dt.min(spec.arrival - t);
            }
            let dt = dt.min(self.options.max_slots - t).max(1);

            // 5) Progress every running job by dt slots.
            for (r, rate) in running.iter_mut().zip(&rates) {
                r.progress += rate.inc * dt as f64;
                r.tau_sum += rate.tau * dt as f64;
                r.tau_slots += dt;
                r.max_p = r.max_p.max(rate.p);
                busy_gpu_slots += r.placement.num_workers() as u64 * dt;
                for g in r.placement.gpus() {
                    busy_history[g.global] += dt as f64;
                }
            }
            t += dt;

            // 6) Completions at the end of the period.
            let mut i = 0;
            while i < running.len() {
                if running[i].progress >= running[i].spec.iterations as f64 {
                    let r = running.swap_remove(i);
                    state.release(r.job, &r.placement);
                    tracker.complete(r.job);
                    events.push(t, r.job, EventKind::Completion);
                    records.push(JobRecord {
                        job: r.job,
                        arrival: r.spec.arrival,
                        start: r.start,
                        finish: t,
                        span: r.placement.span(),
                        workers: r.placement.num_workers(),
                        max_p: r.max_p,
                        mean_tau: r.tau_sum / r.tau_slots.max(1) as f64,
                        iterations_done: r.spec.iterations,
                    });
                } else {
                    i += 1;
                }
            }
        }

        let truncated =
            !pending.is_empty() || !running.is_empty() || next_arrival < order.len();
        for r in running {
            records.push(JobRecord {
                job: r.job,
                arrival: r.spec.arrival,
                start: r.start,
                finish: t,
                span: r.placement.span(),
                workers: r.placement.num_workers(),
                max_p: r.max_p,
                mean_tau: r.tau_sum / r.tau_slots.max(1) as f64,
                iterations_done: r.progress as u64,
            });
        }
        records.sort_by_key(|r| r.job);

        let makespan = records.iter().map(|r| r.finish).max().unwrap_or(0);
        let avg_jct = if records.is_empty() {
            0.0
        } else {
            records.iter().map(|r| r.jct() as f64).sum::<f64>() / records.len() as f64
        };
        let gpu_utilization = if makespan == 0 {
            0.0
        } else {
            busy_gpu_slots as f64 / (makespan * self.cluster.num_gpus() as u64) as f64
        };
        OnlineOutcome {
            policy: policy.name().to_string(),
            outcome: SimOutcome {
                makespan,
                avg_jct,
                gpu_utilization,
                records,
                slots_simulated: t,
                truncated,
            },
            events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceGenerator;

    fn setup() -> (Cluster, ContentionParams) {
        (Cluster::uniform(4, 8, 1.0, 25.0), ContentionParams::paper())
    }

    #[test]
    fn every_policy_completes_a_poisson_trace() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(7, 10.0);
        for kind in OnlinePolicyKind::ALL {
            let mut policy = kind.build();
            let out = OnlineScheduler::new(&c, &jobs, &p).run(policy.as_mut());
            assert_eq!(out.policy, kind.name());
            assert!(!out.outcome.truncated, "{kind} truncated");
            assert_eq!(out.outcome.records.len(), jobs.len(), "{kind}");
            for r in &out.outcome.records {
                assert!(r.start >= r.arrival, "{kind}: {} started before arrival", r.job);
                assert!(r.finish > r.start);
                assert_eq!(
                    r.iterations_done,
                    jobs.iter().find(|j| j.id == r.job).unwrap().iterations
                );
            }
            assert!(out.events.is_causally_ordered(), "{kind}");
            assert_eq!(out.events.count(EventKind::Start), jobs.len());
            assert_eq!(out.events.count(EventKind::Completion), jobs.len());
        }
    }

    #[test]
    fn batch_arrivals_reduce_to_greedy_schedule() {
        // gap 0: all jobs arrive at t = 0; the loop must still run them
        // all, in waves bounded by cluster capacity.
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(3, 0.0);
        let mut policy = OnlineSjfBco::default();
        let out = OnlineScheduler::new(&c, &jobs, &p).run(&mut policy);
        assert!(!out.outcome.truncated);
        assert_eq!(out.outcome.records.len(), jobs.len());
        assert!(out.outcome.makespan > 0);
    }

    #[test]
    fn oversized_job_truncates_instead_of_hanging() {
        let (c, p) = setup();
        let mut jobs = vec![JobSpec::synthetic(JobId(0), 1)];
        jobs.push(JobSpec::synthetic(JobId(1), c.num_gpus() + 1)); // never placeable
        let out = OnlineScheduler::new(&c, &jobs, &p).run(&mut Fifo);
        assert!(out.outcome.truncated);
    }

    #[test]
    fn waits_are_zero_on_an_empty_cluster_with_sparse_arrivals() {
        let (c, p) = setup();
        // one tiny job every 10_000 slots: each runs alone, zero wait
        let mut jobs = TraceGenerator::tiny().generate(1);
        for (i, j) in jobs.iter_mut().enumerate() {
            j.arrival = (i as u64) * 10_000;
        }
        let out = OnlineScheduler::new(&c, &jobs, &p)
            .with_options(OnlineOptions { max_slots: 10_000_000, fractional_progress: false })
            .run(&mut Fifo);
        assert!(!out.outcome.truncated);
        for r in &out.outcome.records {
            assert_eq!(r.start, r.arrival, "{} queued on an empty cluster", r.job);
        }
    }

    #[test]
    fn sjf_beats_or_matches_fifo_on_avg_jct_for_batch_mix() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(11, 2.0);
        let sjf = OnlineScheduler::new(&c, &jobs, &p).run(&mut OnlineSjfBco::default());
        let fifo = OnlineScheduler::new(&c, &jobs, &p).run(&mut Fifo);
        assert!(!sjf.outcome.truncated && !fifo.outcome.truncated);
        // SJF is the mean-JCT heuristic; allow a small tolerance since the
        // tiny trace is nearly contention-free.
        assert!(
            sjf.outcome.avg_jct <= fifo.outcome.avg_jct * 1.25 + 1.0,
            "SJF {} vs FIFO {}",
            sjf.outcome.avg_jct,
            fifo.outcome.avg_jct
        );
    }
}
