//! Non-clairvoyant **online** scheduling of a live arrival stream.
//!
//! The paper's SJF-BCO solves the batch setting — every job waits at
//! t = 0 and the planner sees them all (§4.1). Production clusters serve
//! a continuous stream instead, and an online scheduler must decide at
//! each *event* (job arrival, job completion) using only what has already
//! happened. This subsystem provides that event loop:
//!
//! * [`queue::PendingQueue`] — the live queue of arrived-but-waiting jobs;
//! * [`policy::OnlinePolicy`] — pluggable decision rules
//!   ([`policy::OnlineSjfBco`], [`policy::Fifo`],
//!   [`policy::OnlineFirstFit`], [`policy::FifoBackfill`]) whose API
//!   admits no future knowledge;
//! * [`tracker::ContentionTracker`] — generalized Eq. 6 per-link counts
//!   (server uplinks + ToR uplinks of the cluster's
//!   [`Topology`](crate::topology::Topology)) maintained incrementally in
//!   `O(path)` per admit/complete instead of a full `O(jobs × span)`
//!   snapshot rebuild per event;
//! * [`OnlineScheduler`] — the loop itself, advancing time with the same
//!   [`sim::kernel`](crate::sim::kernel) period arithmetic as the offline
//!   replay engine, so online and clairvoyant runs are directly
//!   comparable slot for slot;
//! * **overload controls** — [`policy::AdmissionControl`] (θ-threshold on
//!   the *projected* bottleneck degree `count × oversub` of each arrival,
//!   evaluated speculatively by
//!   [`tracker::ContentionTracker::whatif_bottleneck`], plus an
//!   unconditional pending-queue cap) and [`policy::MigrationControl`]
//!   (completion-event preemption: up to K running jobs re-placed onto
//!   freed capacity when their bottleneck strictly improves net of a
//!   checkpoint-restart penalty). Both are inert by default, reproducing
//!   the control-free loop bit for bit; arrivals turned away log
//!   [`EventKind::Rejected`], accepted moves log [`EventKind::Migrated`];
//! * **fault injection** — a [`FaultTrace`](crate::faults::FaultTrace)
//!   armed via [`OnlineScheduler::with_faults`] merges timestamped server
//!   crashes, permanent GPU failures and link capacity changes into the
//!   loop as first-class events (applied *before* arrivals at equal
//!   slots). A crash kills its resident gangs — they keep their
//!   checkpointed progress and re-enter through a FIFO **recovery queue**
//!   (re-placed via the migration candidate machinery when
//!   [`MigrationControl::enabled`], else waiting for their original gang
//!   to heal); link changes flow through the
//!   [`Topology::multiplier`](crate::topology::Topology::multiplier)
//!   choke point with link-keyed
//!   [`DirtySet`](crate::contention::DirtySet) invalidation. The empty
//!   trace skips every fault branch — bit-identical to a fault-free run
//!   (`tests/fault_equivalence.rs`).
//!
//! ## Streaming runs and the O(active) memory invariant
//!
//! One generic core drives every mode. It consumes the arrival stream as
//! an **iterator** (any `Iterator<Item: Borrow<JobSpec>>` — a sorted
//! slice, or the lazy [`OpenArrivals`](crate::trace::OpenArrivals)
//! stream, so the full trace need never exist in memory) and pushes every
//! outcome through a [`RunSink`] the moment it is produced:
//!
//! * [`CollectSink`] stores everything — [`OnlineScheduler::run`] wraps
//!   it to assemble the classic [`OnlineOutcome`] exactly as before;
//! * [`StreamSink`] folds each [`JobRecord`] into
//!   [`StreamSketch`](crate::metrics::StreamSketch) percentile sketches
//!   and per-kind event counters, then **drops** it —
//!   [`OnlineScheduler::run_streaming`] wraps it to produce a
//!   [`StreamOutcome`] whose memory never grows with the trace length;
//! * custom sinks interpose on the exact production loop via
//!   [`OnlineScheduler::run_with_sink`].
//!
//! The core's own state is `O(peak active + pending)` regardless of how
//! many jobs flow through: running jobs are keyed by **recycled dense
//! slot ids** (a free-list) inside the tracker and dirty set, so those
//! dense-by-id tables are bounded by the concurrency high-water mark
//! ([`RunStats::peak_live`]) rather than by the largest trace id; pending
//! specs are held only between arrival and dispatch; and the rolling
//! aggregates ([`RunStats`]) use integer sums (`u128` — no
//! float-accumulation order to worry about), so collect-all and streaming
//! runs agree on every aggregate bit for bit.
//!
//! The **equivalence ladder** (each rung property-tested in
//! `tests/stream_equivalence.rs`):
//!
//! 1. `run` == `run_with_sink(CollectSink)` — by construction (`run` *is*
//!    that call plus assembly) and re-checked against events, records,
//!    ledgers and aggregates;
//! 2. `run_streaming` aggregates == `run` aggregates — exactly (integer
//!    sums, shared core); sketch percentiles track the exact ones within
//!    the documented 1/32 relative bound of [`StreamSketch`];
//! 3. slot-id recycling is unobservable — events, records and decisions
//!    carry trace ids only.
//!
//! The clairvoyant-vs-online comparison lives in
//! [`experiments::online`](crate::experiments::online); the `online` CLI
//! subcommand drives Poisson traces through both (`--stream` switches to
//! the sketch-backed sink).

pub mod event;
pub mod policy;
pub mod queue;
pub mod tracker;

pub use event::{EventKind, EventLog, OnlineEvent};
pub use policy::{
    AdmissionControl, ClusterView, Fifo, FifoBackfill, MigrationControl, OnlineFirstFit,
    OnlinePolicy, OnlinePolicyKind, OnlineSjfBco, QueuedJob,
};
pub use queue::PendingQueue;
pub use tracker::ContentionTracker;

use crate::cluster::{Cluster, ClusterState, GpuId, JobPlacement, ServerId};
use crate::contention::ContentionParams;
use crate::faults::{FaultAction, FaultEvent, FaultTrace};
use crate::jobs::{JobId, JobSpec};
use crate::metrics::StreamSketch;
use crate::sched::fa_ffp_select_warm;
use crate::sim::kernel::{self, RatePoint};
use crate::sim::{JobRecord, SimOutcome};
use crate::topology::{Bottleneck, LinkId};
use event::LINK_EVENT_JOB;
use std::borrow::Borrow;
use std::collections::HashMap;

/// Loop options (mirrors [`SimOptions`](crate::sim::SimOptions)).
///
/// The overload controls default to inert ([`AdmissionControl::default`]
/// is `θ = ∞` + unbounded queue, [`MigrationControl::default`] is off),
/// and the loop skips their branches entirely when inert — so the default
/// options reproduce the control-free scheduler bit for bit (enforced by
/// `tests/online_scheduler.rs`).
#[derive(Debug, Clone, Copy)]
pub struct OnlineOptions {
    /// Safety horizon: stop after this many slots even if jobs remain.
    pub max_slots: u64,
    /// Fall back to fractional progress `1/τ` when `φ` floors to zero.
    pub fractional_progress: bool,
    /// θ-admission + queue cap consulted once per arrival.
    pub admission: AdmissionControl,
    /// Completion-event preemption/migration of running jobs.
    pub migration: MigrationControl,
    /// Dirty-set rate caching (§Perf, on by default): re-rate only the
    /// running jobs whose bottleneck-link counts changed since the last
    /// event, per the link-keyed invalidation rule of
    /// [`DirtySet`](crate::contention::DirtySet). `false` restores the
    /// recompute-every-job reference path — bit-identical by property
    /// test (`tests/sim_engine_equivalence.rs`), kept for cross-checking.
    pub rate_cache: bool,
    /// Sliding-window steady-state metrics: `Some(w)` slices the run into
    /// windows of `w` slots and records per-window GPU busy-time and
    /// time-weighted queue length in [`OnlineOutcome::windows`] (the
    /// open-system view — utilization and backlog *over time*, which the
    /// run-level aggregates average away). `None` (default) records
    /// nothing; the accounting is passive either way — the schedule is
    /// bit-identical with the flag on or off. The series is O(run length
    /// / w), not O(jobs), so streaming runs keep it too.
    pub window: Option<u64>,
}

impl Default for OnlineOptions {
    fn default() -> Self {
        OnlineOptions {
            max_slots: 1_000_000,
            fractional_progress: false,
            admission: AdmissionControl::default(),
            migration: MigrationControl::default(),
            rate_cache: true,
            window: None,
        }
    }
}

/// One window of the sliding-window steady-state series (see
/// [`OnlineOptions::window`]): the loop distributes every constant-rate
/// period exactly across the windows it overlaps, so sums over windows
/// equal the run totals.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct WindowSample {
    /// First slot of the window (`index × w`).
    pub start: u64,
    /// GPU busy slots accrued inside the window (running gangs hold their
    /// GPUs through checkpoint-restart freezes, matching the run-level
    /// utilization accounting).
    pub busy_gpu_slots: f64,
    /// `∫ queue_len dt` over the window — divide by the window length for
    /// the time-weighted mean backlog.
    pub queue_area: f64,
    /// Largest pending-queue length observed during the window.
    pub max_queue: usize,
    /// Schedulable (healthy) GPU-slots the window actually offered —
    /// `∫ healthy_gpus dt` over the accounted spans. On a fault-free run
    /// this is exactly `num_gpus × covered span` (integer-valued float
    /// sums, no rounding); under faults it shrinks with outages, so
    /// [`utilization`](Self::utilization) normalizes by *surviving*
    /// capacity instead of reporting a full-cluster outage as idle
    /// headroom.
    pub capacity_gpu_slots: f64,
}

impl WindowSample {
    /// Mean GPU utilization over the window: busy GPU-slots over the
    /// *surviving* capacity the window offered ([`capacity_gpu_slots`]
    /// (Self::capacity_gpu_slots)); the nominal `num_gpus × window`
    /// denominator is the fallback for hand-built samples that never
    /// accrued capacity.
    pub fn utilization(&self, num_gpus: usize, window: u64) -> f64 {
        if self.capacity_gpu_slots > 0.0 {
            self.busy_gpu_slots / self.capacity_gpu_slots
        } else if num_gpus == 0 || window == 0 {
            0.0
        } else {
            self.busy_gpu_slots / (num_gpus as u64 * window) as f64
        }
    }

    /// Time-weighted mean queue length over the window.
    pub fn mean_queue(&self, window: u64) -> f64 {
        if window == 0 { 0.0 } else { self.queue_area / window as f64 }
    }
}

/// "Coolest capacity" of a free-GPU pool: the sum over the `gpus`
/// least-busy entries — the GPUs a selection would actually take — NOT
/// over every free GPU (which would bias toward servers with fewer free
/// GPUs regardless of how hot they run). Shared by every
/// migration-candidate stage.
fn coolest_sum(busies: &mut Vec<f64>, gpus: usize) -> f64 {
    busies.sort_by(|a, b| a.total_cmp(b));
    busies.iter().take(gpus).sum()
}

/// Open-bucket window accumulator: one [`WindowSample`] of state, with
/// every closed bucket (idle gaps included, as all-zero windows) emitted
/// through [`RunSink::window`] the moment its last slot is accounted.
/// This keeps the window series O(1) in the core — the sink decides
/// whether to collect it — while preserving the exact bucket tiling and
/// per-bucket accumulation order of the old materialized series, so sums
/// over windows still equal the run totals to the last ulp.
#[derive(Debug, Default)]
struct WindowAcc {
    /// Bucket index of `open` (`open.start == open_idx × w`).
    open_idx: u64,
    open: WindowSample,
    /// False until the first accounted span — before that there is no
    /// open bucket to close or flush.
    started: bool,
}

impl WindowAcc {
    /// Distribute one constant-rate period `[t, t+dt)` across the window
    /// buckets it overlaps, closing (emitting) every bucket the period
    /// steps past.
    fn account<K: RunSink>(
        &mut self,
        sink: &mut K,
        w: u64,
        t: u64,
        dt: u64,
        busy_per_slot: f64,
        capacity_per_slot: f64,
        queue_len: usize,
    ) {
        debug_assert!(w > 0);
        let mut cur = t;
        let end = t + dt;
        while cur < end {
            self.roll_to(sink, cur / w, w);
            let bucket_end = (cur / w + 1) * w;
            let overlap = bucket_end.min(end) - cur;
            let s = &mut self.open;
            s.busy_gpu_slots += busy_per_slot * overlap as f64;
            s.queue_area += queue_len as f64 * overlap as f64;
            s.max_queue = s.max_queue.max(queue_len);
            s.capacity_gpu_slots += capacity_per_slot * overlap as f64;
            cur = bucket_end.min(end);
        }
    }

    /// Close every bucket strictly before `idx` (untouched ones emit as
    /// all-zero windows) and make `idx` the open bucket.
    fn roll_to<K: RunSink>(&mut self, sink: &mut K, idx: u64, w: u64) {
        if !self.started {
            // leading idle gap: the old series zero-filled from bucket 0
            for i in 0..idx {
                sink.window(WindowSample { start: i * w, ..WindowSample::default() });
            }
            self.open_idx = idx;
            self.open = WindowSample { start: idx * w, ..WindowSample::default() };
            self.started = true;
            return;
        }
        while self.open_idx < idx {
            let next = WindowSample {
                start: (self.open_idx + 1) * w,
                ..WindowSample::default()
            };
            sink.window(std::mem::replace(&mut self.open, next));
            self.open_idx += 1;
        }
    }

    /// Flush the still-open bucket at run end.
    fn finish<K: RunSink>(self, sink: &mut K) {
        if self.started {
            sink.window(self.open);
        }
    }
}

/// One accepted preemption/re-placement, for metrics and the
/// strict-improvement property tests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationRecord {
    pub job: JobId,
    /// Slot at which the move was committed.
    pub at: u64,
    /// Effective bottleneck degree `count × oversub` before the move.
    pub from_effective: f64,
    /// Effective bottleneck degree after the move (strictly smaller).
    pub to_effective: f64,
    /// Checkpoint-restart penalty charged (slots of frozen progress).
    pub restart_slots: u64,
}

/// Push-style receiver of everything an online run produces, called the
/// moment each item exists. The core never stores what it hands over, so
/// the sink alone decides the memory profile of a run: [`CollectSink`]
/// keeps it all (the classic [`OnlineOutcome`] path), [`StreamSink`]
/// folds and drops. Jobs are identified by **trace** ids — internal
/// slot-id recycling never leaks here.
///
/// Default methods discard, so purpose-built probes (e.g. the allocation
/// probe in `tests/alloc_steady_state.rs`) override only what they need.
pub trait RunSink {
    /// A lifecycle event, in realized order (the same stream an
    /// [`EventLog`] would hold).
    fn event(&mut self, at: u64, job: JobId, kind: EventKind) {
        let _ = (at, job, kind);
    }

    /// A finished job's record, in completion order; residual running
    /// jobs flush at the end of a truncated run.
    fn record(&mut self, record: JobRecord) {
        let _ = record;
    }

    /// An arrival turned away by admission control (its
    /// [`EventKind::Rejected`] event was just emitted via
    /// [`event`](Self::event)).
    fn reject(&mut self, at: u64, job: JobId) {
        let _ = (at, job);
    }

    /// A committed migration, in commit order.
    fn migration(&mut self, m: MigrationRecord) {
        let _ = m;
    }

    /// A closed sliding-window bucket, in start order with no gaps
    /// (never called unless [`OnlineOptions::window`] is set). The core
    /// emits and drops — collecting the series is the sink's choice, so
    /// `--window` no longer forces O(run length) memory on a streaming
    /// run.
    fn window(&mut self, w: WindowSample) {
        let _ = w;
    }
}

/// The collect-everything [`RunSink`]: event log, per-job records,
/// rejection and migration ledgers — exactly the material of an
/// [`OnlineOutcome`]. [`OnlineScheduler::run`] is
/// `run_with_sink(CollectSink)` plus assembly.
#[derive(Debug, Clone, Default)]
pub struct CollectSink {
    pub events: EventLog,
    /// Records in emission (completion) order — [`OnlineScheduler::run`]
    /// sorts by job id at assembly.
    pub records: Vec<JobRecord>,
    pub rejected: Vec<JobId>,
    pub migrations: Vec<MigrationRecord>,
    /// Sliding-window series (empty unless [`OnlineOptions::window`]).
    pub windows: Vec<WindowSample>,
}

impl RunSink for CollectSink {
    fn event(&mut self, at: u64, job: JobId, kind: EventKind) {
        self.events.push(at, job, kind);
    }

    fn record(&mut self, record: JobRecord) {
        self.records.push(record);
    }

    fn reject(&mut self, _at: u64, job: JobId) {
        self.rejected.push(job);
    }

    fn migration(&mut self, m: MigrationRecord) {
        self.migrations.push(m);
    }

    fn window(&mut self, w: WindowSample) {
        self.windows.push(w);
    }
}

/// The constant-memory [`RunSink`]: JCT and wait distributions fold into
/// [`StreamSketch`]es (fixed-size, allocated once), events into a
/// per-kind counter array, and every record is dropped after folding.
/// Nothing here grows with the trace.
#[derive(Debug, Clone, Default)]
pub struct StreamSink {
    /// JCT (finish − arrival) distribution.
    pub jct: StreamSketch,
    /// Queueing-delay (start − arrival) distribution.
    pub wait: StreamSketch,
    /// Event tally indexed by [`EventKind::index`].
    pub event_counts: [u64; EventKind::COUNT],
    pub rejected: u64,
    pub migrations: u64,
    /// Sliding-window series — the one opt-in series this sink keeps
    /// (bounded by `slots / window`, not by the job count; armed only
    /// when the caller asked for the series via
    /// [`OnlineOptions::window`]). Probes that want a pure O(active) run
    /// override [`RunSink::window`] to fold-and-drop instead.
    pub windows: Vec<WindowSample>,
}

impl RunSink for StreamSink {
    fn event(&mut self, _at: u64, _job: JobId, kind: EventKind) {
        // archlint: allow(release-panic) kind.index() < EventKind::COUNT, the array's length
        self.event_counts[kind.index()] += 1;
    }

    fn record(&mut self, record: JobRecord) {
        self.jct.insert(record.jct());
        self.wait.insert(record.wait());
    }

    fn reject(&mut self, _at: u64, _job: JobId) {
        self.rejected += 1;
    }

    fn migration(&mut self, _m: MigrationRecord) {
        self.migrations += 1;
    }

    fn window(&mut self, w: WindowSample) {
        self.windows.push(w);
    }
}

/// Forwarding [`RunSink`] that mirrors every item into the run-digest
/// flight recorder ([`crate::obs::ledger`]) before handing it to the
/// real sink. `run_core` wraps its sink in this unconditionally, so the
/// ledger observes exactly the stream the sink observes — events,
/// records, rejections and migrations in realized order. Disarmed, each
/// hook costs one relaxed atomic load (the passivity contract).
struct LedgerTap<'s, K: RunSink> {
    inner: &'s mut K,
}

impl<K: RunSink> RunSink for LedgerTap<'_, K> {
    fn event(&mut self, at: u64, job: JobId, kind: EventKind) {
        crate::obs::ledger::note_event(at, job.0 as u64, kind.index() as u64);
        self.inner.event(at, job, kind);
    }

    fn record(&mut self, record: JobRecord) {
        crate::obs::ledger::note_record(&record);
        self.inner.record(record);
    }

    fn reject(&mut self, at: u64, job: JobId) {
        crate::obs::ledger::note_reject(at, job.0 as u64);
        self.inner.reject(at, job);
    }

    fn migration(&mut self, m: MigrationRecord) {
        crate::obs::ledger::note_migration(
            m.at,
            m.job.0 as u64,
            m.from_effective,
            m.to_effective,
            m.restart_slots,
        );
        self.inner.migration(m);
    }

    fn window(&mut self, w: WindowSample) {
        self.inner.window(w);
    }
}

/// Rolling aggregates the core maintains itself, identically in every
/// mode — integer sums (`u128`), so the streaming and collect-all paths
/// cannot drift even in the last ulp of a mean.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Slots actually simulated (loop end time).
    pub slots_simulated: u64,
    /// Constant-rate periods evaluated.
    pub periods: u64,
    /// GPU-slots spent busy (gangs hold GPUs through restart freezes).
    pub busy_gpu_slots: u64,
    /// Σ JCT over emitted records (truncated residuals included).
    pub jct_sum: u128,
    /// Σ queueing delay over emitted records.
    pub wait_sum: u128,
    /// Records emitted.
    pub finished: u64,
    /// `max finish` over emitted records — the makespan.
    pub max_finish: u64,
    /// True if the horizon (or an unplaceable job) cut the run short.
    pub truncated: bool,
    /// High-water mark of the pending-queue length.
    pub max_pending: usize,
    /// High-water mark of `pending + running + recovering` — the live-job
    /// set whose size bounds the core's memory (the quantity
    /// `BENCH_stream.json` reports against the O(active) claim).
    pub peak_live: usize,
    /// Gangs killed by a fault ([`EventKind::Failed`] emissions). One job
    /// crashed twice counts twice.
    pub failed: u64,
    /// Recovery-queue re-placements committed ([`EventKind::Recovered`]).
    pub recovered: u64,
    /// Σ (re-place slot − kill slot) over committed recoveries — the
    /// starvation ledger of the recovery queue.
    pub recovery_wait_slots: u128,
}

impl RunStats {
    /// Mean JCT (0 when no records) — one integer-to-float conversion,
    /// independent of emission order.
    pub fn avg_jct(&self) -> f64 {
        if self.finished == 0 { 0.0 } else { self.jct_sum as f64 / self.finished as f64 }
    }

    /// Mean queueing delay (0 when no records).
    pub fn avg_wait(&self) -> f64 {
        if self.finished == 0 { 0.0 } else { self.wait_sum as f64 / self.finished as f64 }
    }

    /// Fraction of GPU-slots spent busy up to the makespan.
    pub fn gpu_utilization(&self, num_gpus: usize) -> f64 {
        if self.max_finish == 0 || num_gpus == 0 {
            0.0
        } else {
            self.busy_gpu_slots as f64 / (self.max_finish * num_gpus as u64) as f64
        }
    }
}

/// Result of one streaming run ([`OnlineScheduler::run_streaming`]): the
/// same aggregates an [`OnlineOutcome`] carries — bit-identical where
/// exact (integer-sum means, makespan, counts, windows), sketch-backed
/// where a distribution would need O(jobs) memory (percentiles, within
/// the 1/32 bound of [`StreamSketch`]).
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    pub policy: String,
    /// `max_j T_j` over finished + residual jobs.
    pub makespan: u64,
    /// Exact mean JCT (integer sums).
    pub avg_jct: f64,
    /// Exact mean queueing delay.
    pub avg_wait: f64,
    pub gpu_utilization: f64,
    /// Jobs with emitted records (completions + truncated residuals).
    pub finished: u64,
    /// JCT distribution sketch (count/sum/min/max/mean exact, percentiles
    /// within 1/32).
    pub jct: StreamSketch,
    /// Queueing-delay distribution sketch.
    pub wait: StreamSketch,
    pub rejected: u64,
    pub migrations: u64,
    /// Event tally indexed by [`EventKind::index`].
    pub event_counts: [u64; EventKind::COUNT],
    pub max_pending: usize,
    /// High-water mark of `pending + running + recovering` — the memory
    /// bound.
    pub peak_live: usize,
    /// Fault kills ([`EventKind::Failed`] emissions).
    pub failed: u64,
    /// Recovery re-placements ([`EventKind::Recovered`] emissions).
    pub recovered: u64,
    /// Σ recovery-queue waits over committed recoveries (slots).
    pub recovery_wait_slots: u128,
    pub slots_simulated: u64,
    pub periods: u64,
    pub truncated: bool,
    /// Sliding-window series (empty unless [`OnlineOptions::window`]).
    pub windows: Vec<WindowSample>,
}

impl StreamOutcome {
    /// Number of events of one kind.
    pub fn event_count(&self, kind: EventKind) -> u64 {
        // archlint: allow(release-panic) kind.index() < EventKind::COUNT, the array's length
        self.event_counts[kind.index()]
    }

    /// Fraction of the offered load turned away: `rejected / offered`.
    pub fn rejection_rate(&self, offered: u64) -> f64 {
        if offered == 0 { 0.0 } else { self.rejected as f64 / offered as f64 }
    }
}

/// Result of one collect-all online run: the standard simulation outcome
/// plus the realized event sequence and the overload-control ledger.
#[derive(Debug, Clone)]
pub struct OnlineOutcome {
    pub policy: String,
    pub outcome: SimOutcome,
    pub events: EventLog,
    /// Arrivals turned away by admission control (θ or queue cap), in
    /// rejection order — plus, under faults, queued jobs retroactively
    /// rejected when permanent GPU failures shrink the cluster below
    /// their `G_j`. Jobs on this ledger never started and have no
    /// [`JobRecord`].
    pub rejected: Vec<JobId>,
    /// Every committed migration, in commit order.
    pub migrations: Vec<MigrationRecord>,
    /// High-water mark of the pending-queue length over the run.
    pub max_pending: usize,
    /// Gangs killed by injected faults (0 without a fault trace).
    pub failed: u64,
    /// Recovery-queue re-placements committed.
    pub recovered: u64,
    /// Σ recovery-queue waits over committed recoveries (slots).
    pub recovery_wait_slots: u128,
    /// Sliding-window steady-state series (empty unless
    /// [`OnlineOptions::window`] was set).
    pub windows: Vec<WindowSample>,
}

impl OnlineOutcome {
    /// Fraction of the offered load turned away: `rejected / offered`.
    pub fn rejection_rate(&self, offered: usize) -> f64 {
        if offered == 0 {
            0.0
        } else {
            self.rejected.len() as f64 / offered as f64
        }
    }

    /// Number of committed migrations.
    pub fn migration_count(&self) -> usize {
        self.migrations.len()
    }
}

/// A running gang. `S` owns or borrows the spec: `&JobSpec` for
/// materialized runs (zero copies), `JobSpec` for streaming runs — the
/// spec then lives exactly as long as the job does.
struct Running<S> {
    /// Recycled dense slot id — the key under which the tracker and dirty
    /// set know this job, so their dense tables stay O(peak live).
    slot: u32,
    /// Trace id — the only id events, records and decisions ever carry.
    job: JobId,
    spec: S,
    placement: JobPlacement,
    start: u64,
    progress: f64,
    tau_sum: f64,
    tau_slots: u64,
    max_p: usize,
    /// Checkpoint-restart gate: the job makes no progress before this
    /// slot (0 = never frozen; set to `t + restart_slots` on migration).
    freeze_until: u64,
    /// Times this job was preempted/re-placed.
    migrations: usize,
    /// Cached operating point — refreshed by the dirty-set drain (cache
    /// mode) or every period (reference mode). Never read while the job
    /// is frozen: steps 4/5 branch on `freeze_until` first.
    rate: RatePoint,
}

/// A gang killed by a server crash or GPU failure, holding its
/// checkpointed progress (the [`MigrationControl::restart_slots`] model:
/// completed iterations survive, in-flight work is lost) while it waits
/// in the FIFO recovery queue for capacity to re-place it.
struct Recovering<S> {
    job: JobId,
    spec: S,
    start: u64,
    progress: f64,
    tau_sum: f64,
    tau_slots: u64,
    max_p: usize,
    migrations: usize,
    /// Slot of the kill — recovery wait accrues from here.
    failed_at: u64,
    /// The placement held at kill time. The wait-only strategy (migration
    /// off) re-places *here and only here*, once every GPU of it is
    /// healthy and free again.
    home: JobPlacement,
}

/// Evict one running gang struck by a fault: release occupancy (while its
/// servers are still marked healthy — kills precede the down-marking),
/// forget its tracker counts and dirty-set membership, log the
/// [`EventKind::Failed`] event + audit record, and move the job —
/// checkpoint intact — to the recovery queue. The caller owns the
/// `running` vec (swap_remove + `running_idx` fixup happen there).
#[allow(clippy::too_many_arguments)]
fn fault_kill<S: Borrow<JobSpec>, K: RunSink>(
    r: Running<S>,
    t: u64,
    server: usize,
    topo: &crate::topology::Topology,
    rate_cache: bool,
    state: &mut ClusterState,
    tracker: &mut ContentionTracker,
    dirty: &mut crate::contention::DirtySet,
    running_idx: &mut [usize],
    free_slots: &mut Vec<u32>,
    sink: &mut K,
    recovering: &mut Vec<Recovering<S>>,
    stats: &mut RunStats,
) {
    use crate::obs::{explain, metrics};
    let sjob = JobId(r.slot as usize);
    state.release(r.job, &r.placement);
    let _ = tracker.complete(sjob);
    if rate_cache {
        dirty.on_complete(topo, &r.placement);
    }
    // archlint: allow(release-panic) slots index running_idx by construction (allocated at dispatch)
    running_idx[r.slot as usize] = usize::MAX;
    free_slots.push(r.slot);
    sink.event(t, r.job, EventKind::Failed);
    stats.failed += 1;
    metrics::incr(metrics::Counter::FaultKills);
    explain::record(explain::Decision::FaultKill {
        job: r.job,
        at: t,
        server,
        workers: r.placement.num_workers(),
    });
    recovering.push(Recovering {
        job: r.job,
        spec: r.spec,
        start: r.start,
        progress: r.progress,
        tau_sum: r.tau_sum,
        tau_slots: r.tau_slots,
        max_p: r.max_p,
        migrations: r.migrations,
        failed_at: t,
        home: r.placement,
    });
}

/// Fold one finished record into the rolling aggregates, then hand it to
/// the sink — the single emission point for completions and truncated
/// residuals, so the aggregates cannot diverge from the records.
fn emit_record<K: RunSink>(sink: &mut K, stats: &mut RunStats, rec: JobRecord) {
    stats.jct_sum += rec.jct() as u128;
    stats.wait_sum += rec.wait() as u128;
    stats.finished += 1;
    stats.max_finish = stats.max_finish.max(rec.finish);
    sink.record(rec);
}

/// Event-driven non-clairvoyant scheduler over one cluster + job stream.
///
/// The job slice supplies the arrival stream (its `arrival` fields); jobs
/// are revealed to the policy only once their arrival slot is reached.
/// For open-ended runs that never materialize the trace, build with
/// [`open`](Self::open) and feed an iterator to
/// [`run_streaming`](Self::run_streaming) /
/// [`run_with_sink`](Self::run_with_sink).
pub struct OnlineScheduler<'a> {
    cluster: &'a Cluster,
    jobs: &'a [JobSpec],
    params: &'a ContentionParams,
    options: OnlineOptions,
    /// Sorted fault stream merged into the loop (empty = every fault
    /// branch is skipped; see [`with_faults`](Self::with_faults)).
    faults: &'a [FaultEvent],
}

impl<'a> OnlineScheduler<'a> {
    pub fn new(cluster: &'a Cluster, jobs: &'a [JobSpec], params: &'a ContentionParams) -> Self {
        OnlineScheduler { cluster, jobs, params, options: OnlineOptions::default(), faults: &[] }
    }

    /// A scheduler with no materialized trace — arrivals are supplied per
    /// run to [`run_streaming`](Self::run_streaming) or
    /// [`run_with_sink`](Self::run_with_sink) (e.g. a lazy
    /// [`OpenArrivals`](crate::trace::OpenArrivals) stream).
    pub fn open(cluster: &'a Cluster, params: &'a ContentionParams) -> Self {
        OnlineScheduler {
            cluster,
            jobs: &[],
            params,
            options: OnlineOptions::default(),
            faults: &[],
        }
    }

    pub fn with_options(mut self, options: OnlineOptions) -> Self {
        self.options = options;
        self
    }

    /// Arm the run with a fault trace (see [`crate::faults`]). Events must
    /// be in non-decreasing `at` order, as [`FaultTrace::normalize`] and
    /// [`FaultSpec::generate`](crate::faults::FaultSpec::generate)
    /// guarantee. The empty trace leaves every fault branch unreached —
    /// the run is bit-identical to one never armed
    /// (`tests/fault_equivalence.rs` holds all modes to that).
    pub fn with_faults(mut self, trace: &'a FaultTrace) -> Self {
        debug_assert!(
            trace.events.windows(2).all(|w| w[0].at <= w[1].at),
            "fault trace must be sorted by `at` (call FaultTrace::normalize)"
        );
        self.faults = &trace.events;
        self
    }

    /// Currently-occupied GPU count per server (`capacity − free`), O(S)
    /// from the maintained free counts — the warm tally
    /// [`fa_ffp_select_warm`] takes (the loop-internal twin of
    /// [`ClusterView::occupied_per_server`]).
    fn occupied_per_server(&self, state: &ClusterState) -> Vec<usize> {
        self.cluster
            .server_ids()
            .map(|s| self.cluster.capacity(s) - state.free_on(s))
            .collect()
    }

    /// Speculative θ-admission projection for one arrival: place the gang
    /// with the same FA-FFP selection the dispatch policies use — over
    /// the free GPUs when a gang fits now, else over all GPUs (the
    /// structural floor on the contention the job must cause) — and read
    /// the bottleneck it *would* see from the incremental tracker without
    /// mutating any count. `None` iff the job can never be placed
    /// (`G_j` exceeds the cluster).
    fn projected_bottleneck(
        &self,
        state: &ClusterState,
        busy_history: &[f64],
        tracker: &ContentionTracker,
        gpus: usize,
    ) -> Option<Bottleneck> {
        let load = |g: GpuId| busy_history[g.global];
        let occ = self.occupied_per_server(state);
        let sel = fa_ffp_select_warm(self.cluster, gpus, |g| state.is_free(g), load, &occ)
            .or_else(|| fa_ffp_select_warm(self.cluster, gpus, |_| true, load, &occ));
        sel.map(|g| tracker.whatif_bottleneck(&JobPlacement::new(g)))
    }

    /// The best group-local free gang among server groups (racks or
    /// pods): pick the group whose `gpus` coolest free GPUs are least
    /// busy, then fill densest free servers first — fewest servers ⇒
    /// fewest crossed server uplinks inside the group. Shared by the
    /// rack- and pod-local stages of
    /// [`migration_candidate`](Self::migration_candidate). `servers_of`
    /// yields a group's servers lazily, so the scan allocates only for
    /// the winner (the sort needs a materialized list) and the free-GPU
    /// `busies` tally — same shape as the pre-pod rack stage.
    fn group_local_candidate<I: Iterator<Item = ServerId>>(
        &self,
        state: &ClusterState,
        busy_history: &[f64],
        gpus: usize,
        servers_of: impl Fn(usize) -> I,
        num_groups: usize,
    ) -> Option<JobPlacement> {
        let mut best: Option<(f64, usize)> = None;
        for group in 0..num_groups {
            let free: usize = servers_of(group).map(|s| state.free_on(s)).sum();
            if free >= gpus {
                let mut busies: Vec<f64> = servers_of(group)
                    .flat_map(|s| state.free_gpus_of(self.cluster, s))
                    .map(|g| busy_history[g.global])
                    .collect();
                let load = coolest_sum(&mut busies, gpus);
                if best.map_or(true, |(b, _)| load < b) {
                    best = Some((load, group));
                }
            }
        }
        let (_, group) = best?;
        let mut servers: Vec<ServerId> = servers_of(group).collect();
        servers.sort_by_key(|&s| (std::cmp::Reverse(state.free_on(s)), s));
        let mut gs: Vec<GpuId> = Vec::with_capacity(gpus);
        for s in servers {
            gs.extend(state.free_gpus_of(self.cluster, s));
            if gs.len() >= gpus {
                break;
            }
        }
        gs.truncate(gpus);
        Some(JobPlacement::new(gs))
    }

    /// Candidate gang for a migration, locality-first — the freed
    /// capacity the move should exploit, per the contention model's
    /// preference order:
    ///
    /// 1. a single **server** with a free gang (co-location: the ring
    ///    crosses no link at all),
    /// 2. a single **rack** with a free gang (the ring stays below one
    ///    ToR; densest servers first to minimize uplink crossings),
    /// 3. a single **pod** with a free gang (3-tier fabrics: the ring
    ///    crosses ToRs but stays below one pod switch),
    /// 4. cluster-wide FA-FFP over the free GPUs (fallback).
    ///
    /// Ties break by cumulative busy history (coolest capacity first),
    /// then ids — deterministic.
    fn migration_candidate(
        &self,
        state: &ClusterState,
        busy_history: &[f64],
        gpus: usize,
    ) -> Option<JobPlacement> {
        // (1) co-location on one server
        let mut best: Option<(f64, ServerId)> = None;
        for s in self.cluster.server_ids() {
            if state.free_on(s) >= gpus {
                let mut busies: Vec<f64> = state
                    .free_gpus_of(self.cluster, s)
                    .map(|g| busy_history[g.global])
                    .collect();
                let load = coolest_sum(&mut busies, gpus);
                if best.map_or(true, |(b, _)| load < b) {
                    best = Some((load, s));
                }
            }
        }
        if let Some((_, s)) = best {
            let mut gs: Vec<GpuId> = state.free_gpus_of(self.cluster, s).collect();
            gs.sort_by(|a, b| {
                busy_history[a.global]
                    .total_cmp(&busy_history[b.global])
                    .then(a.index.cmp(&b.index))
            });
            gs.truncate(gpus);
            return Some(JobPlacement::new(gs));
        }
        // (2) rack-local gang (rack tiers only; on a flat fabric every
        // server is its own rack, already covered by (1))
        let topo = self.cluster.topology();
        if topo.has_racks() {
            if let Some(pl) = self.group_local_candidate(
                state,
                busy_history,
                gpus,
                |g| topo.servers_in_rack(g),
                topo.num_racks(),
            ) {
                return Some(pl);
            }
        }
        // (3) pod-local gang (3-tier fabrics: below one pod switch)
        if topo.has_pods() {
            if let Some(pl) = self.group_local_candidate(
                state,
                busy_history,
                gpus,
                |g| topo.servers_in_pod(g),
                topo.num_pods(),
            ) {
                return Some(pl);
            }
        }
        // (4) cluster-wide fallback
        let occ = self.occupied_per_server(state);
        fa_ffp_select_warm(
            self.cluster,
            gpus,
            |g| state.is_free(g),
            |g| busy_history[g.global],
            &occ,
        )
        .map(JobPlacement::new)
    }

    /// Run the stream to completion (or the safety horizon) under one
    /// policy and report realized makespan / JCTs / waits under live
    /// contention. Collect-all mode: this is
    /// `run_with_sink(sorted jobs, CollectSink)` plus outcome assembly.
    pub fn run(&self, policy: &mut dyn OnlinePolicy) -> OnlineOutcome {
        use crate::obs::trace;
        let _run_span = trace::span("online.run", "online")
            .arg("jobs", self.jobs.len() as f64);
        // Arrival stream in (arrival, id) order — the only place the full
        // trace exists; the policy never sees past the revealed prefix.
        let mut order: Vec<&JobSpec> = self.jobs.iter().collect();
        order.sort_by_key(|j| (j.arrival, j.id));
        let mut sink = CollectSink::default();
        let stats = self.run_core(order.into_iter(), policy, &mut sink);
        let CollectSink { events, mut records, rejected, migrations, windows } = sink;
        records.sort_by_key(|r| r.job);
        OnlineOutcome {
            policy: policy.name().to_string(),
            outcome: SimOutcome {
                makespan: stats.max_finish,
                avg_jct: stats.avg_jct(),
                gpu_utilization: stats.gpu_utilization(self.cluster.num_gpus()),
                records,
                slots_simulated: stats.slots_simulated,
                periods: stats.periods,
                truncated: stats.truncated,
            },
            events,
            rejected,
            migrations,
            max_pending: stats.max_pending,
            failed: stats.failed,
            recovered: stats.recovered,
            recovery_wait_slots: stats.recovery_wait_slots,
            windows,
        }
    }

    /// Run an arrival stream through the [`StreamSink`]: O(active) memory
    /// end to end — per-job state exists only between arrival and
    /// completion, distributions fold into fixed-size sketches, and the
    /// returned [`StreamOutcome`] matches a [`run`](Self::run) of the
    /// same trace exactly on every aggregate (integer sums) plus sketch
    /// percentiles within 1/32.
    ///
    /// `arrivals` must be non-decreasing in arrival slot (ties in any
    /// order), as produced by
    /// [`TraceGenerator::arrivals`](crate::trace::TraceGenerator::arrivals)
    /// and
    /// [`open_arrivals`](crate::trace::TraceGenerator::open_arrivals), or
    /// by sorting a materialized slice by `(arrival, id)`.
    pub fn run_streaming<S, I>(
        &self,
        arrivals: I,
        policy: &mut dyn OnlinePolicy,
    ) -> StreamOutcome
    where
        S: Borrow<JobSpec>,
        I: Iterator<Item = S>,
    {
        use crate::obs::trace;
        let _run_span = trace::span("online.run_stream", "online");
        let mut sink = StreamSink::default();
        let stats = self.run_core(arrivals, policy, &mut sink);
        StreamOutcome {
            policy: policy.name().to_string(),
            makespan: stats.max_finish,
            avg_jct: stats.avg_jct(),
            avg_wait: stats.avg_wait(),
            gpu_utilization: stats.gpu_utilization(self.cluster.num_gpus()),
            finished: stats.finished,
            jct: sink.jct,
            wait: sink.wait,
            rejected: sink.rejected,
            migrations: sink.migrations,
            event_counts: sink.event_counts,
            max_pending: stats.max_pending,
            peak_live: stats.peak_live,
            failed: stats.failed,
            recovered: stats.recovered,
            recovery_wait_slots: stats.recovery_wait_slots,
            slots_simulated: stats.slots_simulated,
            periods: stats.periods,
            truncated: stats.truncated,
            windows: sink.windows,
        }
    }

    /// The generic core under any [`RunSink`] — public so equivalence
    /// tests and probes can interpose custom sinks on the exact loop the
    /// production paths run. `arrivals` must be non-decreasing in arrival
    /// slot (see [`run_streaming`](Self::run_streaming)).
    pub fn run_with_sink<S, I, K>(
        &self,
        arrivals: I,
        policy: &mut dyn OnlinePolicy,
        sink: &mut K,
    ) -> RunStats
    where
        S: Borrow<JobSpec>,
        I: Iterator<Item = S>,
        K: RunSink,
    {
        self.run_core(arrivals, policy, sink)
    }

    /// The event loop. One implementation for every mode; the sink and
    /// the spec ownership mode (`S`) are the only degrees of freedom.
    ///
    /// Memory discipline: running jobs are keyed by recycled dense slot
    /// ids inside the tracker / dirty set / `running_idx` (all bounded by
    /// peak concurrency); pending specs live in a map keyed by trace id,
    /// inserted on arrival and removed on dispatch. Nothing here scales
    /// with the total number of jobs streamed through.
    fn run_core<S, I, K>(
        &self,
        arrivals: I,
        policy: &mut dyn OnlinePolicy,
        sink: &mut K,
    ) -> RunStats
    where
        S: Borrow<JobSpec>,
        I: Iterator<Item = S>,
        K: RunSink,
    {
        use crate::obs::{explain, ledger, metrics, timeline, trace};
        let mut arrivals = arrivals.peekable();
        // Fault stream cursor. `fault_armed` gates every fault branch, so
        // an unarmed (or empty-trace) run never touches the recovery
        // machinery — bit-identical to the pre-fault loop by construction.
        let mut fault_stream = self.faults.iter().peekable();
        let fault_armed = !self.faults.is_empty();
        let mut recovering: Vec<Recovering<S>> = Vec::new();

        let mut state = ClusterState::new(self.cluster);
        let mut tracker = ContentionTracker::new(self.cluster);
        let topo = self.cluster.topology();
        // Link-keyed dirty set (§Perf): admissions/completions/migrations
        // touch the churned job's crossed links; only jobs sharing a
        // touched link are re-rated at the next period.
        let mut dirty = crate::contention::DirtySet::new(topo.num_links());
        // Slot-id free-list: tracker, dirty set and running_idx key their
        // dense tables by these recycled ids, so table size follows peak
        // concurrency, never the largest trace id.
        let mut free_slots: Vec<u32> = Vec::new();
        let mut next_slot: u32 = 0;
        let mut running_idx: Vec<usize> = Vec::new();
        let mut pending = PendingQueue::new();
        let mut pending_specs: HashMap<JobId, S> = HashMap::new();
        let mut busy_history = vec![0.0f64; self.cluster.num_gpus()];
        let mut running: Vec<Running<S>> = Vec::new();
        let mut stats = RunStats::default();
        let mut t: u64 = 0;
        let admission_active = self.options.admission.is_active();
        let rate_cache = self.options.rate_cache;
        let window = self.options.window;
        let mut win_acc = WindowAcc::default();
        // Every sink stream flows through the flight-recorder tap — the
        // ledger sees exactly what the sink sees. One relaxed atomic
        // load per item when disarmed.
        let sink = &mut LedgerTap { inner: sink };

        loop {
            // Flight-recorder checkpoint (passive): one relaxed atomic
            // load unless the ledger is armed AND the cadence slot is
            // due; the queue census and per-link counts are computed
            // only then.
            if ledger::checkpoint_due(t) {
                ledger::checkpoint(
                    t,
                    ledger::QueueCensus {
                        pending: pending.len(),
                        running: running.len(),
                        recovering: recovering.len(),
                        free_gpus: self.cluster.server_ids().map(|s| state.free_on(s)).sum(),
                    },
                    false,
                    || {
                        (0..topo.num_links())
                            .map(|l| tracker.link_count(LinkId(l)) as u64)
                            .collect::<Vec<u64>>()
                    },
                );
            }
            // 0) Apply fault events due by now — faults precede arrivals
            //    at equal slots, so a crash at t kills before t's
            //    arrivals queue behind it. Kills release occupancy while
            //    the server is still marked healthy (the release-guard
            //    invariant of ClusterState), then the server goes down.
            if fault_armed {
                let mut killed_any = false;
                let mut capacity_shrunk = false;
                while fault_stream.peek().map_or(false, |f| f.at <= t) {
                    let Some(&fe) = fault_stream.next() else {
                        debug_assert!(false, "peeked fault vanished");
                        break;
                    };
                    metrics::incr(metrics::Counter::FaultEvents);
                    ledger::note_fault(&fe);
                    match fe.action {
                        FaultAction::ServerCrash { server } => {
                            if server >= self.cluster.num_servers() {
                                continue; // trace from a bigger cluster
                            }
                            let s = ServerId(server);
                            if state.server_is_down(s) {
                                continue; // double-crash: idempotent
                            }
                            let mut i = 0;
                            while i < running.len() {
                                // archlint: allow(release-panic) loop condition bounds i; swap_remove re-checks it
                                if running[i].placement.gpus_on(s) > 0 {
                                    let r = running.swap_remove(i);
                                    fault_kill(
                                        r,
                                        t,
                                        server,
                                        topo,
                                        rate_cache,
                                        &mut state,
                                        &mut tracker,
                                        &mut dirty,
                                        &mut running_idx,
                                        &mut free_slots,
                                        sink,
                                        &mut recovering,
                                        &mut stats,
                                    );
                                    if i < running.len() {
                                        // archlint: allow(release-panic) slots index running_idx by construction (allocated at dispatch)
                                        running_idx[running[i].slot as usize] = i;
                                    }
                                    killed_any = true;
                                } else {
                                    i += 1;
                                }
                            }
                            state.set_server_down(self.cluster, s);
                        }
                        FaultAction::ServerRecover { server } => {
                            if server < self.cluster.num_servers() {
                                state.set_server_up(self.cluster, ServerId(server));
                            }
                        }
                        FaultAction::GpuFail { server, gpu } => {
                            if server >= self.cluster.num_servers()
                                || gpu >= self.cluster.capacity(ServerId(server))
                            {
                                continue;
                            }
                            let g = self.cluster.global_gpu(ServerId(server), gpu);
                            if state.owner_of(g).is_some() {
                                if let Some(i) = running
                                    .iter()
                                    .position(|r| r.placement.gpus().contains(&g))
                                {
                                    let r = running.swap_remove(i);
                                    fault_kill(
                                        r,
                                        t,
                                        server,
                                        topo,
                                        rate_cache,
                                        &mut state,
                                        &mut tracker,
                                        &mut dirty,
                                        &mut running_idx,
                                        &mut free_slots,
                                        sink,
                                        &mut recovering,
                                        &mut stats,
                                    );
                                    if i < running.len() {
                                        // archlint: allow(release-panic) slots index running_idx by construction (allocated at dispatch)
                                        running_idx[running[i].slot as usize] = i;
                                    }
                                    killed_any = true;
                                }
                            }
                            state.fail_gpu(g);
                            capacity_shrunk = true;
                        }
                        FaultAction::LinkDegrade { link, factor } => {
                            if link < topo.num_links() {
                                tracker.degrade_link(LinkId(link), factor);
                                if rate_cache {
                                    dirty.on_capacity_change(LinkId(link));
                                }
                                sink.event(t, LINK_EVENT_JOB, EventKind::Degraded);
                                metrics::incr(metrics::Counter::LinkChanges);
                                explain::record(explain::Decision::LinkChange {
                                    link,
                                    at: t,
                                    factor,
                                });
                            }
                        }
                        FaultAction::LinkRestore { link } => {
                            if link < topo.num_links() {
                                tracker.restore_link(LinkId(link));
                                if rate_cache {
                                    dirty.on_capacity_change(LinkId(link));
                                }
                                sink.event(t, LINK_EVENT_JOB, EventKind::Degraded);
                                metrics::incr(metrics::Counter::LinkChanges);
                                explain::record(explain::Decision::LinkChange {
                                    link,
                                    at: t,
                                    factor: 1.0,
                                });
                            }
                        }
                    }
                }
                if killed_any {
                    timeline::sample(t, &tracker);
                }
                // Retroactive admission (armed guards only): a permanent
                // GPU failure may have shrunk the *potential* pool — the
                // ceiling any future recovery can restore — below a
                // queued job's G_j. Such a job can never be placed again;
                // turn it away now instead of wedging the queue into
                // truncation, exactly like the arrival-time TooLarge
                // guard would have.
                if capacity_shrunk && admission_active {
                    let ceiling = state.potential_gpus();
                    let doomed: Vec<JobId> = pending
                        .iter()
                        .filter(|(job, _)| {
                            pending_specs
                                .get(job)
                                .map_or(false, |s| s.borrow().gpus > ceiling)
                        })
                        .map(|(job, _)| job)
                        .collect();
                    for job in doomed {
                        pending.remove(job);
                        pending_specs.remove(&job);
                        sink.event(t, job, EventKind::Rejected);
                        sink.reject(t, job);
                        metrics::incr(metrics::Counter::AdmissionRejects);
                        explain::record(explain::Decision::Reject {
                            job,
                            at: t,
                            reason: explain::RejectReason::TooLarge,
                            projected: -1.0,
                            theta: -1.0,
                        });
                    }
                }
            }

            // 1) Reveal arrivals due by now. With admission control armed,
            //    each arrival passes the queue-cap and θ guards before it
            //    may enter the pending queue; a turned-away job logs
            //    Arrival → Rejected and is gone (an open system's caller
            //    retries elsewhere — there is no hidden backlog).
            while arrivals.peek().map_or(false, |s| s.borrow().arrival <= t) {
                // peek() just returned Some, so next() cannot be None —
                // but the hot loop degrades to "stop revealing" rather
                // than panicking if an iterator ever misbehaves.
                let Some(spec) = arrivals.next() else {
                    debug_assert!(false, "peeked arrival vanished");
                    break;
                };
                let (id, at, gpus) = {
                    let s = spec.borrow();
                    (s.id, s.arrival, s.gpus)
                };
                sink.event(at, id, EventKind::Arrival);
                if trace::armed() {
                    trace::instant(
                        "job.arrive",
                        "online",
                        &[
                            ("job", id.0 as f64),
                            ("t", at as f64),
                            ("gpus", gpus as f64),
                        ],
                    );
                }
                if admission_active {
                    // `(reason, projected, θ)` — the audit payload; -1
                    // marks "not a θ decision" (keeps the JSON finite).
                    let reject = if gpus > self.cluster.num_gpus() {
                        // never placeable: every armed admission guard
                        // turns it away instead of letting it wedge the
                        // queue into truncation (queue-cap-only included)
                        Some((explain::RejectReason::TooLarge, -1.0, -1.0))
                    } else if self.options.admission.queue_full(pending.len()) {
                        Some((explain::RejectReason::QueueFull, -1.0, -1.0))
                    } else if self.options.admission.theta.is_finite() {
                        // archlint: allow(obs-passivity) counter delta feeds only the WhatifPerArrival histogram, never a decision
                        let whatif_before = metrics::get(metrics::Counter::WhatifCalls);
                        let projected = self.projected_bottleneck(
                            &state,
                            &busy_history,
                            &tracker,
                            gpus,
                        );
                        metrics::record(
                            metrics::Hist::WhatifPerArrival,
                            metrics::get(metrics::Counter::WhatifCalls) - whatif_before,
                        );
                        if self.options.admission.theta_exceeded(projected) {
                            let eff = projected.map_or(-1.0, |b| b.effective());
                            Some((
                                explain::RejectReason::Theta,
                                eff,
                                self.options.admission.theta,
                            ))
                        } else {
                            None
                        }
                    } else {
                        None
                    };
                    if let Some((reason, projected, theta)) = reject {
                        sink.event(at, id, EventKind::Rejected);
                        sink.reject(at, id);
                        metrics::incr(metrics::Counter::AdmissionRejects);
                        if trace::armed() {
                            trace::instant(
                                "job.reject",
                                "online",
                                &[("job", id.0 as f64), ("t", at as f64)],
                            );
                        }
                        explain::record(explain::Decision::Reject {
                            job: id,
                            at,
                            reason,
                            projected,
                            theta,
                        });
                        continue;
                    }
                }
                pending.push(id, at);
                pending_specs.insert(id, spec);
                stats.max_pending = stats.max_pending.max(pending.len());
                // pending + running + recovering peaks right after an
                // accept: dispatch and fault kills keep the sum constant,
                // completions and rejections only shrink it
                stats.peak_live = stats
                    .peak_live
                    .max(pending.len() + running.len() + recovering.len());
            }

            // Horizon guard sits *before* dispatch so no job can start at
            // t == max_slots only to be truncated with a zero-length record.
            if t >= self.options.max_slots {
                break;
            }

            // 1b) Drain the recovery queue, FIFO (oldest kill first — the
            //     starvation-fair order). Migration-armed runs re-place
            //     via the locality-first candidate machinery over the
            //     surviving GPUs; wait-only (rigid) runs re-place a job
            //     only onto its original gang, once every GPU of it is
            //     healthy and free. A commit restarts the job frozen for
            //     `restart_slots` with its checkpointed progress; a job
            //     whose G_j exceeds the *potential* pool (permanent GPU
            //     failures) can never run again and is terminally
            //     rejected with its partial-progress record.
            let mut recovered_any = false;
            if fault_armed && !recovering.is_empty() {
                let mut k = 0;
                while k < recovering.len() {
                    let gpus = recovering[k].spec.borrow().gpus;
                    if gpus > state.potential_gpus() {
                        let rec = recovering.remove(k);
                        sink.event(t, rec.job, EventKind::Rejected);
                        explain::record(explain::Decision::Reject {
                            job: rec.job,
                            at: t,
                            reason: explain::RejectReason::TooLarge,
                            projected: -1.0,
                            theta: -1.0,
                        });
                        emit_record(
                            sink,
                            &mut stats,
                            JobRecord {
                                job: rec.job,
                                arrival: rec.spec.borrow().arrival,
                                start: rec.start,
                                finish: t,
                                span: rec.home.span(),
                                workers: rec.home.num_workers(),
                                max_p: rec.max_p,
                                mean_tau: rec.tau_sum / rec.tau_slots.max(1) as f64,
                                iterations_done: kernel::completed_iterations(
                                    rec.progress,
                                ),
                                migrations: rec.migrations,
                            },
                        );
                        continue;
                    }
                    let candidate = if self.options.migration.enabled {
                        self.migration_candidate(&state, &busy_history, gpus)
                    } else {
                        // archlint: allow(release-panic) k is bounded by the while condition
                        let home = &recovering[k].home;
                        if home.gpus().iter().all(|&g| state.is_free(g)) {
                            Some(home.clone())
                        } else {
                            None
                        }
                    };
                    let Some(placement) = candidate else {
                        // archlint: allow(release-panic) k is bounded by the while condition
                        let rec = &recovering[k];
                        let guard = if self.options.migration.enabled {
                            explain::RecoveryGuard::NoCapacity
                        } else {
                            explain::RecoveryGuard::HomeDown
                        };
                        metrics::incr(metrics::Counter::RecoveryDeferrals);
                        explain::record(explain::Decision::RecoveryDefer {
                            job: rec.job,
                            at: t,
                            guard,
                            wait_slots: t - rec.failed_at,
                        });
                        k += 1;
                        continue;
                    };
                    let rec = recovering.remove(k);
                    let slot = match free_slots.pop() {
                        Some(s) => s,
                        None => {
                            let s = next_slot;
                            next_slot += 1;
                            running_idx.push(usize::MAX);
                            s
                        }
                    };
                    let sjob = JobId(slot as usize);
                    state.allocate(rec.job, &placement);
                    tracker.admit(sjob, &placement);
                    if rate_cache {
                        dirty.on_admit(topo, sjob, &placement);
                    }
                    // archlint: allow(release-panic) slot came from free_slots or just grew running_idx
                    running_idx[slot as usize] = running.len();
                    sink.event(t, rec.job, EventKind::Recovered);
                    recovered_any = true;
                    let wait_slots = t - rec.failed_at;
                    stats.recovered += 1;
                    stats.recovery_wait_slots += wait_slots as u128;
                    metrics::incr(metrics::Counter::RecoveryCommits);
                    explain::record(explain::Decision::RecoveryPlace {
                        job: rec.job,
                        at: t,
                        wait_slots,
                        effective: tracker.bottleneck(sjob).effective(),
                    });
                    running.push(Running {
                        slot,
                        job: rec.job,
                        spec: rec.spec,
                        placement,
                        start: rec.start,
                        progress: rec.progress,
                        tau_sum: rec.tau_sum,
                        tau_slots: rec.tau_slots,
                        max_p: rec.max_p,
                        freeze_until: t
                            .saturating_add(self.options.migration.restart_slots),
                        migrations: rec.migrations,
                        rate: RatePoint::IDLE,
                    });
                }
            }
            if recovered_any {
                timeline::sample(t, &tracker);
            }

            // 2) Let the policy start jobs until it declines. Each accepted
            //    dispatch is validated: the job must be queued and the
            //    placement must be a free gang of exactly G_j GPUs
            //    (ClusterState::allocate asserts freeness).
            let mut started_any = false;
            while !pending.is_empty() {
                // `pending` and `pending_specs` move in lockstep; a
                // missing spec is a corrupted queue (debug-asserted),
                // degraded in release to "that job is not offered".
                let queued: Vec<QueuedJob<'_>> = pending
                    .iter()
                    .filter_map(|(job, arrival)| {
                        let spec = pending_specs.get(&job);
                        debug_assert!(spec.is_some(), "queued job has a pending spec");
                        Some(QueuedJob { spec: spec?.borrow(), waited: t - arrival })
                    })
                    .collect();
                let view = ClusterView::new(self.cluster, &state, &busy_history, t);
                let Some((job, placement)) = policy.dispatch(&queued, &view) else { break };
                drop(queued);
                assert!(pending.remove(job), "policy dispatched {job} which is not queued");
                let Some(spec) = pending_specs.remove(&job) else {
                    debug_assert!(false, "dispatched job has a pending spec");
                    continue;
                };
                assert_eq!(
                    placement.num_workers(),
                    spec.borrow().gpus,
                    "gang scheduling: placement must have exactly G_j GPUs"
                );
                let slot = match free_slots.pop() {
                    Some(s) => s,
                    None => {
                        let s = next_slot;
                        next_slot += 1;
                        running_idx.push(usize::MAX);
                        s
                    }
                };
                let sjob = JobId(slot as usize);
                state.allocate(job, &placement);
                tracker.admit(sjob, &placement);
                if rate_cache {
                    dirty.on_admit(topo, sjob, &placement);
                }
                // archlint: allow(release-panic) slot came from free_slots or just grew running_idx
                running_idx[slot as usize] = running.len();
                sink.event(t, job, EventKind::Start);
                started_any = true;
                if trace::armed() || explain::armed() {
                    // audit the dispatch: the realized bottleneck of the
                    // chosen gang, and (explain only) the next-best gang
                    // FA-FFP would pick from what is still free — the
                    // runner-up a different policy call could have taken.
                    let bn = tracker.bottleneck(sjob);
                    if trace::armed() {
                        trace::instant(
                            "job.admit",
                            "online",
                            &[
                                ("job", job.0 as f64),
                                ("t", t as f64),
                                ("link", bn.link.map_or(-1.0, |l| l.0 as f64)),
                            ],
                        );
                    }
                    if explain::armed() {
                        let free_now: usize =
                            self.cluster.server_ids().map(|s| state.free_on(s)).sum();
                        let occ = self.occupied_per_server(&state);
                        let runner_up = fa_ffp_select_warm(
                            self.cluster,
                            spec.borrow().gpus,
                            |g| state.is_free(g),
                            |g| busy_history[g.global],
                            &occ,
                        )
                        .map(|g| {
                            tracker.whatif_bottleneck(&JobPlacement::new(g)).effective()
                        });
                        explain::record(explain::Decision::Placement {
                            job,
                            at: t,
                            chosen_score: bn.effective(),
                            runner_up,
                            candidates: free_now + spec.borrow().gpus,
                        });
                    }
                }
                running.push(Running {
                    slot,
                    job,
                    spec,
                    placement,
                    start: t,
                    progress: 0.0,
                    tau_sum: 0.0,
                    tau_slots: 0,
                    max_p: 0,
                    freeze_until: 0,
                    migrations: 0,
                    rate: RatePoint::IDLE,
                });
            }
            if started_any {
                timeline::sample(t, &tracker);
            }

            if running.is_empty() {
                if pending.is_empty() && recovering.is_empty() && arrivals.peek().is_none() {
                    // All jobs are done. Trailing fault events would
                    // strike an empty cluster — nothing left to observe.
                    break;
                }
                // Idle (or stuck) until the next event reveals work: an
                // arrival, or — under faults — a fault instant (a server
                // recovery can unblock a stuck pending/recovering
                // backlog, so the loop must wake for it).
                let next_arrival = arrivals.peek().map(|s| s.borrow().arrival);
                let next_fault =
                    if fault_armed { fault_stream.peek().map(|f| f.at) } else { None };
                let wake = match (next_arrival, next_fault) {
                    (Some(a), Some(f)) => Some(a.min(f)),
                    (a, f) => a.or(f),
                };
                match wake {
                    Some(at) if at < self.options.max_slots => {
                        if let Some(w) = window {
                            // idle gap: zero busy GPUs, but the queue may
                            // hold a stuck (unplaceable) backlog
                            if at > t {
                                win_acc.account(
                                    sink,
                                    w,
                                    t,
                                    at - t,
                                    0.0,
                                    state.healthy_gpus() as f64,
                                    pending.len(),
                                );
                            }
                        }
                        t = at;
                        continue;
                    }
                    // Backlog no future event can unblock (e.g. a job
                    // larger than the cluster, or a dead home gang with
                    // no recovery left in the trace): truncate.
                    _ => break,
                }
            }

            // 3) Constant-rate period: the bottleneck link from the
            //    incremental tracker, τ/φ from the shared simulation
            //    kernel. Cache mode re-rates only the jobs the dirty set
            //    invalidated; reference mode re-rates everyone. A frozen
            //    (restarting) job's cached rate is never read this period
            //    — steps 4/5 branch on the freeze first.
            let _period_span = trace::span("online.period", "online")
                .arg("t", t as f64)
                .arg("running", running.len() as f64);
            if rate_cache {
                let active = running.len();
                let rerated = dirty.drain(
                    |j| running_idx.get(j.0).map_or(false, |&i| i != usize::MAX),
                    |j| {
                        // archlint: allow(release-panic) is_active filter above admits only live slots
                        let r = &mut running[running_idx[j.0]];
                        r.rate = kernel::rate_point(
                            self.params,
                            self.cluster,
                            r.spec.borrow(),
                            &r.placement,
                            tracker.bottleneck(j),
                            self.options.fractional_progress,
                        );
                    },
                );
                metrics::add(metrics::Counter::DirtyMisses, rerated as u64);
                metrics::add(metrics::Counter::DirtyHits, (active - rerated) as u64);
                metrics::record(metrics::Hist::ReratedPerDrain, rerated as u64);
            } else {
                for r in running.iter_mut() {
                    if t < r.freeze_until {
                        continue; // never read while frozen; re-rated at thaw
                    }
                    r.rate = kernel::rate_point(
                        self.params,
                        self.cluster,
                        r.spec.borrow(),
                        &r.placement,
                        tracker.bottleneck(JobId(r.slot as usize)),
                        self.options.fractional_progress,
                    );
                }
            }
            stats.periods += 1;
            metrics::incr(metrics::Counter::OnlinePeriods);

            // 4) Jump to the next event: completion, thaw of a restarting
            //    (migrated) job, arrival or horizon. A period never spans
            //    a thaw boundary, so "frozen" is constant within it.
            let mut dt = u64::MAX;
            for r in running.iter() {
                if t < r.freeze_until {
                    dt = dt.min(r.freeze_until - t); // re-rate at thaw
                } else {
                    let remaining = r.spec.borrow().iterations as f64 - r.progress;
                    dt = dt.min(kernel::slots_until_done(remaining, r.rate.inc));
                }
            }
            if let Some(s) = arrivals.peek() {
                let at = s.borrow().arrival;
                debug_assert!(at > t, "due arrivals were revealed in step 1");
                dt = dt.min(at - t);
            }
            if fault_armed {
                // a period never spans a fault instant: capacity and
                // link multipliers are constant within it
                if let Some(f) = fault_stream.peek() {
                    debug_assert!(f.at > t, "due faults were applied in step 0");
                    dt = dt.min(f.at - t);
                }
            }
            let dt = dt.min(self.options.max_slots - t).max(1);

            // 5) Progress every running job by dt slots. A job inside its
            //    checkpoint-restart window holds its GPUs (they stay busy
            //    for utilization accounting) but makes no progress and
            //    accrues no τ statistics.
            if let Some(w) = window {
                // queue length and the busy gang set are constant over a
                // period; split the period exactly across window buckets
                let busy_per_slot: f64 =
                    running.iter().map(|r| r.placement.num_workers() as f64).sum();
                win_acc.account(
                    sink,
                    w,
                    t,
                    dt,
                    busy_per_slot,
                    state.healthy_gpus() as f64,
                    pending.len(),
                );
            }
            for r in running.iter_mut() {
                if t >= r.freeze_until {
                    r.progress += r.rate.inc * dt as f64;
                    r.tau_sum += r.rate.tau * dt as f64;
                    r.tau_slots += dt;
                    r.max_p = r.max_p.max(r.rate.p);
                }
                stats.busy_gpu_slots += r.placement.num_workers() as u64 * dt;
                for g in r.placement.gpus() {
                    busy_history[g.global] += dt as f64;
                }
            }
            t += dt;

            // 6) Completions at the end of the period.
            let mut completed_any = false;
            let mut i = 0;
            while i < running.len() {
                // archlint: allow(release-panic) loop condition bounds i; swap_remove re-checks it
                if running[i].progress >= running[i].spec.borrow().iterations as f64 {
                    let r = running.swap_remove(i);
                    let sjob = JobId(r.slot as usize);
                    state.release(r.job, &r.placement);
                    if trace::armed() {
                        // bottleneck read precedes `complete` — the
                        // tracker forgets the job's links on removal
                        let bn = tracker.bottleneck(sjob);
                        trace::instant(
                            "job.complete",
                            "online",
                            &[
                                ("job", r.job.0 as f64),
                                ("t", t as f64),
                                ("link", bn.link.map_or(-1.0, |l| l.0 as f64)),
                            ],
                        );
                    }
                    let _ = tracker.complete(sjob);
                    if rate_cache {
                        dirty.on_complete(topo, &r.placement);
                    }
                    // archlint: allow(release-panic) slots index running_idx by construction (allocated above)
                    running_idx[r.slot as usize] = usize::MAX;
                    if i < running.len() {
                        // archlint: allow(release-panic) slots index running_idx by construction (allocated above)
                        running_idx[running[i].slot as usize] = i;
                    }
                    free_slots.push(r.slot);
                    sink.event(t, r.job, EventKind::Completion);
                    completed_any = true;
                    emit_record(
                        sink,
                        &mut stats,
                        JobRecord {
                            job: r.job,
                            arrival: r.spec.borrow().arrival,
                            start: r.start,
                            finish: t,
                            span: r.placement.span(),
                            workers: r.placement.num_workers(),
                            max_p: r.max_p,
                            mean_tau: r.tau_sum / r.tau_slots.max(1) as f64,
                            iterations_done: r.spec.borrow().iterations,
                            migrations: r.migrations,
                        },
                    );
                } else {
                    i += 1;
                }
            }
            if completed_any {
                timeline::sample(t, &tracker);
            }

            // 7) Migration hook: completions freed capacity — re-place up
            //    to K running jobs whose bottleneck strictly improves net
            //    of the checkpoint-restart cost. Worst bottleneck first
            //    (they gain the most), deterministic tie-break by job id.
            if self.options.migration.enabled && completed_any && !running.is_empty() {
                let mig = self.options.migration;
                // one O(span) bottleneck walk per job, not per comparison
                let mut by_pressure: Vec<(f64, usize)> = running
                    .iter()
                    .enumerate()
                    .map(|(i, r)| {
                        (tracker.bottleneck(JobId(r.slot as usize)).effective(), i)
                    })
                    .collect();
                by_pressure.sort_by(|a, b| {
                    // archlint: allow(release-panic) by_pressure holds enumerate() indices of running
                    b.0.total_cmp(&a.0).then(running[a.1].job.cmp(&running[b.1].job))
                });
                let mut moved = 0usize;
                for (_, idx) in by_pressure {
                    if moved >= mig.max_moves {
                        break;
                    }
                    let (job, sjob, gpus, cur_bn, remaining) = {
                        // archlint: allow(release-panic) idx is an enumerate() index; no removal since
                        let r = &running[idx];
                        if t < r.freeze_until {
                            continue; // still restarting from an earlier move
                        }
                        let sjob = JobId(r.slot as usize);
                        (
                            r.job,
                            sjob,
                            r.spec.borrow().gpus,
                            tracker.bottleneck(sjob),
                            r.spec.borrow().iterations as f64 - r.progress,
                        )
                    };
                    if cur_bn.link.is_none() {
                        continue; // co-located: nothing to improve
                    }
                    // locality-first candidate over the freed capacity:
                    // one server, else one rack, else cluster-wide FA-FFP
                    let Some(candidate) =
                        self.migration_candidate(&state, &busy_history, gpus)
                    else {
                        metrics::incr(metrics::Counter::MigrationAborts);
                        explain::record(explain::Decision::MigrationAbort {
                            job,
                            at: t,
                            guard: explain::MigrationGuard::NoCandidate,
                            current_effective: cur_bn.effective(),
                            candidate_effective: -1.0,
                        });
                        continue;
                    };
                    let Some(new_bn) = tracker.whatif_rebottleneck(sjob, &candidate) else {
                        metrics::incr(metrics::Counter::MigrationAborts);
                        explain::record(explain::Decision::MigrationAbort {
                            job,
                            at: t,
                            guard: explain::MigrationGuard::NoCandidate,
                            current_effective: cur_bn.effective(),
                            candidate_effective: -1.0,
                        });
                        continue;
                    };
                    // guard 1: strictly lower bottleneck effective degree
                    if new_bn.effective() >= cur_bn.effective() {
                        metrics::incr(metrics::Counter::MigrationAborts);
                        explain::record(explain::Decision::MigrationAbort {
                            job,
                            at: t,
                            guard: explain::MigrationGuard::StrictImprovement,
                            current_effective: cur_bn.effective(),
                            candidate_effective: new_bn.effective(),
                        });
                        continue;
                    }
                    // guard 2: completion-time gain net of restart cost
                    // (shared kernel arithmetic, same rates the loop uses)
                    // archlint: allow(release-panic) idx is an enumerate() index; no removal since
                    let mover = &running[idx];
                    let old_rate = kernel::rate_point(
                        self.params,
                        self.cluster,
                        mover.spec.borrow(),
                        &mover.placement,
                        cur_bn,
                        self.options.fractional_progress,
                    );
                    let new_rate = kernel::rate_point(
                        self.params,
                        self.cluster,
                        mover.spec.borrow(),
                        &candidate,
                        new_bn,
                        self.options.fractional_progress,
                    );
                    if !kernel::migration_pays(
                        remaining,
                        old_rate.inc,
                        new_rate.inc,
                        mig.restart_slots,
                    ) {
                        metrics::incr(metrics::Counter::MigrationAborts);
                        explain::record(explain::Decision::MigrationAbort {
                            job,
                            at: t,
                            guard: explain::MigrationGuard::PaysForItself,
                            current_effective: cur_bn.effective(),
                            candidate_effective: new_bn.effective(),
                        });
                        continue;
                    }
                    // commit: occupancy, tracker counts, event, freeze.
                    // For the dirty set a migration is a departure from
                    // the old links plus an admission on the new ones —
                    // the migrant re-rates via the admit half, old
                    // link-sharers via the touched old links.
                    state.release(job, &mover.placement);
                    state.allocate(job, &candidate);
                    tracker.migrate(sjob, &candidate);
                    if rate_cache {
                        dirty.on_migrate(topo, sjob, &mover.placement, &candidate);
                    }
                    sink.event(t, job, EventKind::Migrated);
                    metrics::incr(metrics::Counter::MigrationCommits);
                    if trace::armed() {
                        trace::instant(
                            "job.migrate",
                            "online",
                            &[
                                ("job", job.0 as f64),
                                ("t", t as f64),
                                ("link", new_bn.link.map_or(-1.0, |l| l.0 as f64)),
                            ],
                        );
                    }
                    explain::record(explain::Decision::MigrationCommit {
                        job,
                        at: t,
                        from_effective: cur_bn.effective(),
                        to_effective: new_bn.effective(),
                        restart_slots: mig.restart_slots,
                    });
                    sink.migration(MigrationRecord {
                        job,
                        at: t,
                        from_effective: cur_bn.effective(),
                        to_effective: new_bn.effective(),
                        restart_slots: mig.restart_slots,
                    });
                    // archlint: allow(release-panic) idx is an enumerate() index; no removal since
                    let r = &mut running[idx];
                    r.placement = candidate;
                    r.freeze_until = t.saturating_add(mig.restart_slots);
                    r.migrations += 1;
                    moved += 1;
                }
                if moved > 0 {
                    timeline::sample(t, &tracker);
                }
            }
        }

        // Close the window series: the still-open bucket flushes through
        // the sink, so the emitted series tiles exactly what the old
        // materialized one covered.
        win_acc.finish(sink);
        stats.truncated = !pending.is_empty()
            || !running.is_empty()
            || !recovering.is_empty()
            || arrivals.peek().is_some();
        // Residual recovering jobs flush like running ones (every admitted
        // job gets exactly one record — the conservation invariant the
        // chaos tests audit), with the progress their checkpoint retains.
        for rec in recovering {
            emit_record(
                sink,
                &mut stats,
                JobRecord {
                    job: rec.job,
                    arrival: rec.spec.borrow().arrival,
                    start: rec.start,
                    finish: t,
                    span: rec.home.span(),
                    workers: rec.home.num_workers(),
                    max_p: rec.max_p,
                    mean_tau: rec.tau_sum / rec.tau_slots.max(1) as f64,
                    iterations_done: kernel::completed_iterations(rec.progress),
                    migrations: rec.migrations,
                },
            );
        }
        for r in running {
            emit_record(
                sink,
                &mut stats,
                JobRecord {
                    job: r.job,
                    arrival: r.spec.borrow().arrival,
                    start: r.start,
                    finish: t,
                    span: r.placement.span(),
                    workers: r.placement.num_workers(),
                    max_p: r.max_p,
                    mean_tau: r.tau_sum / r.tau_slots.max(1) as f64,
                    iterations_done: kernel::completed_iterations(r.progress),
                    migrations: r.migrations,
                },
            );
        }
        // Forced final checkpoint: the record stream is complete here
        // (residuals flushed), so two equivalent runs close their
        // ledgers on identical digests even when the cadence never
        // divided the final slot.
        if ledger::armed() {
            ledger::checkpoint(
                t,
                ledger::QueueCensus {
                    pending: pending.len(),
                    running: 0,
                    recovering: 0,
                    free_gpus: self.cluster.server_ids().map(|s| state.free_on(s)).sum(),
                },
                true,
                || {
                    (0..topo.num_links())
                        .map(|l| tracker.link_count(LinkId(l)) as u64)
                        .collect::<Vec<u64>>()
                },
            );
        }
        stats.slots_simulated = t;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{ArrivalProcess, TraceGenerator};

    fn setup() -> (Cluster, ContentionParams) {
        (Cluster::uniform(4, 8, 1.0, 25.0), ContentionParams::paper())
    }

    #[test]
    fn every_policy_completes_a_poisson_trace() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(7, 10.0);
        for kind in OnlinePolicyKind::ALL {
            let mut policy = kind.build();
            let out = OnlineScheduler::new(&c, &jobs, &p).run(policy.as_mut());
            assert_eq!(out.policy, kind.name());
            assert!(!out.outcome.truncated, "{kind} truncated");
            assert_eq!(out.outcome.records.len(), jobs.len(), "{kind}");
            for r in &out.outcome.records {
                assert!(r.start >= r.arrival, "{kind}: {} started before arrival", r.job);
                assert!(r.finish > r.start);
                assert_eq!(
                    r.iterations_done,
                    jobs.iter().find(|j| j.id == r.job).unwrap().iterations
                );
            }
            assert!(out.events.is_causally_ordered(), "{kind}");
            assert_eq!(out.events.count(EventKind::Start), jobs.len());
            assert_eq!(out.events.count(EventKind::Completion), jobs.len());
        }
    }

    #[test]
    fn batch_arrivals_reduce_to_greedy_schedule() {
        // gap 0: all jobs arrive at t = 0; the loop must still run them
        // all, in waves bounded by cluster capacity.
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(3, 0.0);
        let mut policy = OnlineSjfBco::default();
        let out = OnlineScheduler::new(&c, &jobs, &p).run(&mut policy);
        assert!(!out.outcome.truncated);
        assert_eq!(out.outcome.records.len(), jobs.len());
        assert!(out.outcome.makespan > 0);
    }

    #[test]
    fn oversized_job_truncates_instead_of_hanging() {
        let (c, p) = setup();
        let mut jobs = vec![JobSpec::synthetic(JobId(0), 1)];
        jobs.push(JobSpec::synthetic(JobId(1), c.num_gpus() + 1)); // never placeable
        let out = OnlineScheduler::new(&c, &jobs, &p).run(&mut Fifo);
        assert!(out.outcome.truncated);
    }

    #[test]
    fn waits_are_zero_on_an_empty_cluster_with_sparse_arrivals() {
        let (c, p) = setup();
        // one tiny job every 10_000 slots: each runs alone, zero wait
        let mut jobs = TraceGenerator::tiny().generate(1);
        for (i, j) in jobs.iter_mut().enumerate() {
            j.arrival = (i as u64) * 10_000;
        }
        let out = OnlineScheduler::new(&c, &jobs, &p)
            .with_options(OnlineOptions { max_slots: 10_000_000, ..OnlineOptions::default() })
            .run(&mut Fifo);
        assert!(!out.outcome.truncated);
        for r in &out.outcome.records {
            assert_eq!(r.start, r.arrival, "{} queued on an empty cluster", r.job);
        }
    }

    #[test]
    fn queue_cap_rejects_overflow_arrivals() {
        // 1 server x 2 GPUs, 2-GPU jobs: strictly one at a time. Six jobs
        // at t = 0 with a queue cap of 2: arrivals are all revealed
        // before any dispatch, so two enter the queue and four are
        // rejected on arrival.
        let c = Cluster::uniform(1, 2, 1.0, 25.0);
        let p = ContentionParams::paper();
        let jobs: Vec<JobSpec> = (0..6)
            .map(|i| {
                let mut j = JobSpec::synthetic(JobId(i), 2);
                j.iterations = 100;
                j
            })
            .collect();
        let opts = OnlineOptions {
            admission: AdmissionControl { theta: f64::INFINITY, queue_cap: 2 },
            ..OnlineOptions::default()
        };
        let out = OnlineScheduler::new(&c, &jobs, &p).with_options(opts).run(&mut Fifo);
        assert!(!out.outcome.truncated);
        assert_eq!(out.rejected.len(), 4, "cap 2 admits exactly 2 of 6 batch arrivals");
        assert_eq!(out.outcome.records.len(), 2, "rejected jobs have no records");
        assert!(out.max_pending <= 2, "queue never exceeds the cap");
        assert_eq!(out.events.count(EventKind::Rejected), 4);
        assert_eq!(out.events.count(EventKind::Arrival), 6, "every arrival is logged");
        assert!(out.events.is_causally_ordered());
        assert!((out.rejection_rate(jobs.len()) - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn theta_zero_rejects_every_spread_arrival() {
        // 2 servers x 1 GPU: any 2-GPU gang must spread, so its projected
        // bottleneck effective degree is >= 1 > θ = 0.5 → rejected. A
        // 1-GPU job projects co-located (degree 0) and is admitted.
        let c = Cluster::uniform(2, 1, 1.0, 25.0);
        let p = ContentionParams::paper();
        let mut spread = JobSpec::synthetic(JobId(0), 2);
        spread.iterations = 50;
        let mut solo = JobSpec::synthetic(JobId(1), 1);
        solo.iterations = 50;
        let jobs = vec![spread, solo];
        let opts = OnlineOptions {
            admission: AdmissionControl { theta: 0.5, queue_cap: usize::MAX },
            ..OnlineOptions::default()
        };
        let out = OnlineScheduler::new(&c, &jobs, &p).with_options(opts).run(&mut Fifo);
        assert!(!out.outcome.truncated);
        assert_eq!(out.rejected, vec![JobId(0)]);
        assert_eq!(out.outcome.records.len(), 1);
        assert_eq!(out.outcome.records[0].job, JobId(1));
    }

    #[test]
    fn oversized_job_is_rejected_under_admission_not_stuck() {
        // the control-free loop truncates on a never-placeable job (see
        // oversized_job_truncates_instead_of_hanging); with EITHER guard
        // armed — θ or the queue cap alone — admission turns it away
        // cleanly instead of letting it wedge the queue.
        let (c, p) = setup();
        let mut jobs = vec![JobSpec::synthetic(JobId(0), 1)];
        jobs.push(JobSpec::synthetic(JobId(1), c.num_gpus() + 1));
        for admission in [
            AdmissionControl { theta: 1e9, queue_cap: usize::MAX },
            AdmissionControl { theta: f64::INFINITY, queue_cap: 8 }, // cap-only
        ] {
            let opts = OnlineOptions { admission, ..OnlineOptions::default() };
            let out =
                OnlineScheduler::new(&c, &jobs, &p).with_options(opts).run(&mut Fifo);
            assert!(!out.outcome.truncated, "rejection unblocks the stream");
            assert_eq!(out.rejected, vec![JobId(1)]);
            assert_eq!(out.outcome.records.len(), 1);
        }
    }

    #[test]
    fn migration_colocates_a_spread_ring_when_capacity_frees() {
        // 2 servers x 4 GPUs, starved inter-server link so spread rings
        // crawl. FIFO packs jA (3 GPUs, ~29 slots co-located) onto
        // s0g0-2; jB (2 GPUs) is forced to spread over s0g3 + s1g0 and
        // crawls at the starved uplink (~1000 slots). When jA completes,
        // the never-used s1g1/s1g2 are the least-busy free pair, so the
        // migration candidate co-locates jB on server 1: bottleneck
        // 1 → 0, and the rate jump dwarfs the restart cost. The move must
        // fire, strictly improve, and beat the migration-off makespan.
        let c = Cluster::uniform(2, 4, 0.05, 25.0);
        let p = ContentionParams::paper();
        let mk = |id: usize, gpus: usize, iters: u64| {
            let mut j = JobSpec::synthetic(JobId(id), gpus);
            j.iterations = iters;
            j
        };
        let jobs = vec![mk(0, 3, 4000), mk(1, 2, 4000)];
        let base = OnlineOptions { max_slots: 10_000_000, ..OnlineOptions::default() };
        let off = OnlineScheduler::new(&c, &jobs, &p).with_options(base).run(&mut Fifo);
        let on_opts = OnlineOptions {
            migration: MigrationControl { enabled: true, max_moves: 2, restart_slots: 5 },
            ..base
        };
        let on = OnlineScheduler::new(&c, &jobs, &p).with_options(on_opts).run(&mut Fifo);
        assert!(!off.outcome.truncated && !on.outcome.truncated);
        assert!(!on.migrations.is_empty(), "freed server must trigger the move");
        for m in &on.migrations {
            assert!(
                m.to_effective < m.from_effective,
                "{}: bottleneck must strictly improve ({} -> {})",
                m.job,
                m.from_effective,
                m.to_effective
            );
        }
        assert_eq!(out_migrations_total(&on), on.migrations.len());
        assert!(
            on.outcome.makespan < off.outcome.makespan,
            "migration-on {} vs off {}",
            on.outcome.makespan,
            off.outcome.makespan
        );
        assert!(on.events.is_causally_ordered());
        assert_eq!(on.events.count(EventKind::Migrated), on.migrations.len());
    }

    fn out_migrations_total(o: &OnlineOutcome) -> usize {
        o.outcome.records.iter().map(|r| r.migrations).sum()
    }

    #[test]
    fn window_series_conserves_busy_time_and_leaves_the_run_untouched() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(7, 4.0);
        let plain = OnlineScheduler::new(&c, &jobs, &p).run(&mut Fifo);
        let w = 50u64;
        let opts = OnlineOptions { window: Some(w), ..OnlineOptions::default() };
        let windowed = OnlineScheduler::new(&c, &jobs, &p).with_options(opts).run(&mut Fifo);
        // the accounting is passive: the schedule is bit-identical
        assert_eq!(plain.outcome.makespan, windowed.outcome.makespan);
        assert_eq!(plain.outcome.avg_jct, windowed.outcome.avg_jct);
        assert_eq!(plain.outcome.records.len(), windowed.outcome.records.len());
        assert!(plain.windows.is_empty(), "no series without the flag");
        assert!(!windowed.windows.is_empty());
        // windows tile the run: start = index x w, coverage up to the end
        for (i, s) in windowed.windows.iter().enumerate() {
            assert_eq!(s.start, i as u64 * w);
            let util = s.utilization(c.num_gpus(), w);
            assert!((0.0..=1.0 + 1e-9).contains(&util), "window {i}: util {util}");
            assert!(s.queue_area >= 0.0 && s.max_queue >= (s.queue_area > 0.0) as usize);
        }
        // exact conservation: window busy sums to the per-record total
        let total: f64 = windowed.windows.iter().map(|s| s.busy_gpu_slots).sum();
        let expect: f64 = windowed
            .outcome
            .records
            .iter()
            .map(|r| (r.finish - r.start) as f64 * r.workers as f64)
            .sum();
        assert!(
            (total - expect).abs() < 1e-6,
            "window busy {total} != record busy {expect}"
        );
        // the mean-queue accessor is the area over the length
        let s0 = windowed.windows[0];
        assert!((s0.mean_queue(w) - s0.queue_area / w as f64).abs() < 1e-12);
    }

    #[test]
    fn sjf_beats_or_matches_fifo_on_avg_jct_for_batch_mix() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(11, 2.0);
        let sjf = OnlineScheduler::new(&c, &jobs, &p).run(&mut OnlineSjfBco::default());
        let fifo = OnlineScheduler::new(&c, &jobs, &p).run(&mut Fifo);
        assert!(!sjf.outcome.truncated && !fifo.outcome.truncated);
        // SJF is the mean-JCT heuristic; allow a small tolerance since the
        // tiny trace is nearly contention-free.
        assert!(
            sjf.outcome.avg_jct <= fifo.outcome.avg_jct * 1.25 + 1.0,
            "SJF {} vs FIFO {}",
            sjf.outcome.avg_jct,
            fifo.outcome.avg_jct
        );
    }

    #[test]
    fn run_equals_run_with_collect_sink() {
        // run() is documented as run_with_sink(CollectSink) + assembly;
        // hold it to that on a contended trace with both controls armed.
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(13, 3.0);
        let opts = OnlineOptions {
            admission: AdmissionControl { theta: 6.0, queue_cap: 32 },
            migration: MigrationControl { enabled: true, max_moves: 1, restart_slots: 3 },
            window: Some(64),
            ..OnlineOptions::default()
        };
        let sched = OnlineScheduler::new(&c, &jobs, &p).with_options(opts);
        let out = sched.run(&mut Fifo);
        let mut order: Vec<&JobSpec> = jobs.iter().collect();
        order.sort_by_key(|j| (j.arrival, j.id));
        let mut sink = CollectSink::default();
        let stats = sched.run_with_sink(order.into_iter(), &mut Fifo, &mut sink);
        assert_eq!(sink.events.events(), out.events.events());
        assert_eq!(sink.rejected, out.rejected);
        assert_eq!(sink.migrations, out.migrations);
        assert_eq!(stats.max_finish, out.outcome.makespan);
        assert_eq!(stats.avg_jct(), out.outcome.avg_jct);
        assert_eq!(stats.slots_simulated, out.outcome.slots_simulated);
        assert_eq!(stats.periods, out.outcome.periods);
        assert_eq!(stats.max_pending, out.max_pending);
        assert_eq!(sink.windows, out.windows);
        let mut recs = sink.records;
        recs.sort_by_key(|r| r.job);
        assert_eq!(recs.len(), out.outcome.records.len());
        for (a, b) in recs.iter().zip(&out.outcome.records) {
            assert_eq!(
                (a.job, a.start, a.finish, a.migrations),
                (b.job, b.start, b.finish, b.migrations)
            );
        }
    }

    #[test]
    fn streaming_matches_materialized_aggregates() {
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(17, 5.0);
        let sched = OnlineScheduler::new(&c, &jobs, &p);
        let out = sched.run(&mut Fifo);
        // the generator output is already (arrival, id)-sorted
        let stream = sched.run_streaming(jobs.iter(), &mut Fifo);
        assert_eq!(stream.policy, out.policy);
        assert_eq!(stream.makespan, out.outcome.makespan);
        assert_eq!(stream.avg_jct, out.outcome.avg_jct, "integer sums: exact equality");
        assert_eq!(stream.gpu_utilization, out.outcome.gpu_utilization);
        assert_eq!(stream.finished as usize, out.outcome.records.len());
        assert_eq!(stream.periods, out.outcome.periods);
        assert_eq!(stream.slots_simulated, out.outcome.slots_simulated);
        assert_eq!(stream.truncated, out.outcome.truncated);
        assert_eq!(stream.max_pending, out.max_pending);
        assert!((stream.avg_wait - out.outcome.avg_wait()).abs() < 1e-9);
        assert_eq!(
            stream.event_count(EventKind::Arrival) as usize,
            out.events.count(EventKind::Arrival)
        );
        assert_eq!(
            stream.event_count(EventKind::Completion) as usize,
            out.events.count(EventKind::Completion)
        );
        assert_eq!(stream.rejected, 0);
        assert_eq!(stream.rejection_rate(jobs.len() as u64), 0.0);
        // sketch percentiles track the exact ones within the 1/32 bound
        let exact = out.outcome.jct_percentiles();
        for pp in [50.0, 95.0, 100.0] {
            let e = exact.percentile(pp);
            let s = stream.jct.percentile(pp);
            assert!(e <= s && s - e <= e / 32, "p{pp}: sketch {s} vs exact {e}");
        }
        // peak live bounds max_pending and never exceeds the trace
        assert!(stream.peak_live >= stream.max_pending);
        assert!(stream.peak_live <= jobs.len());
    }

    #[test]
    fn giant_trace_ids_cost_active_memory_only() {
        // Trace ids no longer size any dense table: ids near 2^40 would
        // have forced multi-terabyte running_idx/tracker allocations
        // before slot recycling. If this test runs at all, the invariant
        // holds — the tracker sees recycled slots, never the trace ids.
        // (EventLog::is_causally_ordered is itself O(max id), so this
        // test checks records, not the log audit.)
        let (c, p) = setup();
        let big = 1usize << 40;
        let mut jobs = vec![
            JobSpec::synthetic(JobId(big), 2),
            JobSpec::synthetic(JobId(big + 7), 2),
        ];
        for j in &mut jobs {
            j.iterations = 200;
        }
        let out = OnlineScheduler::new(&c, &jobs, &p).run(&mut Fifo);
        assert!(!out.outcome.truncated);
        assert_eq!(out.outcome.records.len(), 2);
        assert_eq!(out.outcome.records[0].job, JobId(big), "records keep trace ids");
        assert_eq!(out.outcome.records[1].job, JobId(big + 7));
    }

    #[test]
    fn open_scheduler_streams_a_lazy_trace() {
        // End-to-end: a lazy OpenArrivals stream through run_streaming on
        // a scheduler built without any materialized jobs.
        let (c, p) = setup();
        let gen = TraceGenerator::tiny();
        let opts = OnlineOptions { max_slots: 10_000_000, ..OnlineOptions::default() };
        let sched = OnlineScheduler::open(&c, &p).with_options(opts);
        let out = sched.run_streaming(
            gen.open_arrivals(11, 60, ArrivalProcess::poisson(8.0)),
            &mut Fifo,
        );
        assert!(!out.truncated);
        assert_eq!(out.finished, 60);
        assert_eq!(out.event_count(EventKind::Arrival), 60);
        assert_eq!(out.event_count(EventKind::Start), 60);
        assert_eq!(out.event_count(EventKind::Completion), 60);
        assert_eq!(out.jct.count(), 60);
        assert_eq!(out.wait.count(), 60);
        assert!(out.makespan > 0);
        assert!(out.peak_live >= 1 && out.peak_live <= 60);
        // the streaming run agrees with materializing the same stream
        let jobs: Vec<JobSpec> =
            gen.open_arrivals(11, 60, ArrivalProcess::poisson(8.0)).collect();
        let mat = OnlineScheduler::new(&c, &jobs, &p).with_options(opts).run(&mut Fifo);
        assert_eq!(out.makespan, mat.outcome.makespan);
        assert_eq!(out.avg_jct, mat.outcome.avg_jct);
        assert_eq!(out.periods, mat.outcome.periods);
    }

    use crate::faults::{FaultAction, FaultEvent, FaultTrace};

    fn hand_trace(events: Vec<FaultEvent>) -> FaultTrace {
        let mut tr = FaultTrace { seed: 0, description: "hand".into(), events };
        tr.normalize();
        tr
    }

    #[test]
    fn crash_kills_and_recovery_completes_the_job() {
        // 2 servers x 2 GPUs; one 2-GPU job started at t = 0 on server 0
        // (FIFO packs co-located). Server 0 crashes at t = 50 and comes
        // back at t = 200; migration is off, so the job waits for its
        // home gang, restarts with its checkpoint and still completes.
        let c = Cluster::uniform(2, 2, 1.0, 25.0);
        let p = ContentionParams::paper();
        let mut j = JobSpec::synthetic(JobId(0), 2);
        j.iterations = 400;
        let jobs = vec![j];
        let tr = hand_trace(vec![
            FaultEvent { at: 50, action: FaultAction::ServerCrash { server: 0 } },
            FaultEvent { at: 200, action: FaultAction::ServerRecover { server: 0 } },
        ]);
        let opts = OnlineOptions { max_slots: 10_000_000, ..OnlineOptions::default() };
        let out = OnlineScheduler::new(&c, &jobs, &p)
            .with_options(opts)
            .with_faults(&tr)
            .run(&mut Fifo);
        assert!(!out.outcome.truncated);
        assert_eq!(out.failed, 1);
        assert_eq!(out.recovered, 1);
        assert_eq!(out.recovery_wait_slots, 150, "killed at 50, re-placed at 200");
        assert_eq!(out.events.count(EventKind::Failed), 1);
        assert_eq!(out.events.count(EventKind::Recovered), 1);
        assert!(out.events.is_causally_ordered());
        let r = &out.outcome.records[0];
        assert_eq!(r.iterations_done, 400, "checkpointed progress survives the crash");
        assert!(r.finish > 400, "the outage stretches the JCT past the crash-free run");
        assert!(r.finish > 200, "the job cannot finish before its home gang heals");
    }

    #[test]
    fn empty_fault_trace_is_bit_identical_smoke() {
        // The full {fabric} x {policy} x {controls} matrix lives in
        // tests/fault_equivalence.rs; this is the in-module canary.
        let (c, p) = setup();
        let jobs = TraceGenerator::tiny().generate_online(19, 3.0);
        let tr = FaultTrace::empty();
        let plain = OnlineScheduler::new(&c, &jobs, &p).run(&mut Fifo);
        let armed =
            OnlineScheduler::new(&c, &jobs, &p).with_faults(&tr).run(&mut Fifo);
        assert_eq!(plain.outcome.makespan, armed.outcome.makespan);
        assert_eq!(plain.outcome.avg_jct, armed.outcome.avg_jct);
        assert_eq!(plain.events.events(), armed.events.events());
        assert_eq!(armed.failed, 0);
        assert_eq!(armed.recovered, 0);
    }

    #[test]
    fn window_capacity_normalizes_by_surviving_gpu_slots() {
        // Satellite: a full-cluster outage must not read as "0% utilized
        // headroom" — the window's capacity shrinks with the outage. One
        // server, one 1-GPU job; crash at 64 (kills the job), recover at
        // 192, the job re-places and finishes. The outage windows carry
        // zero capacity; windows outside it carry num_gpus x w.
        let c = Cluster::uniform(1, 1, 1.0, 25.0);
        let p = ContentionParams::paper();
        let mut j = JobSpec::synthetic(JobId(0), 1);
        j.iterations = 200;
        let jobs = vec![j];
        let tr = hand_trace(vec![
            FaultEvent { at: 64, action: FaultAction::ServerCrash { server: 0 } },
            FaultEvent { at: 192, action: FaultAction::ServerRecover { server: 0 } },
        ]);
        let w = 64u64;
        let opts = OnlineOptions {
            window: Some(w),
            max_slots: 10_000_000,
            ..OnlineOptions::default()
        };
        let out = OnlineScheduler::new(&c, &jobs, &p)
            .with_options(opts)
            .with_faults(&tr)
            .run(&mut Fifo);
        assert!(!out.outcome.truncated);
        assert_eq!(out.failed, 1);
        assert_eq!(out.recovered, 1);
        // windows [64, 128) and [128, 192) span the outage: no capacity
        assert_eq!(out.windows[1].capacity_gpu_slots, 0.0);
        assert_eq!(out.windows[2].capacity_gpu_slots, 0.0);
        assert_eq!(out.windows[1].utilization(c.num_gpus(), w), 0.0, "no capacity, no util");
        // the first window is fully healthy and fully busy
        assert_eq!(out.windows[0].capacity_gpu_slots, w as f64);
        assert!((out.windows[0].utilization(c.num_gpus(), w) - 1.0).abs() < 1e-12);
        // conservation still holds: window busy sums to record busy,
        // with the outage contributing zero busy slots
        let total: f64 = out.windows.iter().map(|s| s.busy_gpu_slots).sum();
        let expect: f64 = out
            .outcome
            .records
            .iter()
            .map(|r| (r.finish - r.start) as f64 * r.workers as f64)
            .sum();
        // the killed span [0, 64) was busy but the job's record restarts
        // at its original start — busy time is conserved against the
        // *held-GPU* spans: [0,64) + [200?, finish). Account directly:
        assert!(total <= expect + 1e-6, "windows never invent busy time");
    }

    #[test]
    fn gpu_failure_retroactively_rejects_a_doomed_queued_job() {
        // Satellite: 1 server x 2 GPUs, queue-cap admission armed. Job 0
        // (1 GPU, long) runs; job 1 needs 2 GPUs and queues. A permanent
        // GPU failure on the free GPU drops the potential pool to 1, so
        // job 1 can never run again: it must be retroactively rejected,
        // not wedge the run into truncation.
        let c = Cluster::uniform(1, 2, 1.0, 25.0);
        let p = ContentionParams::paper();
        let mk = |id: usize, gpus: usize, iters: u64| {
            let mut j = JobSpec::synthetic(JobId(id), gpus);
            j.iterations = iters;
            j
        };
        let jobs = vec![mk(0, 1, 500), mk(1, 2, 100)];
        let tr = hand_trace(vec![FaultEvent {
            at: 10,
            action: FaultAction::GpuFail { server: 0, gpu: 1 },
        }]);
        let opts = OnlineOptions {
            admission: AdmissionControl { theta: f64::INFINITY, queue_cap: 64 },
            max_slots: 10_000_000,
            ..OnlineOptions::default()
        };
        let out = OnlineScheduler::new(&c, &jobs, &p)
            .with_options(opts)
            .with_faults(&tr)
            .run(&mut Fifo);
        assert!(!out.outcome.truncated, "the doomed job is rejected, not stuck");
        assert_eq!(out.rejected, vec![JobId(1)]);
        assert_eq!(out.failed, 0, "the failed GPU was free: no gang was killed");
        assert_eq!(out.outcome.records.len(), 1);
        assert_eq!(out.outcome.records[0].job, JobId(0));
        assert!(out.events.is_causally_ordered());
    }
}
