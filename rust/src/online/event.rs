//! Scheduling events and the per-run event log.
//!
//! The online loop is driven entirely by events: a job **arrival** puts a
//! job in the pending queue, a **start** moves it onto its gang of GPUs,
//! a **completion** frees them. The log records the realized sequence so
//! tests and tooling can audit causality (a job never starts before it
//! arrives, never completes before it starts) without re-simulating.

use crate::jobs::JobId;

/// Sentinel "job" id carried by fabric-level events ([`EventKind::Degraded`])
/// that have no job lifecycle: the causality audit skips it, per-job
/// queries never match it (real slot-recycled ids are dense and small).
pub const LINK_EVENT_JOB: JobId = JobId(usize::MAX);

/// What happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// The job entered the pending queue.
    Arrival,
    /// The scheduler placed the job's gang on GPUs.
    Start,
    /// The job finished its `F_j` iterations and released its gang.
    Completion,
    /// Admission control turned the arrival away (θ-threshold exceeded or
    /// the pending-queue cap was hit): the job never queues, never runs.
    /// Also terminal for a queued or recovering job that a permanent
    /// capacity loss made unplaceable (retroactive re-projection).
    Rejected,
    /// A completion freed capacity that strictly lowers this running
    /// job's bottleneck: it was preempted and re-placed (checkpoint
    /// restart charged in slots). May repeat; always between Start and
    /// Completion.
    Migrated,
    /// A fault killed the job's gang (server crash or GPU failure): the
    /// job keeps its checkpointed progress and enters the recovery queue.
    Failed,
    /// A failed job was re-placed on surviving GPUs (restart charged in
    /// slots, like a migration); it is running again.
    Recovered,
    /// A fabric link's capacity changed (degrade or restore). Carries the
    /// [`LINK_EVENT_JOB`] sentinel — no job lifecycle is involved.
    Degraded,
}

impl EventKind {
    /// Number of variants (dense-array sizing for per-kind counters).
    pub const COUNT: usize = 8;

    /// Dense index of the variant (`0..COUNT`), for allocation-free
    /// per-kind counting in streaming sinks.
    pub fn index(self) -> usize {
        match self {
            EventKind::Arrival => 0,
            EventKind::Start => 1,
            EventKind::Completion => 2,
            EventKind::Rejected => 3,
            EventKind::Migrated => 4,
            EventKind::Failed => 5,
            EventKind::Recovered => 6,
            EventKind::Degraded => 7,
        }
    }
}

/// One timestamped event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OnlineEvent {
    /// Slot at which the event took effect.
    pub at: u64,
    pub job: JobId,
    pub kind: EventKind,
}

/// Chronological record of one online run.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    events: Vec<OnlineEvent>,
}

impl EventLog {
    pub fn push(&mut self, at: u64, job: JobId, kind: EventKind) {
        self.events.push(OnlineEvent { at, job, kind });
    }

    pub fn events(&self) -> &[OnlineEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of events of one kind.
    pub fn count(&self, kind: EventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// All events of one job, in log order.
    pub fn for_job(&self, job: JobId) -> impl Iterator<Item = &OnlineEvent> {
        self.events.iter().filter(move |e| e.job == job)
    }

    /// Causality audit: the log is globally time-ordered, and every job's
    /// own events follow the lifecycle state machine with non-decreasing
    /// timestamps (a prefix is fine — truncated runs):
    ///
    /// ```text
    /// Arrival ──▶ Start ──▶ (Migrated)* ──▶ Completion   (terminal)
    ///    │           ▲  │
    ///    │           │  └──▶ Failed ──▶ Recovered ──▶ (running again)
    ///    │           └─────────┘           │
    ///    │                                 └──▶ Rejected (terminal:
    ///    └──────▶ Rejected (terminal)           unplaceable survivor)
    /// ```
    ///
    /// [`Degraded`](EventKind::Degraded) events are fabric-level: they
    /// must carry the [`LINK_EVENT_JOB`] sentinel (and only they may) and
    /// are excluded from the per-job lifecycle.
    pub fn is_causally_ordered(&self) -> bool {
        // archlint: allow(release-panic) windows(2) yields exactly-2 slices
        if self.events.windows(2).any(|w| w[0].at > w[1].at) {
            return false;
        }
        let max_id = self
            .events
            .iter()
            .filter(|e| e.job != LINK_EVENT_JOB)
            .map(|e| e.job.0)
            .max()
            .map_or(0, |m| m + 1);
        // per-job (lifecycle stage, last event slot); stages:
        // 0 = unseen, 1 = arrived, 2 = running, 3 = terminal,
        // 4 = failed/awaiting recovery
        let mut stage: Vec<(u8, u64)> = vec![(0, 0); max_id];
        for e in &self.events {
            if e.job == LINK_EVENT_JOB {
                // fabric event: valid only for the Degraded kind
                if e.kind != EventKind::Degraded {
                    return false;
                }
                continue;
            }
            if e.kind == EventKind::Degraded {
                // a link event must never carry a real job id
                return false;
            }
            let (at_stage, last_at) = stage[e.job.0];
            if e.at < last_at {
                return false;
            }
            let next = match (at_stage, e.kind) {
                (0, EventKind::Arrival) => 1,
                (1, EventKind::Start) => 2,
                (1, EventKind::Rejected) => 3,
                (2, EventKind::Migrated) => 2,
                (2, EventKind::Completion) => 3,
                (2, EventKind::Failed) => 4,
                (4, EventKind::Recovered) => 2,
                (4, EventKind::Rejected) => 3,
                _ => return false,
            };
            stage[e.job.0] = (next, e.at);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_ordering() {
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(3, JobId(1), EventKind::Arrival);
        log.push(5, JobId(0), EventKind::Completion);
        log.push(5, JobId(1), EventKind::Start);
        assert_eq!(log.len(), 5);
        assert_eq!(log.count(EventKind::Arrival), 2);
        assert_eq!(log.count(EventKind::Completion), 1);
        assert_eq!(log.for_job(JobId(0)).count(), 3);
        assert!(log.is_causally_ordered());
    }

    #[test]
    fn start_before_arrival_is_flagged() {
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Start);
        assert!(!log.is_causally_ordered());
    }

    #[test]
    fn time_regression_is_flagged() {
        let mut log = EventLog::default();
        log.push(5, JobId(0), EventKind::Arrival);
        log.push(3, JobId(0), EventKind::Start);
        assert!(!log.is_causally_ordered());
    }

    #[test]
    fn rejection_is_terminal_after_arrival() {
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Rejected);
        assert!(log.is_causally_ordered());
        assert_eq!(log.count(EventKind::Rejected), 1);
        // a rejected job can never start
        log.push(2, JobId(0), EventKind::Start);
        assert!(!log.is_causally_ordered());
        // nor be rejected before it arrives
        let mut bad = EventLog::default();
        bad.push(0, JobId(1), EventKind::Rejected);
        assert!(!bad.is_causally_ordered());
    }

    #[test]
    fn empty_log_is_causally_ordered() {
        let log = EventLog::default();
        assert!(log.is_causally_ordered());
        assert!(log.is_empty());
        assert_eq!(log.len(), 0);
        assert_eq!(log.count(EventKind::Arrival), 0);
        assert_eq!(log.for_job(JobId(0)).count(), 0);
    }

    const ALL_KINDS: [EventKind; EventKind::COUNT] = [
        EventKind::Arrival,
        EventKind::Start,
        EventKind::Completion,
        EventKind::Rejected,
        EventKind::Migrated,
        EventKind::Failed,
        EventKind::Recovered,
        EventKind::Degraded,
    ];

    #[test]
    fn kind_indices_are_dense() {
        let mut seen = [false; EventKind::COUNT];
        for kind in ALL_KINDS {
            let i = kind.index();
            assert!(i < EventKind::COUNT);
            assert!(!seen[i], "duplicate index for {kind:?}");
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "every index 0..COUNT is hit");
    }

    #[test]
    fn rejected_is_terminal_against_every_kind() {
        // Rejected-then-anything is flagged: rejection ends the lifecycle
        for kind in ALL_KINDS {
            let mut log = EventLog::default();
            log.push(0, JobId(0), EventKind::Arrival);
            log.push(0, JobId(0), EventKind::Rejected);
            assert!(log.is_causally_ordered());
            log.push(1, JobId(0), kind);
            assert!(!log.is_causally_ordered(), "Rejected then {kind:?} must be flagged");
        }
    }

    #[test]
    fn terminal_state_matrix_for_completion_and_rejection() {
        // nothing may follow either terminal stage, whatever the kind —
        // including the new fault-lifecycle kinds
        for terminal in [EventKind::Completion, EventKind::Rejected] {
            for kind in ALL_KINDS {
                let mut log = EventLog::default();
                log.push(0, JobId(0), EventKind::Arrival);
                if terminal == EventKind::Completion {
                    log.push(0, JobId(0), EventKind::Start);
                }
                log.push(2, JobId(0), terminal);
                assert!(log.is_causally_ordered());
                log.push(3, JobId(0), kind);
                assert!(
                    !log.is_causally_ordered(),
                    "{terminal:?} then {kind:?} must be flagged"
                );
            }
        }
    }

    #[test]
    fn failed_recovered_lifecycle_is_accepted() {
        // crash mid-run, wait, recover, run to completion — twice
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(5, JobId(0), EventKind::Failed);
        log.push(9, JobId(0), EventKind::Recovered);
        log.push(12, JobId(0), EventKind::Failed);
        log.push(13, JobId(0), EventKind::Recovered);
        log.push(30, JobId(0), EventKind::Completion);
        assert!(log.is_causally_ordered());
        assert_eq!(log.count(EventKind::Failed), 2);
        assert_eq!(log.count(EventKind::Recovered), 2);
        // a recovery abandoned as unplaceable ends in Rejected
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(5, JobId(0), EventKind::Failed);
        log.push(5, JobId(0), EventKind::Rejected);
        assert!(log.is_causally_ordered());
    }

    #[test]
    fn recovered_before_failed_is_flagged() {
        // Recovered without a preceding Failed is invalid from every
        // non-failed stage
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(1, JobId(0), EventKind::Recovered);
        assert!(!log.is_causally_ordered(), "queued job cannot recover");
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(3, JobId(0), EventKind::Recovered);
        assert!(!log.is_causally_ordered(), "running job cannot recover");
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Recovered);
        assert!(!log.is_causally_ordered(), "unseen job cannot recover");
        // a queued (never started) job cannot fail either: it holds no gang
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(2, JobId(0), EventKind::Failed);
        assert!(!log.is_causally_ordered(), "queued job cannot fail");
        // and a failed job must recover before completing or migrating
        for kind in [EventKind::Completion, EventKind::Migrated, EventKind::Failed] {
            let mut log = EventLog::default();
            log.push(0, JobId(0), EventKind::Arrival);
            log.push(0, JobId(0), EventKind::Start);
            log.push(4, JobId(0), EventKind::Failed);
            log.push(6, JobId(0), kind);
            assert!(!log.is_causally_ordered(), "Failed then {kind:?} must be flagged");
        }
    }

    #[test]
    fn crash_during_migration_interleaving() {
        // job 0 migrates (frozen restart window), the target's server
        // crashes mid-restart, the job recovers elsewhere and completes;
        // job 1 rides through untouched — the audit accepts the
        // interleaving and each per-job slice stays lifecycle-clean
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(1, JobId(1), EventKind::Arrival);
        log.push(1, JobId(1), EventKind::Start);
        log.push(4, JobId(0), EventKind::Migrated);
        log.push(6, JobId(0), EventKind::Failed); // crash lands mid-restart
        log.push(6, LINK_EVENT_JOB, EventKind::Degraded);
        log.push(8, JobId(0), EventKind::Recovered);
        log.push(11, JobId(1), EventKind::Completion);
        log.push(15, JobId(0), EventKind::Migrated); // free to migrate again
        log.push(25, JobId(0), EventKind::Completion);
        assert!(log.is_causally_ordered());
        let job0: Vec<EventKind> = log.for_job(JobId(0)).map(|e| e.kind).collect();
        assert_eq!(
            job0,
            [
                EventKind::Arrival,
                EventKind::Start,
                EventKind::Migrated,
                EventKind::Failed,
                EventKind::Recovered,
                EventKind::Migrated,
                EventKind::Completion
            ]
        );
        // Recovered must not precede the Failed in the interleaving: swap
        // the two and the audit flags it
        let mut bad = EventLog::default();
        bad.push(0, JobId(0), EventKind::Arrival);
        bad.push(0, JobId(0), EventKind::Start);
        bad.push(4, JobId(0), EventKind::Recovered);
        bad.push(6, JobId(0), EventKind::Failed);
        assert!(!bad.is_causally_ordered());
    }

    #[test]
    fn degraded_events_are_fabric_level_only() {
        // sentinel-carried Degraded events thread through any lifecycle
        let mut log = EventLog::default();
        log.push(0, LINK_EVENT_JOB, EventKind::Degraded);
        log.push(1, JobId(0), EventKind::Arrival);
        log.push(1, JobId(0), EventKind::Start);
        log.push(3, LINK_EVENT_JOB, EventKind::Degraded); // restore instant
        log.push(7, JobId(0), EventKind::Completion);
        assert!(log.is_causally_ordered());
        assert_eq!(log.count(EventKind::Degraded), 2);
        // the sentinel never collides with a real job's slice
        assert_eq!(log.for_job(JobId(0)).count(), 3);
        // a Degraded event with a real job id is malformed
        let mut bad = EventLog::default();
        bad.push(0, JobId(0), EventKind::Arrival);
        bad.push(1, JobId(0), EventKind::Degraded);
        assert!(!bad.is_causally_ordered());
        // and the sentinel may not carry lifecycle kinds
        let mut bad = EventLog::default();
        bad.push(0, LINK_EVENT_JOB, EventKind::Arrival);
        assert!(!bad.is_causally_ordered());
        // a giant sentinel id must not blow up the stage vector (O(jobs),
        // not O(usize::MAX))
        let lone = {
            let mut log = EventLog::default();
            log.push(0, LINK_EVENT_JOB, EventKind::Degraded);
            log
        };
        assert!(lone.is_causally_ordered());
    }

    #[test]
    fn migrated_before_placement_is_flagged() {
        // queued (arrived) but never placed: Migrated is invalid
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(2, JobId(0), EventKind::Migrated);
        assert!(!log.is_causally_ordered());
        // an unseen job can't migrate either
        let mut log = EventLog::default();
        log.push(0, JobId(3), EventKind::Migrated);
        assert!(!log.is_causally_ordered());
    }

    #[test]
    fn count_and_for_job_on_multi_job_interleavings() {
        // three jobs interleaved: 0 runs-migrates-completes, 1 is
        // rejected, 2 arrives late and completes after 0
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(1, JobId(1), EventKind::Arrival);
        log.push(1, JobId(1), EventKind::Rejected);
        log.push(2, JobId(2), EventKind::Arrival);
        log.push(2, JobId(2), EventKind::Start);
        log.push(4, JobId(0), EventKind::Migrated);
        log.push(7, JobId(0), EventKind::Completion);
        log.push(9, JobId(2), EventKind::Completion);
        assert!(log.is_causally_ordered());
        assert_eq!(log.len(), 9);
        assert_eq!(log.count(EventKind::Arrival), 3);
        assert_eq!(log.count(EventKind::Start), 2);
        assert_eq!(log.count(EventKind::Completion), 2);
        assert_eq!(log.count(EventKind::Rejected), 1);
        assert_eq!(log.count(EventKind::Migrated), 1);
        // for_job slices one lifecycle out of the interleaving, in order
        let job0: Vec<EventKind> = log.for_job(JobId(0)).map(|e| e.kind).collect();
        assert_eq!(
            job0,
            [EventKind::Arrival, EventKind::Start, EventKind::Migrated, EventKind::Completion]
        );
        let job1: Vec<EventKind> = log.for_job(JobId(1)).map(|e| e.kind).collect();
        assert_eq!(job1, [EventKind::Arrival, EventKind::Rejected]);
        let job2: Vec<(u64, EventKind)> =
            log.for_job(JobId(2)).map(|e| (e.at, e.kind)).collect();
        assert_eq!(
            job2,
            [(2, EventKind::Arrival), (2, EventKind::Start), (9, EventKind::Completion)]
        );
        // unknown job: empty slice, not a panic
        assert_eq!(log.for_job(JobId(42)).count(), 0);
    }

    #[test]
    fn migrations_repeat_between_start_and_completion() {
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(4, JobId(0), EventKind::Migrated);
        log.push(9, JobId(0), EventKind::Migrated);
        log.push(20, JobId(0), EventKind::Completion);
        assert!(log.is_causally_ordered());
        assert_eq!(log.count(EventKind::Migrated), 2);
        // migrating a job that never started is flagged
        let mut bad = EventLog::default();
        bad.push(0, JobId(0), EventKind::Arrival);
        bad.push(1, JobId(0), EventKind::Migrated);
        assert!(!bad.is_causally_ordered());
        // and nothing may follow a completion
        let mut bad = EventLog::default();
        bad.push(0, JobId(0), EventKind::Arrival);
        bad.push(0, JobId(0), EventKind::Start);
        bad.push(5, JobId(0), EventKind::Completion);
        bad.push(6, JobId(0), EventKind::Migrated);
        assert!(!bad.is_causally_ordered());
    }
}
