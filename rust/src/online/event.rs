//! Scheduling events and the per-run event log.
//!
//! The online loop is driven entirely by events: a job **arrival** puts a
//! job in the pending queue, a **start** moves it onto its gang of GPUs,
//! a **completion** frees them. The log records the realized sequence so
//! tests and tooling can audit causality (a job never starts before it
//! arrives, never completes before it starts) without re-simulating.

use crate::jobs::JobId;

/// What happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// The job entered the pending queue.
    Arrival,
    /// The scheduler placed the job's gang on GPUs.
    Start,
    /// The job finished its `F_j` iterations and released its gang.
    Completion,
    /// Admission control turned the arrival away (θ-threshold exceeded or
    /// the pending-queue cap was hit): the job never queues, never runs.
    Rejected,
    /// A completion freed capacity that strictly lowers this running
    /// job's bottleneck: it was preempted and re-placed (checkpoint
    /// restart charged in slots). May repeat; always between Start and
    /// Completion.
    Migrated,
}

impl EventKind {
    /// Number of variants (dense-array sizing for per-kind counters).
    pub const COUNT: usize = 5;

    /// Dense index of the variant (`0..COUNT`), for allocation-free
    /// per-kind counting in streaming sinks.
    pub fn index(self) -> usize {
        match self {
            EventKind::Arrival => 0,
            EventKind::Start => 1,
            EventKind::Completion => 2,
            EventKind::Rejected => 3,
            EventKind::Migrated => 4,
        }
    }
}

/// One timestamped event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OnlineEvent {
    /// Slot at which the event took effect.
    pub at: u64,
    pub job: JobId,
    pub kind: EventKind,
}

/// Chronological record of one online run.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    events: Vec<OnlineEvent>,
}

impl EventLog {
    pub fn push(&mut self, at: u64, job: JobId, kind: EventKind) {
        self.events.push(OnlineEvent { at, job, kind });
    }

    pub fn events(&self) -> &[OnlineEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of events of one kind.
    pub fn count(&self, kind: EventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// All events of one job, in log order.
    pub fn for_job(&self, job: JobId) -> impl Iterator<Item = &OnlineEvent> {
        self.events.iter().filter(move |e| e.job == job)
    }

    /// Causality audit: the log is globally time-ordered, and every job's
    /// own events follow the lifecycle state machine with non-decreasing
    /// timestamps (a prefix is fine — truncated runs):
    ///
    /// ```text
    /// Arrival ──▶ Start ──▶ (Migrated)* ──▶ Completion
    ///    └──────▶ Rejected                      (both terminal)
    /// ```
    pub fn is_causally_ordered(&self) -> bool {
        // archlint: allow(release-panic) windows(2) yields exactly-2 slices
        if self.events.windows(2).any(|w| w[0].at > w[1].at) {
            return false;
        }
        let max_id = self.events.iter().map(|e| e.job.0).max().map_or(0, |m| m + 1);
        // per-job (lifecycle stage, last event slot); stages:
        // 0 = unseen, 1 = arrived, 2 = running, 3 = terminal
        let mut stage: Vec<(u8, u64)> = vec![(0, 0); max_id];
        for e in &self.events {
            let (at_stage, last_at) = stage[e.job.0];
            if e.at < last_at {
                return false;
            }
            let next = match (at_stage, e.kind) {
                (0, EventKind::Arrival) => 1,
                (1, EventKind::Start) => 2,
                (1, EventKind::Rejected) => 3,
                (2, EventKind::Migrated) => 2,
                (2, EventKind::Completion) => 3,
                _ => return false,
            };
            stage[e.job.0] = (next, e.at);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_ordering() {
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(3, JobId(1), EventKind::Arrival);
        log.push(5, JobId(0), EventKind::Completion);
        log.push(5, JobId(1), EventKind::Start);
        assert_eq!(log.len(), 5);
        assert_eq!(log.count(EventKind::Arrival), 2);
        assert_eq!(log.count(EventKind::Completion), 1);
        assert_eq!(log.for_job(JobId(0)).count(), 3);
        assert!(log.is_causally_ordered());
    }

    #[test]
    fn start_before_arrival_is_flagged() {
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Start);
        assert!(!log.is_causally_ordered());
    }

    #[test]
    fn time_regression_is_flagged() {
        let mut log = EventLog::default();
        log.push(5, JobId(0), EventKind::Arrival);
        log.push(3, JobId(0), EventKind::Start);
        assert!(!log.is_causally_ordered());
    }

    #[test]
    fn rejection_is_terminal_after_arrival() {
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Rejected);
        assert!(log.is_causally_ordered());
        assert_eq!(log.count(EventKind::Rejected), 1);
        // a rejected job can never start
        log.push(2, JobId(0), EventKind::Start);
        assert!(!log.is_causally_ordered());
        // nor be rejected before it arrives
        let mut bad = EventLog::default();
        bad.push(0, JobId(1), EventKind::Rejected);
        assert!(!bad.is_causally_ordered());
    }

    #[test]
    fn empty_log_is_causally_ordered() {
        let log = EventLog::default();
        assert!(log.is_causally_ordered());
        assert!(log.is_empty());
        assert_eq!(log.len(), 0);
        assert_eq!(log.count(EventKind::Arrival), 0);
        assert_eq!(log.for_job(JobId(0)).count(), 0);
    }

    #[test]
    fn rejected_is_terminal_against_every_kind() {
        // Rejected-then-anything is flagged: rejection ends the lifecycle
        for kind in [
            EventKind::Arrival,
            EventKind::Start,
            EventKind::Completion,
            EventKind::Rejected,
            EventKind::Migrated,
        ] {
            let mut log = EventLog::default();
            log.push(0, JobId(0), EventKind::Arrival);
            log.push(0, JobId(0), EventKind::Rejected);
            assert!(log.is_causally_ordered());
            log.push(1, JobId(0), kind);
            assert!(!log.is_causally_ordered(), "Rejected then {kind:?} must be flagged");
        }
    }

    #[test]
    fn migrated_before_placement_is_flagged() {
        // queued (arrived) but never placed: Migrated is invalid
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(2, JobId(0), EventKind::Migrated);
        assert!(!log.is_causally_ordered());
        // an unseen job can't migrate either
        let mut log = EventLog::default();
        log.push(0, JobId(3), EventKind::Migrated);
        assert!(!log.is_causally_ordered());
    }

    #[test]
    fn count_and_for_job_on_multi_job_interleavings() {
        // three jobs interleaved: 0 runs-migrates-completes, 1 is
        // rejected, 2 arrives late and completes after 0
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(1, JobId(1), EventKind::Arrival);
        log.push(1, JobId(1), EventKind::Rejected);
        log.push(2, JobId(2), EventKind::Arrival);
        log.push(2, JobId(2), EventKind::Start);
        log.push(4, JobId(0), EventKind::Migrated);
        log.push(7, JobId(0), EventKind::Completion);
        log.push(9, JobId(2), EventKind::Completion);
        assert!(log.is_causally_ordered());
        assert_eq!(log.len(), 9);
        assert_eq!(log.count(EventKind::Arrival), 3);
        assert_eq!(log.count(EventKind::Start), 2);
        assert_eq!(log.count(EventKind::Completion), 2);
        assert_eq!(log.count(EventKind::Rejected), 1);
        assert_eq!(log.count(EventKind::Migrated), 1);
        // for_job slices one lifecycle out of the interleaving, in order
        let job0: Vec<EventKind> = log.for_job(JobId(0)).map(|e| e.kind).collect();
        assert_eq!(
            job0,
            [EventKind::Arrival, EventKind::Start, EventKind::Migrated, EventKind::Completion]
        );
        let job1: Vec<EventKind> = log.for_job(JobId(1)).map(|e| e.kind).collect();
        assert_eq!(job1, [EventKind::Arrival, EventKind::Rejected]);
        let job2: Vec<(u64, EventKind)> =
            log.for_job(JobId(2)).map(|e| (e.at, e.kind)).collect();
        assert_eq!(
            job2,
            [(2, EventKind::Arrival), (2, EventKind::Start), (9, EventKind::Completion)]
        );
        // unknown job: empty slice, not a panic
        assert_eq!(log.for_job(JobId(42)).count(), 0);
    }

    #[test]
    fn migrations_repeat_between_start_and_completion() {
        let mut log = EventLog::default();
        log.push(0, JobId(0), EventKind::Arrival);
        log.push(0, JobId(0), EventKind::Start);
        log.push(4, JobId(0), EventKind::Migrated);
        log.push(9, JobId(0), EventKind::Migrated);
        log.push(20, JobId(0), EventKind::Completion);
        assert!(log.is_causally_ordered());
        assert_eq!(log.count(EventKind::Migrated), 2);
        // migrating a job that never started is flagged
        let mut bad = EventLog::default();
        bad.push(0, JobId(0), EventKind::Arrival);
        bad.push(1, JobId(0), EventKind::Migrated);
        assert!(!bad.is_causally_ordered());
        // and nothing may follow a completion
        let mut bad = EventLog::default();
        bad.push(0, JobId(0), EventKind::Arrival);
        bad.push(0, JobId(0), EventKind::Start);
        bad.push(5, JobId(0), EventKind::Completion);
        bad.push(6, JobId(0), EventKind::Migrated);
        assert!(!bad.is_causally_ordered());
    }
}
