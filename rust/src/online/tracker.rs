//! Incremental contention tracking — the **single contention engine**
//! shared by the whole stack.
//!
//! A from-scratch [`ContentionSnapshot`] rebuild costs `O(Σ_j span_j)`
//! over *all* active jobs per event, plus an allocation for the dense
//! `p_j` table. This tracker maintains the per-link active-ring counts of
//! the generalized Eq. 6 *incrementally* instead: admitting or completing
//! a job costs `O(path)` — the job's crossed links, `O(span_j)` for a
//! fixed number of fabric tiers — and `p_j` / bottleneck queries read the
//! maintained counts directly with no rebuild and no allocation.
//!
//! Since the incremental-simulation unification, every consumer runs on
//! one tracker:
//!
//! * the **online event loop** ([`crate::online::OnlineScheduler`]) — its
//!   original home: one tracker lives for the whole run, admissions and
//!   completions apply `O(path)` deltas;
//! * the **batch replay engine** ([`crate::sim::Simulator`], default
//!   [`ContentionMode::TrackerDirtySet`](crate::sim::ContentionMode)) —
//!   the same persistent-tracker discipline, paired with a
//!   [`DirtySet`](crate::contention::DirtySet) that re-rates only the
//!   jobs whose bottleneck-link counts actually changed (the snapshot
//!   rebuild survives as the cross-checked reference mode);
//! * the **planners** — SJF-BCO's κ-bisection and the baseline θ
//!   bisections score every candidate plan through
//!   [`PlanScorer`](crate::sim::PlanScorer), which replays candidates on
//!   the tracker engine with scratch reused across candidates, and the
//!   θ-admission / migration controls probe placements speculatively via
//!   [`whatif_bottleneck`](ContentionTracker::whatif_bottleneck) /
//!   [`whatif_rebottleneck`](ContentionTracker::whatif_rebottleneck)
//!   (zero mutation, zero allocation).
//!
//! In debug builds every mutation cross-checks the incremental counts
//! against a full from-scratch rebuild (the invariant the
//! `online_hot_path` bench exploits in release builds).

use crate::cluster::{Cluster, JobPlacement};
use crate::contention::ContentionSnapshot;
use crate::jobs::JobId;
use crate::net::{self, Allocation};
use crate::topology::{Bottleneck, Topology};

/// Live per-link contention state of the running set.
#[derive(Debug, Clone)]
pub struct ContentionTracker {
    /// The fabric the counts are indexed by (cloned from the cluster —
    /// a handful of small `Vec`s).
    topology: Topology,
    /// `link_jobs[ℓ] = Σ_{j active} 1{ring j crosses ℓ}` — the generalized
    /// Eq. 6 count per fabric link (server uplinks first, then ToRs,
    /// then pod uplinks).
    link_jobs: Vec<usize>,
    /// `count_hist[c] = #links with count c` for `c ≥ 1` — maintained
    /// alongside the counts so [`max_contention`](Self::max_contention)
    /// is O(1) instead of an O(L) scan per call (the histogram walk on
    /// decrement amortizes against the increments that raised the max).
    count_hist: Vec<usize>,
    max_count: usize,
    /// Active placements, indexed by dense `JobId`.
    active: Vec<Option<JobPlacement>>,
    num_active: usize,
}

impl ContentionTracker {
    pub fn new(cluster: &Cluster) -> Self {
        let topology = cluster.topology().clone();
        let link_jobs = vec![0; topology.num_links()];
        ContentionTracker {
            topology,
            link_jobs,
            count_hist: Vec::new(),
            max_count: 0,
            active: Vec::new(),
            num_active: 0,
        }
    }

    /// Number of currently active jobs.
    pub fn num_active(&self) -> usize {
        self.num_active
    }

    /// The fabric the counts are indexed by.
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Fault injection: degrade one fabric link to `factor` (0, 1] of its
    /// pristine capacity. The tracker owns the run's working copy of the
    /// topology, so the change lands exactly where every bottleneck and
    /// what-if query reads its multipliers — callers pair this with
    /// [`DirtySet::on_capacity_change`](crate::contention::DirtySet::on_capacity_change)
    /// so crossing members re-rate at the next drain.
    pub fn degrade_link(&mut self, l: crate::topology::LinkId, factor: f64) {
        self.topology.degrade_link(l, factor);
    }

    /// Fault injection: restore one degraded link to its pristine
    /// capacity (bit-identical multipliers to a never-degraded fabric).
    pub fn restore_link(&mut self, l: crate::topology::LinkId) {
        self.topology.restore_link(l);
    }

    /// Active-ring count on one fabric link (the raw Eq. 6 count the
    /// obs timeline samples).
    pub fn link_count(&self, l: crate::topology::LinkId) -> usize {
        self.link_jobs[l.0]
    }

    /// Clear every count and active placement (start of a fresh run)
    /// without deallocating — the batch engine reuses one tracker across
    /// candidate-plan replays.
    pub fn reset(&mut self) {
        self.link_jobs.iter_mut().for_each(|c| *c = 0);
        self.count_hist.iter_mut().for_each(|h| *h = 0);
        self.max_count = 0;
        self.active.clear();
        self.num_active = 0;
    }

    /// Admit one job: `O(path)` count updates along its crossed links.
    ///
    /// Panics if the job is already active.
    // archlint: allow(release-panic) count histogram is sized num_gpus+2 and counts are bounded by active rings
    pub fn admit(&mut self, job: JobId, placement: &JobPlacement) {
        if self.active.len() <= job.0 {
            self.active.resize(job.0 + 1, None);
        }
        assert!(self.active[job.0].is_none(), "{job} already active in tracker");
        let link_jobs = &mut self.link_jobs;
        let hist = &mut self.count_hist;
        let max_count = &mut self.max_count;
        self.topology.for_each_crossed(placement, |l| {
            let c = link_jobs[l.0];
            link_jobs[l.0] = c + 1;
            if hist.len() <= c + 1 {
                hist.resize(c + 2, 0);
            }
            if c > 0 {
                hist[c] -= 1;
            }
            hist[c + 1] += 1;
            if c + 1 > *max_count {
                *max_count = c + 1;
            }
        });
        self.active[job.0] = Some(placement.clone());
        self.num_active += 1;
        self.debug_check_against_rebuild();
    }

    /// Complete one job: `O(path)` count updates. Returns the placement
    /// the job held, or `None` if the job was not active. Completing an
    /// inactive job is always a caller bug (the event loop only completes
    /// members of its running set), so debug builds assert; release
    /// builds deliberately degrade to a reported no-op instead of tearing
    /// down a long-lived scheduler process — callers observe the `None`
    /// and the debug cross-check catches any count desync in CI.
    // archlint: allow(release-panic) count histogram is sized num_gpus+2 and counts are bounded by active rings
    pub fn complete(&mut self, job: JobId) -> Option<JobPlacement> {
        let slot = self.active.get_mut(job.0).and_then(Option::take);
        debug_assert!(slot.is_some(), "{job} not active in tracker");
        let placement = slot?;
        let link_jobs = &mut self.link_jobs;
        let hist = &mut self.count_hist;
        let max_count = &mut self.max_count;
        self.topology.for_each_crossed(&placement, |l| {
            let c = link_jobs[l.0];
            link_jobs[l.0] = c - 1;
            hist[c] -= 1;
            if c > 1 {
                hist[c - 1] += 1;
            }
            // the histogram may have gaps (e.g. counts {5, 3}); walk down
            // past empty buckets — each step undoes one earlier raise, so
            // the walk amortizes to O(1) per mutation
            if c == *max_count && hist[c] == 0 {
                while *max_count > 0 && hist[*max_count] == 0 {
                    *max_count -= 1;
                }
            }
        });
        self.num_active -= 1;
        self.debug_check_against_rebuild();
        Some(placement)
    }

    /// Re-place an active job atomically (preemption/migration): its old
    /// per-link counts are released and the new placement's charged, both
    /// in `O(path)`. Returns the old placement, or `None` (no-op) if the
    /// job was not active.
    pub fn migrate(&mut self, job: JobId, placement: &JobPlacement) -> Option<JobPlacement> {
        // explicit pre-check: an inactive job is a quiet no-op here (the
        // debug_assert! in `complete` is reserved for completion events)
        self.active.get(job.0).and_then(|o| o.as_ref())?;
        let old = self.complete(job)?;
        self.admit(job, placement);
        Some(old)
    }

    /// Contention degree `p_j[t]` (generalized Eq. 6) of an active job: 0
    /// for co-located jobs, else the ring count at its bottleneck link —
    /// `O(path)`, no rebuild. An inactive job is a debug-asserted logic
    /// error and reads as 0 (co-located / no contention) in release; use
    /// [`try_p_j`](Self::try_p_j) where absence is expected.
    pub fn p_j(&self, job: JobId) -> usize {
        self.bottleneck(job).p
    }

    /// Non-panicking [`p_j`](Self::p_j).
    pub fn try_p_j(&self, job: JobId) -> Option<usize> {
        self.try_bottleneck(job).map(|b| b.p)
    }

    /// The bottleneck link of an active job's ring under the maintained
    /// counts. An inactive job is a debug-asserted logic error; release
    /// builds degrade to [`Bottleneck::NONE`] (the contention-free
    /// operating point) instead of tearing down the event loop — use
    /// [`try_bottleneck`](Self::try_bottleneck) where absence is expected.
    pub fn bottleneck(&self, job: JobId) -> Bottleneck {
        let bn = self.try_bottleneck(job);
        debug_assert!(bn.is_some(), "{job} not active in tracker");
        bn.unwrap_or(Bottleneck::NONE)
    }

    /// Non-panicking [`bottleneck`](Self::bottleneck).
    pub fn try_bottleneck(&self, job: JobId) -> Option<Bottleneck> {
        let pl = self.active.get(job.0).and_then(|o| o.as_ref())?;
        Some(self.topology.bottleneck(pl, &self.link_jobs))
    }

    /// Placement of an active job, if any.
    pub fn placement(&self, job: JobId) -> Option<&JobPlacement> {
        self.active.get(job.0).and_then(|o| o.as_ref())
    }

    /// **Speculative** bottleneck a *not-yet-admitted* placement would see
    /// if admitted right now: every crossed link evaluated at `count + 1`
    /// (the candidate ring counts itself, Eq. 6). `O(path)`, zero
    /// mutation, zero allocation — the θ-admission hot path.
    pub fn whatif_bottleneck(&self, placement: &JobPlacement) -> Bottleneck {
        crate::obs::metrics::incr(crate::obs::metrics::Counter::WhatifCalls);
        let _span = crate::obs::trace::span("tracker.whatif", "tracker");
        let mut best = Bottleneck::NONE;
        self.topology.for_each_crossed(placement, |l| {
            let cand = Bottleneck {
                p: self.link_jobs[l.0] + 1,
                oversub: self.topology.multiplier(l),
                link: Some(l),
            };
            if best.link.is_none() || cand.dominates(&best) {
                best = cand;
            }
        });
        best
    }

    /// **Speculative** bandwidth share (Gbps) a not-yet-admitted placement
    /// would be allocated right now: the equal split of its projected
    /// bottleneck link, `c_ref / (count × multiplier)`. Co-located
    /// candidates are not link-limited (`f64::INFINITY`). Under
    /// [`ContentionModel::MaxMinFair`](crate::net::ContentionModel) this
    /// is the quantity the θ-admission guard effectively bounds from
    /// below: `degree > θ  ⟺  share < c_ref / θ`.
    pub fn whatif_share_gbps(&self, placement: &JobPlacement) -> f64 {
        let bn = self.whatif_bottleneck(placement);
        if bn.link.is_none() {
            f64::INFINITY
        } else {
            // archlint: allow(choke-point) report-only conversion of a Topology-computed degree to Gbps
            self.topology.reference_gbps() / bn.effective()
        }
    }

    /// **Speculative** bottleneck an *active* job would see after moving to
    /// `candidate`: its current placement's link contributions are deducted
    /// before the candidate's crossed links are evaluated at `count + 1`.
    /// `O(span_old × span_new)` worst case (tiny in practice — crossed
    /// links are unique per placement), zero mutation. `None` if the job
    /// is not active — the migration what-if of a completed job is
    /// meaningless.
    pub fn whatif_rebottleneck(
        &self,
        job: JobId,
        candidate: &JobPlacement,
    ) -> Option<Bottleneck> {
        crate::obs::metrics::incr(crate::obs::metrics::Counter::WhatifCalls);
        let _span = crate::obs::trace::span("tracker.whatif_re", "tracker");
        let current = self.active.get(job.0).and_then(|o| o.as_ref())?;
        let mut own: Vec<usize> = Vec::new();
        self.topology.for_each_crossed(current, |l| own.push(l.0));
        let mut best = Bottleneck::NONE;
        self.topology.for_each_crossed(candidate, |l| {
            // each link appears at most once in a placement's crossed set
            let minus = usize::from(own.contains(&l.0));
            let cand = Bottleneck {
                p: self.link_jobs[l.0] - minus + 1,
                oversub: self.topology.multiplier(l),
                link: Some(l),
            };
            if best.link.is_none() || cand.dominates(&best) {
                best = cand;
            }
        });
        Some(best)
    }

    /// Largest active-ring count on any single fabric link — O(1) from
    /// the count histogram maintained on every admit/complete/migrate
    /// (the `O(L)` scan survives as
    /// [`max_contention_scan`](Self::max_contention_scan), the
    /// cross-checked reference). On a flat fabric this equals the largest
    /// contention degree across all active jobs.
    pub fn max_contention(&self) -> usize {
        #[cfg(debug_assertions)]
        {
            // counted so a debug-build verify run can report that the
            // cross-check actually executed (see obs::metrics)
            crate::obs::metrics::incr(crate::obs::metrics::Counter::HistCrossChecks);
            debug_assert_eq!(
                self.max_count,
                self.max_contention_scan(),
                "count histogram diverged from the O(L) scan"
            );
        }
        self.max_count
    }

    /// The pre-histogram `O(L)` reference for
    /// [`max_contention`](Self::max_contention) — kept for the debug
    /// cross-check, the property test and the `net_alloc` bench.
    pub fn max_contention_scan(&self) -> usize {
        self.link_jobs.iter().copied().max().unwrap_or(0)
    }

    /// Per-link **residual bandwidth** (Gbps) under the engines'
    /// bottleneck-share rates ([`net::residual_ledger`] over the active
    /// set): what is left per link is the headroom the dirty-set
    /// invalidation rule reasons about (a link's residual moves iff its
    /// count — or a crosser's bottleneck — moved, both of which the
    /// touched-link rule covers). `O(Σ span)` over the active set — a
    /// report/diagnostic path, not the hot loop.
    pub fn residual_gbps(&self) -> Vec<f64> {
        net::residual_ledger(&self.topology, self.active_jobs(), &self.link_jobs)
    }

    /// Full max-min **progressive fill** over the active set
    /// ([`net::progressive_fill`]): true water-filled per-ring rates and
    /// per-link residuals, including the headroom the bottleneck-share
    /// model leaves unclaimed. Report path; allocates the output.
    pub fn water_fill(&self, scratch: &mut net::AllocScratch) -> Allocation {
        net::progressive_fill(&self.topology, self.active_jobs(), scratch)
    }

    /// Active (job, placement) pairs in job-id order.
    pub fn active_jobs(&self) -> impl Iterator<Item = (JobId, &JobPlacement)> + Clone {
        self.active
            .iter()
            .enumerate()
            .filter_map(|(i, o)| o.as_ref().map(|pl| (JobId(i), pl)))
    }

    /// Full from-scratch [`ContentionSnapshot`] over the active set — the
    /// `O(jobs × span)` baseline the tracker replaces (kept for the debug
    /// cross-check, property tests and the hot-path bench). Streams the
    /// active set straight into the build — no intermediate refs `Vec`.
    pub fn full_rebuild(&self, cluster: &Cluster) -> ContentionSnapshot {
        ContentionSnapshot::build_iter(cluster, self.active_jobs())
    }

    /// Debug invariant: incremental counts equal a full recount.
    fn debug_check_against_rebuild(&self) {
        #[cfg(debug_assertions)]
        {
            // counted so a debug-build verify run can report that the
            // cross-check actually executed (see obs::metrics)
            crate::obs::metrics::incr(crate::obs::metrics::Counter::TrackerCrossChecks);
            let mut expect = vec![0usize; self.link_jobs.len()];
            for pl in self.active.iter().flatten() {
                self.topology.for_each_crossed(pl, |l| expect[l.0] += 1);
            }
            debug_assert_eq!(
                expect, self.link_jobs,
                "incremental per-link counts diverged from full rebuild"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerId;

    fn mk(c: &Cluster, pairs: &[(usize, usize)]) -> JobPlacement {
        JobPlacement::new(pairs.iter().map(|&(s, i)| c.global_gpu(ServerId(s), i)).collect())
    }

    #[test]
    fn matches_snapshot_on_the_three_way_case() {
        let c = Cluster::uniform(4, 8, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (2, 0)]));
        tr.admit(JobId(2), &mk(&c, &[(0, 2), (3, 0)]));
        tr.admit(JobId(3), &mk(&c, &[(2, 1), (3, 1)]));
        assert_eq!(tr.p_j(JobId(0)), 3);
        assert_eq!(tr.p_j(JobId(1)), 3);
        assert_eq!(tr.p_j(JobId(2)), 3);
        assert_eq!(tr.p_j(JobId(3)), 2);
        assert_eq!(tr.max_contention(), 3);
        let snap = tr.full_rebuild(&c);
        for (j, _) in tr.active_jobs() {
            assert_eq!(tr.p_j(j), snap.p_j(j));
            assert_eq!(tr.bottleneck(j), snap.bottleneck(j));
        }
        assert_eq!(tr.max_contention(), snap.max_contention());
    }

    #[test]
    fn completion_decrements_counts() {
        let c = Cluster::uniform(3, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (1, 1)]));
        assert_eq!(tr.p_j(JobId(0)), 2);
        tr.complete(JobId(1));
        assert_eq!(tr.p_j(JobId(0)), 1, "job counts only itself after the peer leaves");
        tr.complete(JobId(0));
        assert_eq!(tr.num_active(), 0);
        assert_eq!(tr.max_contention(), 0);
    }

    #[test]
    fn colocated_jobs_do_not_contend() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (0, 1)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 2), (1, 0)]));
        assert_eq!(tr.p_j(JobId(0)), 0, "co-located ring never crosses an uplink");
        assert_eq!(tr.p_j(JobId(1)), 1, "spread ring counts itself");
        assert_eq!(tr.bottleneck(JobId(0)), Bottleneck::NONE);
    }

    #[test]
    fn try_queries_survive_inactive_jobs() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        assert_eq!(tr.try_p_j(JobId(0)), None);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        assert_eq!(tr.try_p_j(JobId(0)), Some(1));
        tr.complete(JobId(0));
        assert_eq!(tr.try_p_j(JobId(0)), None);
        assert_eq!(tr.try_bottleneck(JobId(42)), None);
    }

    #[test]
    fn rack_tier_counts_track_incrementally() {
        use crate::topology::Topology;
        // 4 servers, 2 racks of 2, ToR oversubscribed 2x.
        let c = Cluster::uniform(4, 4, 1.0, 25.0)
            .with_topology(Topology::racks(4, 2, 2.0));
        let mut tr = ContentionTracker::new(&c);
        // rack-local spread ring: bottleneck stays a server uplink
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        assert_eq!(tr.bottleneck(JobId(0)).oversub, 1.0);
        // cross-rack ring: its ToR uplinks (count 1, oversub 2) now beat
        // the shared server-0 uplink (count 2) on effective degree 1·2 vs
        // … no: server 0 carries both rings, effective 2·1 = 2 ties 1·2 —
        // the higher raw count wins the tie, keeping the server uplink.
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (2, 0)]));
        let bn = tr.bottleneck(JobId(1));
        assert_eq!((bn.p, bn.oversub), (2, 1.0), "tie prefers the higher count");
        // a second cross-rack ring tips the ToR: count 2, effective 4
        tr.admit(JobId(2), &mk(&c, &[(1, 1), (3, 0)]));
        let bn = tr.bottleneck(JobId(2));
        assert_eq!((bn.p, bn.oversub), (2, 2.0));
        assert_eq!(bn.link, Some(c.topology().rack_uplink(0)));
        // completions unwind the rack counts too: with only one cross-rack
        // ring left the ToR's effective degree 1·2 ties the server-1 uplink
        // it shares with job 0 (count 2), and the higher count wins again.
        tr.complete(JobId(1));
        let bn = tr.bottleneck(JobId(2));
        assert_eq!((bn.p, bn.oversub), (2, 1.0));
        assert_eq!(bn.link, Some(c.topology().server_uplink(ServerId(1))));
        // tracker agrees with the from-scratch snapshot on the rack fabric
        let snap = tr.full_rebuild(&c);
        for (j, _) in tr.active_jobs() {
            assert_eq!(tr.bottleneck(j), snap.bottleneck(j), "{j}");
        }
        assert_eq!(tr.max_contention(), snap.max_contention());
    }

    #[test]
    #[should_panic]
    fn double_admit_panics() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        let pl = mk(&c, &[(0, 0)]);
        tr.admit(JobId(0), &pl);
        tr.admit(JobId(0), &pl);
    }

    // The inactive-complete contract is split by build profile: debug
    // builds assert (logic error), release paths degrade to a no-op that
    // reports the absence via `None`.
    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn completing_inactive_job_panics_in_debug() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        let _ = tr.complete(JobId(7));
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn completing_inactive_job_is_a_none_noop_in_release() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        assert!(tr.complete(JobId(7)).is_none());
        assert_eq!(tr.num_active(), 0);
        assert_eq!(tr.bottleneck(JobId(7)), Bottleneck::NONE);
    }

    #[test]
    fn complete_returns_the_released_placement() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        let pl = mk(&c, &[(0, 0), (1, 0)]);
        tr.admit(JobId(0), &pl);
        assert_eq!(tr.complete(JobId(0)).as_ref(), Some(&pl));
    }

    #[test]
    fn whatif_bottleneck_previews_admission_without_mutating() {
        let c = Cluster::uniform(3, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        let counts_before = tr.max_contention();
        // a second ring crossing server 0 would see count 2 there
        let cand = mk(&c, &[(0, 1), (2, 0)]);
        let bn = tr.whatif_bottleneck(&cand);
        assert_eq!(bn.p, 2, "counts itself plus the standing ring");
        // co-located candidate: nothing crossed
        assert_eq!(tr.whatif_bottleneck(&mk(&c, &[(2, 1), (2, 2)])), Bottleneck::NONE);
        // the preview mutated nothing
        assert_eq!(tr.max_contention(), counts_before);
        assert_eq!(tr.num_active(), 1);
        // and admitting for real reproduces the preview exactly
        tr.admit(JobId(1), &cand);
        assert_eq!(tr.bottleneck(JobId(1)), bn);
    }

    #[test]
    fn whatif_rebottleneck_deducts_the_jobs_own_contribution() {
        let c = Cluster::uniform(3, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        // two rings sharing server 0's uplink
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (2, 0)]));
        assert_eq!(tr.p_j(JobId(1)), 2);
        // moving job 1 fully onto server 2: co-located, contention gone
        let colo = mk(&c, &[(2, 1), (2, 2)]);
        assert_eq!(tr.whatif_rebottleneck(JobId(1), &colo), Some(Bottleneck::NONE));
        // moving job 1 onto servers 1+2 avoids server 0 but still spreads:
        // server 1 already carries job 0's ring → count 2 there
        let moved = mk(&c, &[(1, 1), (2, 1)]);
        let bn = tr.whatif_rebottleneck(JobId(1), &moved).unwrap();
        assert_eq!(bn.p, 2);
        // staying put must reproduce the live bottleneck (self-deduction
        // then self-recount is the identity)
        let stay = tr.whatif_rebottleneck(JobId(1), &mk(&c, &[(0, 1), (2, 0)])).unwrap();
        assert_eq!(stay, tr.bottleneck(JobId(1)));
        // inactive job: no what-if
        assert!(tr.whatif_rebottleneck(JobId(9), &colo).is_none());
    }

    #[test]
    fn migrate_moves_counts_atomically() {
        let c = Cluster::uniform(3, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        let old_pl = mk(&c, &[(0, 0), (1, 0)]);
        tr.admit(JobId(0), &old_pl);
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (2, 0)]));
        let new_pl = mk(&c, &[(2, 1), (2, 2)]);
        assert_eq!(tr.migrate(JobId(0), &new_pl).as_ref(), Some(&old_pl));
        assert_eq!(tr.num_active(), 2);
        assert_eq!(tr.p_j(JobId(0)), 0, "co-located after the move");
        assert_eq!(tr.p_j(JobId(1)), 1, "old contender no longer shares server 0");
        // counts agree with a from-scratch rebuild after the move
        let snap = tr.full_rebuild(&c);
        for (j, _) in tr.active_jobs() {
            assert_eq!(tr.bottleneck(j), snap.bottleneck(j), "{j}");
        }
        assert!(tr.migrate(JobId(9), &new_pl).is_none(), "inactive: no-op");
    }

    #[test]
    fn reset_clears_counts_and_allows_reuse() {
        let c = Cluster::uniform(3, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (2, 0)]));
        tr.reset();
        assert_eq!(tr.num_active(), 0);
        assert_eq!(tr.max_contention(), 0);
        assert_eq!(tr.try_p_j(JobId(0)), None);
        // fresh run on the reused tracker behaves like a new one
        tr.admit(JobId(0), &mk(&c, &[(1, 1), (2, 1)]));
        assert_eq!(tr.p_j(JobId(0)), 1);
        let snap = tr.full_rebuild(&c);
        assert_eq!(snap.p_j(JobId(0)), 1);
    }

    #[test]
    fn incremental_max_contention_tracks_the_scan() {
        use crate::topology::Topology;
        use crate::util::proptest_lite::check;
        check("histogram max == O(L) scan", 40, |rng| {
            let c = match rng.gen_usize(0, 2) {
                0 => Cluster::uniform(rng.gen_usize(3, 6), 4, 1.0, 25.0),
                1 => Cluster::uniform(6, 4, 1.0, 25.0)
                    .with_topology(Topology::racks(6, 2, 2.0)),
                _ => Cluster::uniform(8, 4, 1.0, 25.0)
                    .with_topology(Topology::pods(8, 2, 2, 2.0, 4.0)),
            };
            let mut tr = ContentionTracker::new(&c);
            let mut active: Vec<JobId> = Vec::new();
            let mut next = 0usize;
            for _ in 0..60 {
                let roll = rng.gen_f64();
                if active.is_empty() || roll < 0.55 {
                    let k = rng.gen_usize(1, c.num_gpus().min(6));
                    let mut gpus: Vec<_> = c.all_gpus().collect();
                    rng.shuffle(&mut gpus);
                    gpus.truncate(k);
                    let job = JobId(next);
                    next += 1;
                    tr.admit(job, &JobPlacement::new(gpus));
                    active.push(job);
                } else if roll < 0.8 {
                    let victim = active.swap_remove(rng.gen_usize(0, active.len() - 1));
                    tr.complete(victim);
                } else {
                    let job = active[rng.gen_usize(0, active.len() - 1)];
                    let k = rng.gen_usize(1, c.num_gpus().min(6));
                    let mut gpus: Vec<_> = c.all_gpus().collect();
                    rng.shuffle(&mut gpus);
                    gpus.truncate(k);
                    tr.migrate(job, &JobPlacement::new(gpus));
                }
                assert_eq!(tr.max_contention(), tr.max_contention_scan());
            }
            tr.reset();
            assert_eq!(tr.max_contention(), 0);
        });
    }

    #[test]
    fn residuals_and_water_fill_account_for_the_active_set() {
        let c = Cluster::uniform(3, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        let full = c.topology().link_gbps(crate::topology::LinkId(0));
        assert_eq!(tr.residual_gbps(), vec![full; 3], "idle fabric is all headroom");
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (2, 0)]));
        let res = tr.residual_gbps();
        // both rings bottleneck on server 0's uplink (count 2): share c/2
        // each, saturating link 0; links 1 and 2 keep the other half
        assert!(res[0].abs() < 1e-12, "shared uplink saturated, got {}", res[0]);
        assert_eq!(res[1], full / 2.0);
        assert_eq!(res[2], full / 2.0);
        let mut scratch = crate::net::AllocScratch::default();
        let alloc = tr.water_fill(&mut scratch);
        assert_eq!(alloc.num_rings(), 2);
        assert_eq!(alloc.rate_of(JobId(0)), Some(full / 2.0));
        // projected share of a third ring across the hot uplink: c/3
        let share = tr.whatif_share_gbps(&mk(&c, &[(0, 2), (1, 1)]));
        assert!((share - full / 3.0).abs() < 1e-12, "got {share}");
        assert_eq!(
            tr.whatif_share_gbps(&mk(&c, &[(2, 1), (2, 2)])),
            f64::INFINITY,
            "co-located candidates are not link-limited"
        );
    }

    #[test]
    fn id_reuse_after_completion_is_allowed() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.complete(JobId(0));
        tr.admit(JobId(0), &mk(&c, &[(0, 1), (1, 1)]));
        assert_eq!(tr.p_j(JobId(0)), 1);
    }
}
