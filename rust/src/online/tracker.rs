//! Incremental contention tracking for the online event loop.
//!
//! The offline simulator rebuilds a [`ContentionSnapshot`] from scratch at
//! every event — `O(Σ_j span_j)` over *all* active jobs, plus an
//! allocation for the dense `p_j` table. That is fine for replaying one
//! plan, but the online scheduler fields a continuous arrival stream
//! where most events touch a single job. This tracker maintains the
//! per-uplink active-job counts of Eq. 6 *incrementally*: admitting or
//! completing a job costs `O(span_j)` of that one job, and `p_j` queries
//! read the maintained counts directly with no rebuild and no allocation.
//!
//! In debug builds every mutation cross-checks the incremental counts
//! against a full from-scratch rebuild (the invariant the
//! `online_hot_path` bench exploits in release builds).

use crate::cluster::{Cluster, JobPlacement};
use crate::contention::ContentionSnapshot;
use crate::jobs::JobId;

/// Live per-uplink contention state of the running set.
#[derive(Debug, Clone)]
pub struct ContentionTracker {
    /// `uplink_jobs[s] = Σ_{j active} 1{0 < y_js < G_j}` — the Eq. 6
    /// count of spread rings crossing server `s`'s uplink.
    uplink_jobs: Vec<usize>,
    /// Active placements, indexed by dense `JobId`.
    active: Vec<Option<JobPlacement>>,
    num_active: usize,
}

impl ContentionTracker {
    pub fn new(cluster: &Cluster) -> Self {
        ContentionTracker {
            uplink_jobs: vec![0; cluster.num_servers()],
            active: Vec::new(),
            num_active: 0,
        }
    }

    /// Number of currently active jobs.
    pub fn num_active(&self) -> usize {
        self.num_active
    }

    /// Admit one job: `O(span_j)` count updates.
    ///
    /// Panics if the job is already active.
    pub fn admit(&mut self, job: JobId, placement: &JobPlacement) {
        if self.active.len() <= job.0 {
            self.active.resize(job.0 + 1, None);
        }
        assert!(self.active[job.0].is_none(), "{job} already active in tracker");
        if placement.is_spread() {
            for s in placement.servers() {
                self.uplink_jobs[s.0] += 1;
            }
        }
        self.active[job.0] = Some(placement.clone());
        self.num_active += 1;
        self.debug_check_against_rebuild();
    }

    /// Complete one job: `O(span_j)` count updates.
    ///
    /// Panics if the job is not active.
    pub fn complete(&mut self, job: JobId) {
        let placement = self
            .active
            .get_mut(job.0)
            .and_then(Option::take)
            .unwrap_or_else(|| panic!("{job} not active in tracker"));
        if placement.is_spread() {
            for s in placement.servers() {
                self.uplink_jobs[s.0] -= 1;
            }
        }
        self.num_active -= 1;
        self.debug_check_against_rebuild();
    }

    /// Contention degree `p_j[t]` (Eq. 6) of an active job: 0 for
    /// co-located jobs, else the max maintained count over the servers its
    /// ring crosses — `O(span_j)`, no rebuild.
    pub fn p_j(&self, job: JobId) -> usize {
        let pl = self
            .active
            .get(job.0)
            .and_then(|o| o.as_ref())
            .unwrap_or_else(|| panic!("{job} not active in tracker"));
        if pl.is_spread() {
            pl.servers().map(|s| self.uplink_jobs[s.0]).max().unwrap_or(0)
        } else {
            0
        }
    }

    /// Placement of an active job, if any.
    pub fn placement(&self, job: JobId) -> Option<&JobPlacement> {
        self.active.get(job.0).and_then(|o| o.as_ref())
    }

    /// Largest contention degree across all active jobs — equals
    /// `max_s uplink_jobs[s]`, `O(|S|)`.
    pub fn max_contention(&self) -> usize {
        self.uplink_jobs.iter().copied().max().unwrap_or(0)
    }

    /// Active (job, placement) pairs in job-id order.
    pub fn active_jobs(&self) -> impl Iterator<Item = (JobId, &JobPlacement)> {
        self.active
            .iter()
            .enumerate()
            .filter_map(|(i, o)| o.as_ref().map(|pl| (JobId(i), pl)))
    }

    /// Full from-scratch [`ContentionSnapshot`] over the active set — the
    /// `O(jobs × span)` baseline the tracker replaces (kept for the debug
    /// cross-check, property tests and the hot-path bench).
    pub fn full_rebuild(&self, cluster: &Cluster) -> ContentionSnapshot {
        let refs: Vec<(JobId, &JobPlacement)> = self.active_jobs().collect();
        ContentionSnapshot::build_ref(cluster, &refs)
    }

    /// Debug invariant: incremental counts equal a full recount.
    fn debug_check_against_rebuild(&self) {
        #[cfg(debug_assertions)]
        {
            let mut expect = vec![0usize; self.uplink_jobs.len()];
            for pl in self.active.iter().flatten() {
                if pl.is_spread() {
                    for s in pl.servers() {
                        expect[s.0] += 1;
                    }
                }
            }
            debug_assert_eq!(
                expect, self.uplink_jobs,
                "incremental uplink counts diverged from full rebuild"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerId;

    fn mk(c: &Cluster, pairs: &[(usize, usize)]) -> JobPlacement {
        JobPlacement::new(pairs.iter().map(|&(s, i)| c.global_gpu(ServerId(s), i)).collect())
    }

    #[test]
    fn matches_snapshot_on_the_three_way_case() {
        let c = Cluster::uniform(4, 8, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (2, 0)]));
        tr.admit(JobId(2), &mk(&c, &[(0, 2), (3, 0)]));
        tr.admit(JobId(3), &mk(&c, &[(2, 1), (3, 1)]));
        assert_eq!(tr.p_j(JobId(0)), 3);
        assert_eq!(tr.p_j(JobId(1)), 3);
        assert_eq!(tr.p_j(JobId(2)), 3);
        assert_eq!(tr.p_j(JobId(3)), 2);
        assert_eq!(tr.max_contention(), 3);
        let snap = tr.full_rebuild(&c);
        for (j, _) in tr.active_jobs() {
            assert_eq!(tr.p_j(j), snap.p_j(j));
        }
        assert_eq!(tr.max_contention(), snap.max_contention());
    }

    #[test]
    fn completion_decrements_counts() {
        let c = Cluster::uniform(3, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 1), (1, 1)]));
        assert_eq!(tr.p_j(JobId(0)), 2);
        tr.complete(JobId(1));
        assert_eq!(tr.p_j(JobId(0)), 1, "job counts only itself after the peer leaves");
        tr.complete(JobId(0));
        assert_eq!(tr.num_active(), 0);
        assert_eq!(tr.max_contention(), 0);
    }

    #[test]
    fn colocated_jobs_do_not_contend() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (0, 1)]));
        tr.admit(JobId(1), &mk(&c, &[(0, 2), (1, 0)]));
        assert_eq!(tr.p_j(JobId(0)), 0, "co-located ring never crosses an uplink");
        assert_eq!(tr.p_j(JobId(1)), 1, "spread ring counts itself");
    }

    #[test]
    #[should_panic]
    fn double_admit_panics() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        let pl = mk(&c, &[(0, 0)]);
        tr.admit(JobId(0), &pl);
        tr.admit(JobId(0), &pl);
    }

    #[test]
    #[should_panic]
    fn completing_inactive_job_panics() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.complete(JobId(7));
    }

    #[test]
    fn id_reuse_after_completion_is_allowed() {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let mut tr = ContentionTracker::new(&c);
        tr.admit(JobId(0), &mk(&c, &[(0, 0), (1, 0)]));
        tr.complete(JobId(0));
        tr.admit(JobId(0), &mk(&c, &[(0, 1), (1, 1)]));
        assert_eq!(tr.p_j(JobId(0)), 1);
    }
}
