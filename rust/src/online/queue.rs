//! The live pending queue: jobs that have arrived but not yet started.
//!
//! Kept in arrival order (FIFO); policies see it read-only through the
//! [`QueuedJob`](super::policy::QueuedJob) view the scheduler builds, so
//! a policy can reorder *its choice* but never mutate the queue itself.

use crate::jobs::JobId;

/// Arrival-ordered queue of waiting jobs.
#[derive(Debug, Clone, Default)]
pub struct PendingQueue {
    /// (job, arrival slot) in arrival order.
    entries: Vec<(JobId, u64)>,
}

impl PendingQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a newly arrived job. Arrivals must be pushed in
    /// chronological order (the event loop guarantees this).
    pub fn push(&mut self, job: JobId, arrival: u64) {
        debug_assert!(
            self.entries.last().map_or(true, |&(_, a)| a <= arrival),
            "arrivals must be enqueued in chronological order"
        );
        debug_assert!(!self.contains(job), "{job} already queued");
        self.entries.push((job, arrival));
    }

    /// Remove a job (on start); returns whether it was queued.
    pub fn remove(&mut self, job: JobId) -> bool {
        match self.entries.iter().position(|&(j, _)| j == job) {
            Some(i) => {
                self.entries.remove(i);
                true
            }
            None => false,
        }
    }

    /// Head of the queue (earliest arrival still waiting).
    pub fn head(&self) -> Option<JobId> {
        self.entries.first().map(|&(j, _)| j)
    }

    /// (job, arrival) pairs in arrival order.
    pub fn iter(&self) -> impl Iterator<Item = (JobId, u64)> + '_ {
        self.entries.iter().copied()
    }

    pub fn contains(&self, job: JobId) -> bool {
        self.entries.iter().any(|&(j, _)| j == job)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_removal() {
        let mut q = PendingQueue::new();
        q.push(JobId(3), 0);
        q.push(JobId(1), 2);
        q.push(JobId(2), 2);
        assert_eq!(q.len(), 3);
        assert_eq!(q.head(), Some(JobId(3)));
        assert!(q.contains(JobId(1)));
        assert!(q.remove(JobId(1)));
        assert!(!q.remove(JobId(1)), "second removal is a no-op");
        let order: Vec<_> = q.iter().map(|(j, _)| j.0).collect();
        assert_eq!(order, vec![3, 2]);
        assert!(q.remove(JobId(3)));
        assert_eq!(q.head(), Some(JobId(2)));
        assert!(q.remove(JobId(2)));
        assert!(q.is_empty());
        assert_eq!(q.head(), None);
    }
}
