//! Server and GPU identities.


/// Index of a server in the cluster (dense, 0-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ServerId(pub usize);

impl std::fmt::Display for ServerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// A GPU identity: its server, its slot on that server, and its
/// cluster-global index (used for the per-GPU execution-time accounting
/// `U_s^g` in Algorithms 1–3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GpuId {
    pub server: ServerId,
    pub index: usize,
    pub global: usize,
}

impl std::fmt::Display for GpuId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:g{}", self.server, self.index)
    }
}

/// A server with `O_s` homogeneous GPUs (paper §4.1: equal computation
/// speed, synchronized).
#[derive(Debug, Clone)]
pub struct Server {
    id: ServerId,
    capacity: usize,
}

impl Server {
    pub fn new(id: ServerId, capacity: usize) -> Self {
        assert!(capacity > 0, "server must host at least one GPU");
        Server { id, capacity }
    }

    pub fn id(&self) -> ServerId {
        self.id
    }

    /// GPU capacity `O_s`.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_forms() {
        let s = ServerId(3);
        assert_eq!(s.to_string(), "s3");
        let g = GpuId { server: s, index: 2, global: 14 };
        assert_eq!(g.to_string(), "s3:g2");
    }

    #[test]
    #[should_panic]
    fn zero_capacity_server_rejected() {
        Server::new(ServerId(0), 0);
    }
}
