//! Cluster substrate: servers, GPUs, link bandwidths, placements.
//!
//! This models the multi-tenant GPU cluster of the paper's §4.1: a set of
//! servers `S`, each equipped with `O_s` homogeneous synchronized GPUs,
//! connected by a network with fast intra-server links (bandwidth `b^i`,
//! e.g. NVLink) and slower inter-server links (bandwidth `b^e`, e.g.
//! 10 Gbps Ethernet), with `b^i >> b^e`.

mod placement;
mod server;
mod state;

pub use placement::{JobPlacement, PlacementBuilder};
pub use server::{GpuId, Server, ServerId};
pub use state::ClusterState;

use crate::topology::Topology;

/// The whole multi-tenant GPU cluster.
///
/// Bandwidths are expressed in *model units per time-slot* — the same unit
/// as job gradient sizes `m_j`, so `m_j / bandwidth` is a number of slots.
#[derive(Debug, Clone)]
pub struct Cluster {
    servers: Vec<Server>,
    /// Inter-server link bandwidth `b^e`.
    pub inter_bw: f64,
    /// Intra-server link bandwidth `b^i` (`b^i >> b^e` in practice).
    pub intra_bw: f64,
    /// Prefix sums of GPU counts for global-id mapping (`gpu_base[s]` is the
    /// global id of server `s`'s first GPU).
    gpu_base: Vec<usize>,
    /// The shared-link fabric above the servers. Every constructor builds
    /// the paper's flat 1-tier fabric (Eq. 6 exactly); use
    /// [`with_topology`](Self::with_topology) to mount a rack tier.
    topology: Topology,
}

impl Cluster {
    /// Build a cluster from per-server GPU capacities `O_s`.
    pub fn new(capacities: &[usize], inter_bw: f64, intra_bw: f64) -> Self {
        assert!(!capacities.is_empty(), "cluster needs at least one server");
        assert!(inter_bw > 0.0 && intra_bw > 0.0, "bandwidths must be positive");
        let servers: Vec<Server> = capacities
            .iter()
            .enumerate()
            .map(|(i, &c)| Server::new(ServerId(i), c))
            .collect();
        let mut gpu_base = Vec::with_capacity(servers.len());
        let mut acc = 0usize;
        for s in &servers {
            gpu_base.push(acc);
            acc += s.capacity();
        }
        let topology = Topology::flat(servers.len());
        Cluster { servers, inter_bw, intra_bw, gpu_base, topology }
    }

    /// Replace the network fabric (builder style). Panics if the topology
    /// was built for a different server count.
    pub fn with_topology(mut self, topology: Topology) -> Self {
        assert_eq!(
            topology.num_servers(),
            self.servers.len(),
            "topology server count must match the cluster"
        );
        self.topology = topology;
        self
    }

    /// The shared-link fabric above the servers.
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// A homogeneous cluster: `n_servers` servers with `gpus_per_server` each.
    pub fn uniform(n_servers: usize, gpus_per_server: usize, inter_bw: f64, intra_bw: f64) -> Self {
        Self::new(&vec![gpus_per_server; n_servers], inter_bw, intra_bw)
    }

    /// The paper's §7 cluster: 20 servers, `O_s` drawn u.a.r. from
    /// {4, 8, 16, 32}, seeded for reproducibility.
    pub fn paper(seed: u64) -> Self {
        Self::random(20, seed)
    }

    /// A random cluster with `n_servers` servers and capacities drawn
    /// u.a.r. from {4, 8, 16, 32} (paper §7), b^e = 1.0, b^i = 25.0.
    ///
    /// The bandwidth ratio 25:1 approximates NVLink (~300 GB/s effective)
    /// vs 10 Gbps Ethernet used by [19], clipped to keep slot counts sane.
    pub fn random(n_servers: usize, seed: u64) -> Self {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let choices = [4usize, 8, 16, 32];
        let caps: Vec<usize> = (0..n_servers).map(|_| *rng.choose(&choices)).collect();
        Self::new(&caps, 1.0, 25.0)
    }

    /// Number of servers `|S|`.
    pub fn num_servers(&self) -> usize {
        self.servers.len()
    }

    /// Total number of GPUs `N` in the cluster.
    pub fn num_gpus(&self) -> usize {
        self.gpu_base.last().map(|b| b + self.servers.last().unwrap().capacity()).unwrap_or(0)
    }

    /// GPU capacity `O_s` of server `s`.
    pub fn capacity(&self, s: ServerId) -> usize {
        self.servers[s.0].capacity()
    }

    /// Largest per-server GPU capacity `max_s O_s` — the worst-case
    /// contention degree used in the paper's τ bounds (§5.1).
    pub fn max_capacity(&self) -> usize {
        self.servers.iter().map(|s| s.capacity()).max().unwrap_or(0)
    }

    /// Iterate over servers.
    pub fn servers(&self) -> impl Iterator<Item = &Server> {
        self.servers.iter()
    }

    /// All server ids.
    pub fn server_ids(&self) -> impl Iterator<Item = ServerId> + '_ {
        (0..self.servers.len()).map(ServerId)
    }

    /// Map a (server, local index) pair to a cluster-global GPU id.
    pub fn global_gpu(&self, s: ServerId, local: usize) -> GpuId {
        debug_assert!(local < self.capacity(s));
        GpuId { server: s, index: local, global: self.gpu_base[s.0] + local }
    }

    /// Map a global GPU index back to its (server, local) identity.
    pub fn gpu_from_global(&self, global: usize) -> GpuId {
        debug_assert!(global < self.num_gpus());
        // binary search over prefix sums
        let s = match self.gpu_base.binary_search(&global) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        GpuId { server: ServerId(s), index: global - self.gpu_base[s], global }
    }

    /// All GPUs of a server.
    pub fn gpus_of(&self, s: ServerId) -> impl Iterator<Item = GpuId> + '_ {
        (0..self.capacity(s)).map(move |i| self.global_gpu(s, i))
    }

    /// All GPUs in the cluster in global-id order.
    pub fn all_gpus(&self) -> impl Iterator<Item = GpuId> + '_ {
        self.server_ids().flat_map(move |s| self.gpus_of(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_cluster_counts() {
        let c = Cluster::uniform(4, 8, 1.0, 25.0);
        assert_eq!(c.num_servers(), 4);
        assert_eq!(c.num_gpus(), 32);
        assert_eq!(c.max_capacity(), 8);
        for s in c.server_ids() {
            assert_eq!(c.capacity(s), 8);
        }
    }

    #[test]
    fn heterogeneous_global_ids_roundtrip() {
        let c = Cluster::new(&[4, 16, 8, 32], 1.0, 25.0);
        assert_eq!(c.num_gpus(), 60);
        for g in 0..c.num_gpus() {
            let gpu = c.gpu_from_global(g);
            assert_eq!(gpu.global, g);
            let back = c.global_gpu(gpu.server, gpu.index);
            assert_eq!(back, gpu);
        }
    }

    #[test]
    fn paper_cluster_is_seeded() {
        let a = Cluster::paper(7);
        let b = Cluster::paper(7);
        let caps_a: Vec<_> = a.servers().map(|s| s.capacity()).collect();
        let caps_b: Vec<_> = b.servers().map(|s| s.capacity()).collect();
        assert_eq!(caps_a, caps_b);
        assert_eq!(a.num_servers(), 20);
        assert!(caps_a.iter().all(|c| [4, 8, 16, 32].contains(c)));
    }

    #[test]
    fn bandwidth_ordering() {
        let c = Cluster::paper(0);
        assert!(c.intra_bw > c.inter_bw, "paper assumes b^i >> b^e");
    }

    #[test]
    #[should_panic]
    fn empty_cluster_rejected() {
        Cluster::new(&[], 1.0, 2.0);
    }

    #[test]
    fn default_topology_is_flat() {
        let c = Cluster::uniform(4, 8, 1.0, 25.0);
        assert!(!c.topology().has_racks());
        assert_eq!(c.topology().num_servers(), 4);
    }

    #[test]
    fn with_topology_mounts_a_rack_tier() {
        let c = Cluster::uniform(4, 8, 1.0, 25.0)
            .with_topology(crate::topology::Topology::racks(4, 2, 2.0));
        assert!(c.topology().has_racks());
        assert_eq!(c.topology().num_racks(), 2);
    }

    #[test]
    #[should_panic]
    fn mismatched_topology_rejected() {
        let _ = Cluster::uniform(4, 8, 1.0, 25.0)
            .with_topology(crate::topology::Topology::flat(5));
    }
}
