//! Job placements: the per-job slice of a scheduling decision `y[t]`.
//!
//! Under gang scheduling (paper Eq. 3) a job's placement is fixed from its
//! start slot `a_j` to its completion `T_j`, so a placement is a *static*
//! assignment of GPUs rather than a per-slot function.

use super::{Cluster, GpuId, ServerId};
use std::collections::BTreeMap;

/// The set of GPUs allocated to one job — `y_j = [y_js, ∀s]` plus the
/// concrete GPU identities (needed for per-GPU execution-time accounting and
/// for driving the live RAR engine).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobPlacement {
    /// GPUs in ring order. The RAR ring visits GPUs in this order; workers
    /// on the same server are contiguous so the ring crosses each server
    /// boundary the minimum number of times.
    gpus: Vec<GpuId>,
    /// `y_js`: number of GPUs on each used server (no zero entries).
    per_server: BTreeMap<ServerId, usize>,
}

impl JobPlacement {
    /// Build a placement from a GPU list. GPUs are re-ordered so that
    /// same-server workers are contiguous in the ring (the natural placement
    /// the paper's Fig. 2 depicts, minimising inter-server hops).
    pub fn new(mut gpus: Vec<GpuId>) -> Self {
        assert!(!gpus.is_empty(), "placement must contain at least one GPU");
        gpus.sort_by_key(|g| (g.server, g.index));
        // Reject duplicate GPUs (each GPU hosts at most one worker, Eq. 2).
        for w in gpus.windows(2) {
            assert!(w[0] != w[1], "duplicate GPU in placement: {}", w[0]);
        }
        let mut per_server = BTreeMap::new();
        for g in &gpus {
            *per_server.entry(g.server).or_insert(0) += 1;
        }
        JobPlacement { gpus, per_server }
    }

    /// Number of workers `w_j` (== requested GPUs `G_j` under gang sched).
    pub fn num_workers(&self) -> usize {
        self.gpus.len()
    }

    /// `y_js` for server `s` (0 if unused).
    pub fn gpus_on(&self, s: ServerId) -> usize {
        self.per_server.get(&s).copied().unwrap_or(0)
    }

    /// Servers used by this job, i.e. `{s : y_js > 0}`.
    pub fn servers(&self) -> impl Iterator<Item = ServerId> + '_ {
        self.per_server.keys().copied()
    }

    /// `Σ_s 1{y_js > 0}` — the server span driving the communication
    /// overhead term γ_j (paper §4.1 2-3).
    pub fn span(&self) -> usize {
        self.per_server.len()
    }

    /// True iff the job uses inter-server communication, i.e. there exists a
    /// server with `0 < y_js < G_j` (the indicator in Eq. 6).
    pub fn is_spread(&self) -> bool {
        self.span() > 1
    }

    /// True iff this job's ring crosses server `s`'s inter-server link while
    /// *not* being fully contained in `s`: the Eq. 6 indicator
    /// `1{0 < y_js < G_j}`.
    pub fn uses_uplink_of(&self, s: ServerId) -> bool {
        let y = self.gpus_on(s);
        y > 0 && y < self.num_workers()
    }

    /// GPUs in ring order.
    pub fn gpus(&self) -> &[GpuId] {
        &self.gpus
    }

    /// Ring links as (upstream, downstream) pairs — `L_j` in the paper.
    /// A single-worker "ring" has no links.
    pub fn ring_links(&self) -> Vec<(GpuId, GpuId)> {
        if self.gpus.len() < 2 {
            return Vec::new();
        }
        let mut links = Vec::with_capacity(self.gpus.len());
        for i in 0..self.gpus.len() {
            links.push((self.gpus[i], self.gpus[(i + 1) % self.gpus.len()]));
        }
        links
    }

    /// Number of ring links that cross servers (inter-server hops).
    pub fn inter_server_hops(&self) -> usize {
        self.ring_links().iter().filter(|(a, b)| a.server != b.server).count()
    }
}

/// Incrementally builds a placement while checking capacity constraints
/// against a cluster — used by the placement subroutines (Alg. 2/3).
#[derive(Debug)]
pub struct PlacementBuilder<'c> {
    cluster: &'c Cluster,
    gpus: Vec<GpuId>,
}

impl<'c> PlacementBuilder<'c> {
    pub fn new(cluster: &'c Cluster) -> Self {
        PlacementBuilder { cluster, gpus: Vec::new() }
    }

    /// Add one GPU; panics if it does not belong to the cluster.
    pub fn push(&mut self, gpu: GpuId) -> &mut Self {
        debug_assert!(gpu.global < self.cluster.num_gpus());
        debug_assert_eq!(self.cluster.global_gpu(gpu.server, gpu.index), gpu);
        self.gpus.push(gpu);
        self
    }

    pub fn len(&self) -> usize {
        self.gpus.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gpus.is_empty()
    }

    pub fn build(self) -> JobPlacement {
        JobPlacement::new(self.gpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> Cluster {
        Cluster::uniform(3, 4, 1.0, 25.0)
    }

    fn gpu(c: &Cluster, s: usize, i: usize) -> GpuId {
        c.global_gpu(ServerId(s), i)
    }

    #[test]
    fn colocated_placement() {
        let c = cluster();
        let p = JobPlacement::new(vec![gpu(&c, 1, 0), gpu(&c, 1, 1), gpu(&c, 1, 2)]);
        assert_eq!(p.num_workers(), 3);
        assert_eq!(p.span(), 1);
        assert!(!p.is_spread());
        assert!(!p.uses_uplink_of(ServerId(1)));
        assert_eq!(p.inter_server_hops(), 0);
    }

    #[test]
    fn spread_placement() {
        let c = cluster();
        let p = JobPlacement::new(vec![gpu(&c, 0, 0), gpu(&c, 0, 1), gpu(&c, 2, 0)]);
        assert_eq!(p.span(), 2);
        assert!(p.is_spread());
        assert!(p.uses_uplink_of(ServerId(0)));
        assert!(p.uses_uplink_of(ServerId(2)));
        assert!(!p.uses_uplink_of(ServerId(1)));
        // ring: s0g0 -> s0g1 -> s2g0 -> s0g0: two inter-server hops
        assert_eq!(p.inter_server_hops(), 2);
    }

    #[test]
    fn ring_links_wrap_around() {
        let c = cluster();
        let p = JobPlacement::new(vec![gpu(&c, 0, 0), gpu(&c, 1, 0), gpu(&c, 2, 0)]);
        let links = p.ring_links();
        assert_eq!(links.len(), 3);
        assert_eq!(links[2].1, links[0].0, "ring closes");
        assert_eq!(p.inter_server_hops(), 3);
    }

    #[test]
    fn single_worker_has_no_links() {
        let c = cluster();
        let p = JobPlacement::new(vec![gpu(&c, 0, 0)]);
        assert!(p.ring_links().is_empty());
        assert!(!p.is_spread());
    }

    #[test]
    #[should_panic]
    fn duplicate_gpu_rejected() {
        let c = cluster();
        JobPlacement::new(vec![gpu(&c, 0, 0), gpu(&c, 0, 0)]);
    }

    #[test]
    fn builder_checks_membership() {
        let c = cluster();
        let mut b = PlacementBuilder::new(&c);
        b.push(gpu(&c, 0, 0)).push(gpu(&c, 0, 1));
        assert_eq!(b.len(), 2);
        let p = b.build();
        assert_eq!(p.num_workers(), 2);
    }
}
