//! Live occupancy state of the cluster during simulation / coordination.

use super::{Cluster, GpuId, JobPlacement, ServerId};
use crate::jobs::JobId;

/// Tracks which job (if any) occupies each GPU — enforcing the packing
/// constraint Eq. 2 ("each GPU can only be occupied by one worker of some
/// job at any given time") — plus the component **health** layer the
/// fault model needs: a crashed server or a permanently failed GPU drops
/// out of the schedulable pool ([`is_free`](Self::is_free) is
/// free-AND-healthy), and the per-server free counts track only healthy
/// GPUs. With no faults injected every mask stays false and the
/// occupancy behaviour is exactly the pre-fault one.
#[derive(Debug, Clone)]
pub struct ClusterState {
    /// `owner[global_gpu_id] = Some(job)` while occupied.
    owner: Vec<Option<JobId>>,
    /// Free *healthy* GPU count per server (derived, O(1) queries).
    free_per_server: Vec<usize>,
    /// Permanently failed GPUs (no per-GPU recovery).
    down_gpu: Vec<bool>,
    /// Servers currently in a crash outage.
    server_down: Vec<bool>,
    /// Individually failed GPUs per server (restores server recovery
    /// without rescanning the mask).
    down_per_server: Vec<usize>,
    /// Total healthy GPUs right now (outages and permanent failures).
    healthy: usize,
    /// Total GPUs that can ever be healthy again (nominal minus permanent
    /// failures; ignores in-flight outages).
    potential: usize,
}

impl ClusterState {
    pub fn new(cluster: &Cluster) -> Self {
        let total = cluster.num_gpus();
        ClusterState {
            owner: vec![None; total],
            free_per_server: cluster.servers().map(|s| s.capacity()).collect(),
            down_gpu: vec![false; total],
            server_down: vec![false; cluster.num_servers()],
            down_per_server: vec![0; cluster.num_servers()],
            healthy: total,
            potential: total,
        }
    }

    /// Number of free healthy GPUs on server `s`.
    pub fn free_on(&self, s: ServerId) -> usize {
        self.free_per_server[s.0]
    }

    /// Total free healthy GPUs in the cluster.
    pub fn total_free(&self) -> usize {
        self.free_per_server.iter().sum()
    }

    /// Is this specific GPU free (unoccupied AND healthy)?
    pub fn is_free(&self, gpu: GpuId) -> bool {
        self.owner[gpu.global].is_none() && self.is_healthy(gpu)
    }

    /// Is this GPU schedulable at all (server up, not permanently failed)?
    pub fn is_healthy(&self, gpu: GpuId) -> bool {
        !self.down_gpu[gpu.global] && !self.server_down[gpu.server.0]
    }

    /// Is server `s` in a crash outage?
    pub fn server_is_down(&self, s: ServerId) -> bool {
        self.server_down[s.0]
    }

    /// GPUs currently schedulable (nominal minus outages and permanent
    /// failures) — the surviving capacity window accounting normalizes by.
    pub fn healthy_gpus(&self) -> usize {
        self.healthy
    }

    /// GPUs that can ever be schedulable again (nominal minus permanent
    /// failures only): the bound admission re-projection rejects against —
    /// a crashed server may recover, a failed GPU never does.
    pub fn potential_gpus(&self) -> usize {
        self.potential
    }

    /// Server `s` crashes: its GPUs leave the pool. Resident gangs must
    /// already have been killed (released) — occupancy on a crashing
    /// server is a caller bug.
    pub fn set_server_down(&mut self, cluster: &Cluster, s: ServerId) {
        if self.server_down[s.0] {
            return;
        }
        debug_assert!(
            cluster.gpus_of(s).all(|g| self.owner[g.global].is_none()),
            "server {s:?} crashed with resident workers not yet killed"
        );
        self.server_down[s.0] = true;
        self.healthy -= cluster.capacity(s) - self.down_per_server[s.0];
        self.free_per_server[s.0] = 0;
    }

    /// Server `s` recovers: its GPUs (minus permanent failures) rejoin the
    /// pool, all free.
    pub fn set_server_up(&mut self, cluster: &Cluster, s: ServerId) {
        if !self.server_down[s.0] {
            return;
        }
        self.server_down[s.0] = false;
        let back = cluster.capacity(s) - self.down_per_server[s.0];
        self.healthy += back;
        debug_assert!(cluster.gpus_of(s).all(|g| self.owner[g.global].is_none()));
        self.free_per_server[s.0] = back;
    }

    /// GPU `gpu` fails permanently. The resident gang, if any, must
    /// already have been killed (released).
    pub fn fail_gpu(&mut self, gpu: GpuId) {
        if self.down_gpu[gpu.global] {
            return;
        }
        debug_assert!(
            self.owner[gpu.global].is_none(),
            "GPU {gpu} failed with its resident worker not yet killed"
        );
        self.down_gpu[gpu.global] = true;
        self.down_per_server[gpu.server.0] += 1;
        self.potential -= 1;
        if !self.server_down[gpu.server.0] {
            self.healthy -= 1;
            self.free_per_server[gpu.server.0] -= 1;
        }
    }

    /// Owner of a GPU, if any.
    pub fn owner_of(&self, gpu: GpuId) -> Option<JobId> {
        self.owner[gpu.global]
    }

    /// Free GPUs of server `s` in local-index order.
    pub fn free_gpus_of<'a>(
        &'a self,
        cluster: &'a Cluster,
        s: ServerId,
    ) -> impl Iterator<Item = GpuId> + 'a {
        cluster.gpus_of(s).filter(move |g| self.is_free(*g))
    }

    /// Allocate all GPUs of `placement` to `job` (gang allocation, Eq. 1).
    ///
    /// Panics if any GPU is already occupied — schedulers must only emit
    /// feasible placements.
    pub fn allocate(&mut self, job: JobId, placement: &JobPlacement) {
        for g in placement.gpus() {
            assert!(
                self.owner[g.global].is_none(),
                "GPU {} already owned by {:?} while allocating {:?}",
                g,
                self.owner[g.global],
                job
            );
            debug_assert!(self.is_healthy(g), "GPU {g} allocated to {job:?} while down");
            self.owner[g.global] = Some(job);
            self.free_per_server[g.server.0] -= 1;
        }
    }

    /// Release all GPUs of `placement` from `job` (simultaneous release on
    /// completion, paper §4.1).
    pub fn release(&mut self, job: JobId, placement: &JobPlacement) {
        for g in placement.gpus() {
            assert_eq!(
                self.owner[g.global],
                Some(job),
                "GPU {} not owned by {:?} on release",
                g,
                job
            );
            self.owner[g.global] = None;
            // kills always release BEFORE the component is marked down, so
            // a healthy release is the invariant; the guard keeps the free
            // counts consistent even if a caller breaks it
            debug_assert!(self.is_healthy(g), "GPU {g} released while down");
            if self.is_healthy(g) {
                self.free_per_server[g.server.0] += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Cluster, ClusterState) {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let st = ClusterState::new(&c);
        (c, st)
    }

    #[test]
    fn allocate_release_roundtrip() {
        let (c, mut st) = setup();
        assert_eq!(st.total_free(), 8);
        let p = JobPlacement::new(vec![
            c.global_gpu(ServerId(0), 0),
            c.global_gpu(ServerId(0), 1),
            c.global_gpu(ServerId(1), 0),
        ]);
        st.allocate(JobId(0), &p);
        assert_eq!(st.total_free(), 5);
        assert_eq!(st.free_on(ServerId(0)), 2);
        assert_eq!(st.free_on(ServerId(1)), 3);
        assert_eq!(st.owner_of(c.global_gpu(ServerId(0), 0)), Some(JobId(0)));
        st.release(JobId(0), &p);
        assert_eq!(st.total_free(), 8);
    }

    #[test]
    #[should_panic]
    fn double_allocation_panics() {
        let (c, mut st) = setup();
        let p = JobPlacement::new(vec![c.global_gpu(ServerId(0), 0)]);
        st.allocate(JobId(0), &p);
        st.allocate(JobId(1), &p);
    }

    #[test]
    #[should_panic]
    fn release_by_non_owner_panics() {
        let (c, mut st) = setup();
        let p = JobPlacement::new(vec![c.global_gpu(ServerId(0), 0)]);
        st.allocate(JobId(0), &p);
        st.release(JobId(1), &p);
    }

    #[test]
    fn free_gpu_iteration_skips_busy() {
        let (c, mut st) = setup();
        let p = JobPlacement::new(vec![c.global_gpu(ServerId(0), 1)]);
        st.allocate(JobId(3), &p);
        let free: Vec<_> = st.free_gpus_of(&c, ServerId(0)).map(|g| g.index).collect();
        assert_eq!(free, vec![0, 2, 3]);
    }

    #[test]
    fn pristine_state_is_fully_healthy() {
        let (c, st) = setup();
        assert_eq!(st.healthy_gpus(), 8);
        assert_eq!(st.potential_gpus(), 8);
        assert!(!st.server_is_down(ServerId(0)));
        assert!(c.all_gpus().all(|g| st.is_healthy(g)));
    }

    #[test]
    fn server_outage_roundtrip() {
        let (c, mut st) = setup();
        st.set_server_down(&c, ServerId(0));
        assert!(st.server_is_down(ServerId(0)));
        assert_eq!(st.healthy_gpus(), 4);
        assert_eq!(st.potential_gpus(), 8, "outages are recoverable");
        assert_eq!(st.free_on(ServerId(0)), 0);
        assert_eq!(st.total_free(), 4);
        assert!(!st.is_free(c.global_gpu(ServerId(0), 0)));
        assert_eq!(st.free_gpus_of(&c, ServerId(0)).count(), 0);
        // idempotent
        st.set_server_down(&c, ServerId(0));
        assert_eq!(st.healthy_gpus(), 4);
        st.set_server_up(&c, ServerId(0));
        assert_eq!(st.healthy_gpus(), 8);
        assert_eq!(st.free_on(ServerId(0)), 4);
        st.set_server_up(&c, ServerId(0));
        assert_eq!(st.healthy_gpus(), 8);
    }

    #[test]
    fn gpu_failure_is_permanent_across_server_recovery() {
        let (c, mut st) = setup();
        let g = c.global_gpu(ServerId(0), 2);
        st.fail_gpu(g);
        assert_eq!(st.healthy_gpus(), 7);
        assert_eq!(st.potential_gpus(), 7);
        assert_eq!(st.free_on(ServerId(0)), 3);
        assert!(!st.is_free(g));
        // double-failure is a no-op
        st.fail_gpu(g);
        assert_eq!(st.potential_gpus(), 7);
        // outage + recovery brings back everything except the failed GPU
        st.set_server_down(&c, ServerId(0));
        assert_eq!(st.healthy_gpus(), 4);
        st.set_server_up(&c, ServerId(0));
        assert_eq!(st.healthy_gpus(), 7);
        assert_eq!(st.free_on(ServerId(0)), 3);
        assert!(!st.is_healthy(g));
        let free: Vec<_> = st.free_gpus_of(&c, ServerId(0)).map(|g| g.index).collect();
        assert_eq!(free, vec![0, 1, 3]);
    }

    #[test]
    fn fail_gpu_during_outage_defers_the_free_count_hit() {
        let (c, mut st) = setup();
        st.set_server_down(&c, ServerId(1));
        st.fail_gpu(c.global_gpu(ServerId(1), 0));
        assert_eq!(st.healthy_gpus(), 4);
        assert_eq!(st.potential_gpus(), 7);
        st.set_server_up(&c, ServerId(1));
        assert_eq!(st.healthy_gpus(), 7);
        assert_eq!(st.free_on(ServerId(1)), 3);
    }

    #[test]
    fn occupancy_and_health_compose() {
        let (c, mut st) = setup();
        let p = JobPlacement::new(vec![c.global_gpu(ServerId(1), 0)]);
        st.allocate(JobId(0), &p);
        // kill-then-crash: release first (healthy), then mark down
        st.release(JobId(0), &p);
        st.set_server_down(&c, ServerId(1));
        assert_eq!(st.total_free(), 4);
        // allocation on the surviving server still works
        let p2 = JobPlacement::new(vec![c.global_gpu(ServerId(0), 0)]);
        st.allocate(JobId(1), &p2);
        assert_eq!(st.total_free(), 3);
    }
}
