//! Live occupancy state of the cluster during simulation / coordination.

use super::{Cluster, GpuId, JobPlacement, ServerId};
use crate::jobs::JobId;

/// Tracks which job (if any) occupies each GPU — enforcing the packing
/// constraint Eq. 2 ("each GPU can only be occupied by one worker of some
/// job at any given time").
#[derive(Debug, Clone)]
pub struct ClusterState {
    /// `owner[global_gpu_id] = Some(job)` while occupied.
    owner: Vec<Option<JobId>>,
    /// Free-GPU count per server (derived, kept in sync for O(1) queries).
    free_per_server: Vec<usize>,
}

impl ClusterState {
    pub fn new(cluster: &Cluster) -> Self {
        ClusterState {
            owner: vec![None; cluster.num_gpus()],
            free_per_server: cluster.servers().map(|s| s.capacity()).collect(),
        }
    }

    /// Number of free GPUs on server `s`.
    pub fn free_on(&self, s: ServerId) -> usize {
        self.free_per_server[s.0]
    }

    /// Total free GPUs in the cluster.
    pub fn total_free(&self) -> usize {
        self.free_per_server.iter().sum()
    }

    /// Is this specific GPU free?
    pub fn is_free(&self, gpu: GpuId) -> bool {
        self.owner[gpu.global].is_none()
    }

    /// Owner of a GPU, if any.
    pub fn owner_of(&self, gpu: GpuId) -> Option<JobId> {
        self.owner[gpu.global]
    }

    /// Free GPUs of server `s` in local-index order.
    pub fn free_gpus_of<'a>(
        &'a self,
        cluster: &'a Cluster,
        s: ServerId,
    ) -> impl Iterator<Item = GpuId> + 'a {
        cluster.gpus_of(s).filter(move |g| self.is_free(*g))
    }

    /// Allocate all GPUs of `placement` to `job` (gang allocation, Eq. 1).
    ///
    /// Panics if any GPU is already occupied — schedulers must only emit
    /// feasible placements.
    pub fn allocate(&mut self, job: JobId, placement: &JobPlacement) {
        for g in placement.gpus() {
            assert!(
                self.owner[g.global].is_none(),
                "GPU {} already owned by {:?} while allocating {:?}",
                g,
                self.owner[g.global],
                job
            );
            self.owner[g.global] = Some(job);
            self.free_per_server[g.server.0] -= 1;
        }
    }

    /// Release all GPUs of `placement` from `job` (simultaneous release on
    /// completion, paper §4.1).
    pub fn release(&mut self, job: JobId, placement: &JobPlacement) {
        for g in placement.gpus() {
            assert_eq!(
                self.owner[g.global],
                Some(job),
                "GPU {} not owned by {:?} on release",
                g,
                job
            );
            self.owner[g.global] = None;
            self.free_per_server[g.server.0] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Cluster, ClusterState) {
        let c = Cluster::uniform(2, 4, 1.0, 25.0);
        let st = ClusterState::new(&c);
        (c, st)
    }

    #[test]
    fn allocate_release_roundtrip() {
        let (c, mut st) = setup();
        assert_eq!(st.total_free(), 8);
        let p = JobPlacement::new(vec![
            c.global_gpu(ServerId(0), 0),
            c.global_gpu(ServerId(0), 1),
            c.global_gpu(ServerId(1), 0),
        ]);
        st.allocate(JobId(0), &p);
        assert_eq!(st.total_free(), 5);
        assert_eq!(st.free_on(ServerId(0)), 2);
        assert_eq!(st.free_on(ServerId(1)), 3);
        assert_eq!(st.owner_of(c.global_gpu(ServerId(0), 0)), Some(JobId(0)));
        st.release(JobId(0), &p);
        assert_eq!(st.total_free(), 8);
    }

    #[test]
    #[should_panic]
    fn double_allocation_panics() {
        let (c, mut st) = setup();
        let p = JobPlacement::new(vec![c.global_gpu(ServerId(0), 0)]);
        st.allocate(JobId(0), &p);
        st.allocate(JobId(1), &p);
    }

    #[test]
    #[should_panic]
    fn release_by_non_owner_panics() {
        let (c, mut st) = setup();
        let p = JobPlacement::new(vec![c.global_gpu(ServerId(0), 0)]);
        st.allocate(JobId(0), &p);
        st.release(JobId(1), &p);
    }

    #[test]
    fn free_gpu_iteration_skips_busy() {
        let (c, mut st) = setup();
        let p = JobPlacement::new(vec![c.global_gpu(ServerId(0), 1)]);
        st.allocate(JobId(3), &p);
        let free: Vec<_> = st.free_gpus_of(&c, ServerId(0)).map(|g| g.index).collect();
        assert_eq!(free, vec![0, 2, 3]);
    }
}
