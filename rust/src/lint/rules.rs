//! The `archlint` rule set — one rule per architecture invariant (see
//! ROADMAP.md "Architecture invariants"). Each rule is a lexical check
//! over a [`LexedFile`]; all diagnostics are `file:line` findings that
//! can be suppressed by a `// archlint: allow(<rule>) <reason>`
//! annotation on the line (trailing or directly above) or above the
//! enclosing `fn`.
//!
//! | rule | invariant |
//! |---|---|
//! | `choke-point` | fabric/rate semantics join at `Topology::multiplier` |
//! | `obs-passivity` | obs hooks never feed a decision; arming is free |
//! | `release-panic` | hot paths return `Option`/sentinels, not panics |
//! | `nondeterminism` | no hash-order iteration or unguarded float→int |
//! | `active-memory` | online-loop memory stays O(active), not O(trace) |
//! | `allow-audit` | annotations name real rules and carry a reason |

use super::lexer::{find_word, has_word, LexedFile};

/// One diagnostic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path as scanned (diagnostics print it verbatim).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule name from [`RULES`].
    pub rule: &'static str,
    pub message: String,
}

/// Static rule metadata, used by `--list-rules` and the JSON report.
pub struct RuleInfo {
    pub name: &'static str,
    /// The architecture invariant the rule mechanizes, one line.
    pub invariant: &'static str,
}

/// Every rule, in reporting order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: "choke-point",
        invariant: "oversubscription/capacity-ratio arithmetic lives in topology/ and net/; \
                    everything else consumes Topology::multiplier / Bottleneck::effective()",
    },
    RuleInfo {
        name: "obs-passivity",
        invariant: "obs hook results never bind into scheduler code, and trace::instant \
                    sites sit behind the armed() fast path",
    },
    RuleInfo {
        name: "release-panic",
        invariant: "release-reachable hot paths (sim/, online/, contention/, net/, \
                    topology/, faults/) use Option/sentinel returns, not \
                    unwrap/expect/panic or unaudited slice indexing",
    },
    RuleInfo {
        name: "nondeterminism",
        invariant: "no HashMap/HashSet iteration order and no unguarded saturating \
                    float→int casts on outcome or emission paths",
    },
    RuleInfo {
        name: "active-memory",
        invariant: "online-loop collections grow only through the Running set, the \
                    pending queue or the RunSink seam; debug_assert! bodies are \
                    side-effect-free",
    },
    RuleInfo {
        name: "allow-audit",
        invariant: "every archlint annotation names known rules and records a reason",
    },
];

/// Modules where a release-reachable panic is a finding (the PR 3 bug
/// class): the simulator, the online loop, the contention fabric, and
/// the fault-injection stream (merged into the online hot loop).
const HOT_MODULES: &[&str] = &["sim", "online", "contention", "net", "topology", "faults"];

/// Modules the obs-passivity rule patrols (where scheduler decisions
/// are made — fault recovery placement included).
const OBS_MODULES: &[&str] = &["sim", "online", "sched", "contention", "net", "faults"];

/// Modules exempt from the choke-point rule: the two that *implement*
/// capacity semantics, plus passive/reporting and self-referential code.
const CHOKE_EXEMPT: &[&str] = &["topology", "net", "obs", "util", "lint"];

/// Integer cast targets for the float→int check.
const INT_TYPES: &[&str] =
    &["usize", "u8", "u16", "u32", "u64", "u128", "isize", "i8", "i16", "i32", "i64", "i128"];

/// Run every rule over `f`, then filter findings through the allow
/// annotations. Returns the surviving findings (sorted by line) and a
/// used-flag per entry of `f.allows`, so the caller can census used vs
/// stale annotations.
pub fn check_file(f: &LexedFile) -> (Vec<Finding>, Vec<bool>) {
    let mut raw = Vec::new();
    rule_choke_point(f, &mut raw);
    rule_obs_passivity(f, &mut raw);
    rule_release_panic(f, &mut raw);
    rule_nondeterminism(f, &mut raw);
    rule_active_memory(f, &mut raw);

    let mut used = vec![false; f.allows.len()];
    let mut kept: Vec<Finding> = Vec::new();
    for finding in raw {
        match f.allow_covering(finding.rule, finding.line) {
            Some(i) => {
                if let Some(slot) = used.get_mut(i) {
                    *slot = true;
                }
            }
            None => kept.push(finding),
        }
    }
    // allow-audit runs last and is not itself suppressible
    rule_allow_audit(f, &mut kept);
    kept.sort_by(|a, b| a.line.cmp(&b.line).then_with(|| a.rule.cmp(b.rule)));
    (kept, used)
}

fn emit(out: &mut Vec<Finding>, f: &LexedFile, line: usize, rule: &'static str, msg: String) {
    out.push(Finding { file: f.path.clone(), line, rule, message: msg });
}

// ---------------------------------------------------------------------
// rule 1: choke-point
// ---------------------------------------------------------------------

fn rule_choke_point(f: &LexedFile, out: &mut Vec<Finding>) {
    if CHOKE_EXEMPT.contains(&f.module()) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        let arithmetic = code.contains('*') || code.contains('/');
        if !arithmetic {
            continue;
        }
        if code.contains(".oversub") {
            emit(
                out,
                f,
                i + 1,
                "choke-point",
                "oversubscription arithmetic outside topology//net/ — consume \
                 Topology::multiplier or Bottleneck::effective() instead"
                    .to_string(),
            );
        } else if code.contains("_gbps(") {
            emit(
                out,
                f,
                i + 1,
                "choke-point",
                "capacity-ratio arithmetic outside topology//net/ — route Gbps math \
                 through net:: or Topology accessors at the choke point"
                    .to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------------
// rule 2: obs-passivity
// ---------------------------------------------------------------------

/// Obs namespaces whose *results* must not bind into scheduler code.
const OBS_PREFIXES: &[&str] = &[
    "obs::",
    "trace::",
    "metrics::",
    "explain::",
    "timeline::",
    "ledger::",
    "prof::",
    "crate::obs",
];

fn rule_obs_passivity(f: &LexedFile, out: &mut Vec<Finding>) {
    if !OBS_MODULES.contains(&f.module()) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        // (a) obs result bound to a live (non-`_`) variable
        if let Some(eq) = assignment_pos(code) {
            let rhs = code[eq + 1..].trim_start();
            if OBS_PREFIXES.iter().any(|p| rhs.starts_with(p)) {
                let name = super::lexer::binding_name(code);
                let live = name.as_deref().map_or(true, |n| !n.starts_with('_'));
                if live && rhs.starts_with("trace::span(") {
                    emit(
                        out,
                        f,
                        i + 1,
                        "obs-passivity",
                        "span guard must bind to a `_`-prefixed variable (RAII close, \
                         never read back)"
                            .to_string(),
                    );
                } else if live {
                    emit(
                        out,
                        f,
                        i + 1,
                        "obs-passivity",
                        "obs hook result bound to a live variable in scheduler code — \
                         instrumentation must only read state, never feed a decision"
                            .to_string(),
                    );
                }
            }
        }
        // (b) instant events outside the armed() fast path
        if code.contains("trace::instant(") && !line.in_armed_guard {
            emit(
                out,
                f,
                i + 1,
                "obs-passivity",
                "trace::instant call site must sit inside an `if …armed()` guard (the \
                 disarmed fast path is one relaxed load)"
                    .to_string(),
            );
        }
    }
}

/// Byte position of a plain `=` assignment (not `==`, `!=`, `<=`, `>=`,
/// `=>`, or compound `+=`-style operators); `None` if the line has none.
fn assignment_pos(code: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'=' {
            continue;
        }
        let prev = if i == 0 { b' ' } else { bytes[i - 1] };
        let next = bytes.get(i + 1).copied().unwrap_or(b' ');
        if matches!(prev, b'=' | b'!' | b'<' | b'>' | b'+' | b'-' | b'*' | b'/' | b'%' | b'&' | b'|' | b'^') {
            continue;
        }
        if next == b'=' || next == b'>' {
            continue;
        }
        return Some(i);
    }
    None
}

// ---------------------------------------------------------------------
// rule 3: release-panic
// ---------------------------------------------------------------------

/// Panic tokens searched verbatim in cleaned code.
const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

fn rule_release_panic(f: &LexedFile, out: &mut Vec<Finding>) {
    if !HOT_MODULES.contains(&f.module()) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test || line.in_cfg_debug || line.in_debug_assert {
            continue;
        }
        let code = line.code.as_str();
        for tok in PANIC_TOKENS {
            if code.contains(tok) {
                emit(
                    out,
                    f,
                    i + 1,
                    "release-panic",
                    format!(
                        "`{tok}` is release-reachable in a hot-path module — return \
                         Option/a sentinel (PR 3 tracker precedent) or annotate why it \
                         cannot fire"
                    ),
                );
            }
        }
        for content in index_sites(code) {
            if blessed_index(&content) {
                continue;
            }
            emit(
                out,
                f,
                i + 1,
                "release-panic",
                format!(
                    "slice indexing `[{content}]` can panic in release — use get()/the \
                     dense-id idiom (`v[id.0]`, sized at construction) or annotate the \
                     bound argument"
                ),
            );
        }
    }
}

/// Bracket contents of every index expression on the line: a `[` that
/// directly follows an identifier char, `)` or `]`. Unterminated
/// brackets (expression continues on the next line) yield `…`.
fn index_sites(code: &str) -> Vec<String> {
    let bytes = code.as_bytes();
    let mut sites = Vec::new();
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'[' || i == 0 {
            continue;
        }
        let prev = bytes[i - 1] as char;
        if !(prev.is_ascii_alphanumeric() || prev == '_' || prev == ')' || prev == ']') {
            continue;
        }
        // find the matching close on this line
        let mut depth = 1usize;
        let mut end = None;
        for (j, &c) in bytes.iter().enumerate().skip(i + 1) {
            if c == b'[' {
                depth += 1;
            } else if c == b']' {
                depth -= 1;
                if depth == 0 {
                    end = Some(j);
                    break;
                }
            }
        }
        match end {
            Some(j) => sites.push(code[i + 1..j].trim().to_string()),
            None => sites.push("…".to_string()),
        }
    }
    sites
}

/// The house dense-id idiom: indexing by a newtype id (`v[l.0]`,
/// `v[job.0]`) or a global GPU ordinal (`busy[g.global]`) into a vector
/// sized at construction. Documented in ROADMAP.md; everything else
/// must justify its bound.
fn blessed_index(content: &str) -> bool {
    let ok_chars = content.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.');
    ok_chars && (content.ends_with(".0") || content.ends_with(".global")) && content.len() > 2
}

// ---------------------------------------------------------------------
// rule 4: nondeterminism
// ---------------------------------------------------------------------

/// Iteration forms whose order is hash-seeded.
const HASH_ITER: &[&str] =
    &[".iter()", ".iter_mut()", ".into_iter()", ".keys()", ".values()", ".values_mut()", ".drain("];

/// Float-producing method tails that make a cast source fractional.
const FLOAT_METHODS: &[&str] = &[".floor()", ".ceil()", ".round()", ".sqrt()", ".ln()", ".exp()"];

fn rule_nondeterminism(f: &LexedFile, out: &mut Vec<Finding>) {
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        // (a) hash-order iteration over a declared HashMap/HashSet
        for name in &f.hash_names {
            let Some(at) = find_word(code, name) else { continue };
            let after = &code[at + name.len()..];
            if HASH_ITER.iter().any(|p| after.starts_with(p)) {
                emit(
                    out,
                    f,
                    i + 1,
                    "nondeterminism",
                    format!(
                        "iteration over hash-ordered `{name}` — outcomes and emissions \
                         must not depend on hash order (use BTreeMap/Vec or sort first)"
                    ),
                );
            } else if let Some(inpos) = find_word(code, "in") {
                let tail = code[inpos + 2..].trim_start();
                let tail = tail.strip_prefix("&mut ").unwrap_or(tail);
                let tail = tail.strip_prefix('&').unwrap_or(tail);
                let matches_name = tail.starts_with(name.as_str())
                    && !tail[name.len()..]
                        .chars()
                        .next()
                        .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
                if matches_name && has_word(code, "for") {
                    emit(
                        out,
                        f,
                        i + 1,
                        "nondeterminism",
                        format!("`for … in {name}` iterates in hash order"),
                    );
                }
            }
        }
        // (b) unguarded saturating float→int `as` casts
        for (pos, _ty) in int_cast_sites(code) {
            let src = code[..pos].trim_end();
            if !float_source(src, &f.float_names) {
                continue;
            }
            let guarded = match f.fn_at(i + 1) {
                Some(scope) => f
                    .lines
                    .iter()
                    .take(scope.body_end)
                    .skip(scope.header.saturating_sub(1))
                    .any(|l| l.code.contains("is_finite") || l.code.contains("is_nan")),
                None => false,
            };
            if !guarded {
                emit(
                    out,
                    f,
                    i + 1,
                    "nondeterminism",
                    "float→int `as` cast saturates silently on NaN/∞ — guard the \
                     source with is_finite() and an explicit sentinel (see \
                     sim/kernel.rs::slots_until_done) or annotate the bound"
                        .to_string(),
                );
            }
        }
    }
}

/// Byte positions of ` as <int>` casts on the line, with the target type.
fn int_cast_sites(code: &str) -> Vec<(usize, &'static str)> {
    let mut sites = Vec::new();
    let mut from = 0;
    while let Some(rel) = code.get(from..).and_then(|t| t.find(" as ")) {
        let at = from + rel;
        from = at + 4;
        let target = code[at + 4..].trim_start();
        for ty in INT_TYPES {
            let hit = target.starts_with(ty)
                && !target[ty.len()..]
                    .chars()
                    .next()
                    .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
            if hit {
                sites.push((at, *ty));
                break;
            }
        }
    }
    sites
}

/// Is the expression text ending at a cast fractional? Lexical: a float
/// method tail, a float literal, or a trailing identifier path whose
/// last segment was declared `f64`/`f32` in this file.
fn float_source(src: &str, float_names: &[String]) -> bool {
    let tail_start = src
        .rfind(|c: char| matches!(c, '=' | '(' | ',' | '{' | ';'))
        .map(|i| i + 1)
        .unwrap_or(0);
    let segment = &src[tail_start..];
    if FLOAT_METHODS.iter().any(|m| segment.contains(m)) {
        return true;
    }
    if has_float_literal(segment) {
        return true;
    }
    // trailing identifier path: `r.progress`, `tau`
    let path_start = src
        .rfind(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == '.'))
        .map(|i| i + 1)
        .unwrap_or(0);
    let path = &src[path_start..];
    let last = path.rsplit('.').next().unwrap_or(path);
    !last.is_empty() && float_names.iter().any(|n| n == last)
}

/// Does `s` contain a `1.5`-style float literal (digit, dot, digit)?
fn has_float_literal(s: &str) -> bool {
    let bytes = s.as_bytes();
    bytes.windows(3).any(|w| {
        w[0].is_ascii_digit() && w[1] == b'.' && w[2].is_ascii_digit()
    })
}

// ---------------------------------------------------------------------
// rule 5: active-memory
// ---------------------------------------------------------------------

/// Collection-growth calls patrolled in the online loop.
const GROWTH: &[&str] = &[".push(", ".push_back(", ".insert(", ".extend(", ".append(", ".resize("];

/// Receivers allowed to grow in `online/mod.rs`: the Running set, the
/// pending queue and its spec side-table, slot-recycling state, armed
/// window series, and per-period scratch bounded by the active set.
const ACTIVE_BLESSED: &[&str] = &[
    "running",
    "running_idx",
    "pending",
    "pending_specs",
    "free_slots",
    "windows",
    "gs",
    "busies",
    "servers",
    "by_pressure",
    "queued",
    "sink",
];

/// Mutation shapes that make a `debug_assert!` body unsafe to compile
/// out.
const MUTATIONS: &[&str] = &[
    ".push(",
    ".push_back(",
    ".insert(",
    ".remove(",
    ".pop(",
    ".clear(",
    ".drain(",
    ".extend(",
    ".swap_remove(",
    "+=",
    "-=",
    "*=",
    "/=",
];

fn rule_active_memory(f: &LexedFile, out: &mut Vec<Finding>) {
    let online_loop = f.path.replace('\\', "/").ends_with("online/mod.rs");
    for (i, line) in f.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        // debug_assert! bodies must be side-effect-free (everywhere)
        if line.in_debug_assert {
            let body = match code.find("debug_assert") {
                Some(at) => &code[at..],
                None => code,
            };
            if MUTATIONS.iter().any(|m| body.contains(m)) {
                emit(
                    out,
                    f,
                    i + 1,
                    "active-memory",
                    "debug_assert! body mutates state — the check vanishes in release \
                     builds, taking the side effect with it"
                        .to_string(),
                );
            }
        }
        if !online_loop {
            continue;
        }
        // per-job collection growth outside the blessed receivers
        for g in GROWTH {
            let Some(at) = code.find(g) else { continue };
            let receiver = receiver_name(&code[..at]);
            if ACTIVE_BLESSED.iter().any(|b| *b == receiver) {
                continue;
            }
            // the RunSink seam: sinks choose fold-or-collect themselves
            let in_sink_impl =
                f.impl_at(i + 1).is_some_and(|imp| imp.name.contains("RunSink"));
            if in_sink_impl {
                continue;
            }
            emit(
                out,
                f,
                i + 1,
                "active-memory",
                format!(
                    "`{receiver}{g}…)` grows a collection in the online loop — per-job \
                     state must live in Running/pending (freed on completion) or flow \
                     through the RunSink seam (O(active) memory invariant)"
                ),
            );
        }
    }
}

/// Last path segment of the receiver before a method call:
/// `stats.windows` → `windows`, `self.events` → `events`.
fn receiver_name(before: &str) -> String {
    let start = before
        .rfind(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == '.'))
        .map(|i| i + 1)
        .unwrap_or(0);
    let path = &before[start..];
    path.rsplit('.').next().unwrap_or(path).to_string()
}

// ---------------------------------------------------------------------
// rule 6: allow-audit
// ---------------------------------------------------------------------

fn rule_allow_audit(f: &LexedFile, out: &mut Vec<Finding>) {
    for a in &f.allows {
        if a.rules.is_empty() {
            emit(
                out,
                f,
                a.line,
                "allow-audit",
                "malformed annotation: `archlint: allow(<rule>[, <rule>…]) <reason>`"
                    .to_string(),
            );
            continue;
        }
        for r in &a.rules {
            if !RULES.iter().any(|info| info.name == r) {
                emit(
                    out,
                    f,
                    a.line,
                    "allow-audit",
                    format!("unknown rule `{r}` in allow annotation"),
                );
            }
        }
        if a.reason.len() < 3 {
            emit(
                out,
                f,
                a.line,
                "allow-audit",
                "allow annotation needs a reason after the closing paren".to_string(),
            );
        }
        if a.target == super::lexer::AllowTarget::Dangling {
            emit(
                out,
                f,
                a.line,
                "allow-audit",
                "allow annotation attaches to no code line".to_string(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        check_file(&lex(path, src)).0
    }

    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn index_site_extraction() {
        assert_eq!(index_sites("v[l.0] = x[i + 1];"), vec!["l.0".to_string(), "i + 1".to_string()]);
        assert_eq!(index_sites("#[cfg(test)]"), Vec::<String>::new());
        assert_eq!(index_sites("let t: [u64; 4] = a;"), Vec::<String>::new());
        assert!(blessed_index("l.0"));
        assert!(blessed_index("g.global"));
        assert!(!blessed_index("a.1"));
        assert!(!blessed_index("idx"));
        assert!(!blessed_index("s..e"));
    }

    #[test]
    fn assignment_pos_skips_comparisons() {
        assert!(assignment_pos("if a == b {").is_none());
        assert!(assignment_pos("a <= b; c >= d; e != f").is_none());
        assert!(assignment_pos("x += 1;").is_none());
        assert!(assignment_pos("Some(x) => y,").is_none());
        assert!(assignment_pos("let x = 1;").is_some());
    }

    #[test]
    fn float_source_heuristics() {
        let floats = vec!["progress".to_string()];
        assert!(float_source("let idx = (p / 100.0).round()", &floats));
        assert!(float_source("r.progress", &floats));
        assert!(float_source("x * 1.5", &floats));
        assert!(!float_source("windows.len()", &floats));
        assert!(!float_source("slot", &floats));
    }

    #[test]
    fn choke_point_flags_and_passes() {
        let bad = "fn f(b: &Bottleneck) -> f64 {\n    2.0 * b.oversub\n}\n";
        assert_eq!(rules_of(&findings("rust/src/sim/x.rs", bad)), vec!["choke-point"]);
        // the blessed accessor and exempt modules pass
        let good = "fn f(b: &Bottleneck) -> f64 {\n    2.0 * b.effective()\n}\n";
        assert!(findings("rust/src/sim/x.rs", good).is_empty());
        assert!(findings("rust/src/topology/x.rs", bad).is_empty(), "topology/ is exempt");
    }

    #[test]
    fn obs_passivity_flags_and_passes() {
        let bad = "fn f() {\n    let n = metrics::get(metrics::Counter::X);\n    let _ = n;\n}\n";
        assert_eq!(rules_of(&findings("rust/src/online/x.rs", bad)), vec!["obs-passivity"]);
        let naked = "fn f() {\n    trace::instant(\"e\", \"c\", &[]);\n}\n";
        assert_eq!(rules_of(&findings("rust/src/online/x.rs", naked)), vec!["obs-passivity"]);
        let good = "fn f() {\n    let _span = trace::span(\"e\", \"c\");\n    if trace::armed() {\n        trace::instant(\"e\", \"c\", &[]);\n    }\n}\n";
        assert!(findings("rust/src/online/x.rs", good).is_empty());
        assert!(findings("rust/src/metrics/x.rs", bad).is_empty(), "only decision modules");
        // the flight-recorder namespace is patrolled like the others...
        let led = "fn f() {\n    let c = ledger::QueueCensus { pending: 0 };\n    use_it(c);\n}\n";
        assert_eq!(rules_of(&findings("rust/src/online/x.rs", led)), vec!["obs-passivity"]);
        // ...while unbound hook calls stay clean (the run_core idiom)
        let hook = "fn f(t: u64) {\n    if ledger::checkpoint_due(t) {\n        ledger::checkpoint(t, ledger::QueueCensus::default(), false, Vec::new);\n    }\n    prof::noop();\n}\n";
        assert!(findings("rust/src/online/x.rs", hook).is_empty());
    }

    #[test]
    fn release_panic_flags_and_passes() {
        let bad = "fn f(v: &[u64]) -> u64 {\n    v.first().copied().unwrap()\n}\n";
        assert_eq!(rules_of(&findings("rust/src/online/x.rs", bad)), vec!["release-panic"]);
        let idx = "fn f(v: &[u64], i: usize) -> u64 {\n    v[i + 1]\n}\n";
        assert_eq!(rules_of(&findings("rust/src/net/x.rs", idx)), vec!["release-panic"]);
        let good = "fn f(v: &[u64], l: LinkId) -> u64 {\n    debug_assert!(l.0 < v.len());\n    v[l.0]\n}\n";
        assert!(findings("rust/src/net/x.rs", good).is_empty(), "dense-id idiom is blessed");
        let test_only = "#[cfg(test)]\nmod tests {\n    fn t(v: &[u64]) -> u64 {\n        v[9].max(v.first().copied().unwrap())\n    }\n}\n";
        assert!(findings("rust/src/net/x.rs", test_only).is_empty());
        let annotated = "fn f(v: &[u64], i: usize) -> u64 {\n    v[i % v.len()] // archlint: allow(release-panic) modulo keeps i in range\n}\n";
        assert!(findings("rust/src/net/x.rs", annotated).is_empty());
        assert!(findings("rust/src/sched/x.rs", bad).is_empty(), "only hot-path modules");
    }

    #[test]
    fn nondeterminism_flags_and_passes() {
        let bad = "fn f() {\n    let mut seen = HashMap::new();\n    seen.insert(1, 2);\n    for (k, v) in seen.iter() {\n        emit(k, v);\n    }\n}\n";
        assert_eq!(rules_of(&findings("rust/src/metrics/x.rs", bad)), vec!["nondeterminism"]);
        let cast = "struct S {\n    progress: f64,\n}\nfn f(s: &S) -> u64 {\n    s.progress as u64\n}\n";
        assert_eq!(rules_of(&findings("rust/src/metrics/x.rs", cast)), vec!["nondeterminism"]);
        let guarded = "struct S {\n    progress: f64,\n}\nfn f(s: &S) -> u64 {\n    if !s.progress.is_finite() {\n        return 0;\n    }\n    s.progress as u64\n}\n";
        assert!(findings("rust/src/metrics/x.rs", guarded).is_empty());
        let btree = "fn f() {\n    let mut seen = BTreeMap::new();\n    seen.insert(1, 2);\n    for (k, v) in seen.iter() {\n        emit(k, v);\n    }\n}\n";
        assert!(findings("rust/src/metrics/x.rs", btree).is_empty());
    }

    #[test]
    fn active_memory_flags_and_passes() {
        let bad = "fn run_core() {\n    let mut all_records = Vec::new();\n    all_records.push(1);\n}\n";
        assert_eq!(
            rules_of(&findings("rust/src/online/mod.rs", bad)),
            vec!["active-memory"]
        );
        let blessed = "fn run_core() {\n    let mut pending = Vec::new();\n    pending.push(1);\n    let mut free_slots = Vec::new();\n    free_slots.push(2);\n}\n";
        assert!(findings("rust/src/online/mod.rs", blessed).is_empty());
        let sink_impl = "impl RunSink for CollectSink {\n    fn record(&mut self, r: u64) {\n        self.records.push(r);\n    }\n}\n";
        assert!(findings("rust/src/online/mod.rs", sink_impl).is_empty(), "RunSink seam is the sink's choice");
        let elsewhere = "fn f() {\n    let mut anything = Vec::new();\n    anything.push(1);\n}\n";
        assert!(findings("rust/src/online/tracker.rs", elsewhere).is_empty(), "only the loop file");
        let dbg = "fn f(v: &mut Vec<u64>) {\n    debug_assert!(v.pop().is_some());\n}\n";
        assert_eq!(rules_of(&findings("rust/src/sim/x.rs", dbg)), vec!["active-memory"]);
        let dbg_ok = "fn f(v: &[u64]) {\n    debug_assert!(v.len() > 1);\n}\n";
        assert!(findings("rust/src/sim/x.rs", dbg_ok).is_empty());
    }

    #[test]
    fn allow_audit_flags_unknown_rules_and_missing_reasons() {
        let unknown = "fn f(v: &[u64]) -> u64 {\n    v.first().copied().unwrap_or(0) // archlint: allow(no-such-rule) whatever\n}\n";
        assert_eq!(rules_of(&findings("rust/src/util/x.rs", unknown)), vec!["allow-audit"]);
        let bare = "fn f() {\n    g(); // archlint: allow(release-panic)\n}\n";
        assert_eq!(rules_of(&findings("rust/src/util/x.rs", bare)), vec!["allow-audit"]);
        let fine = "fn f(v: &[u64], i: usize) -> u64 {\n    v[i] // archlint: allow(release-panic) i is bounds-checked by the caller\n}\n";
        assert!(findings("rust/src/online/x.rs", fine).is_empty());
    }

    #[test]
    fn used_allow_census() {
        let src = "fn f(v: &[u64], i: usize) -> u64 {\n    v[i] // archlint: allow(release-panic) bounded by caller\n}\n// archlint: allow(release-panic) stale — nothing fires here\nfn g() -> u64 {\n    0\n}\n";
        let (kept, used) = check_file(&lex("rust/src/online/x.rs", src));
        assert!(kept.is_empty());
        assert_eq!(used, vec![true, false]);
    }
}
