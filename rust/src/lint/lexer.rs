//! Minimal Rust lexer for `archlint` — just enough structure to run the
//! architecture-invariant rules without a real parser (house zero-dep
//! style, like `util::toml_lite`).
//!
//! The lexer makes one character pass and one line pass over a source
//! file and produces a [`LexedFile`]:
//!
//! * per-line **cleaned code** — comments removed, string/char literal
//!   *contents* stripped (so a rule pattern inside a string constant can
//!   never fire), raw strings (`r#"…"#`) and nested block comments
//!   handled — plus the comment text (where `// archlint: allow(…)`
//!   annotations live);
//! * **brace depth** at each line start, exact because braces inside
//!   literals and comments are already gone;
//! * **regions**: `#[cfg(test)]` items, `#[cfg(debug_assertions)]`
//!   items, `debug_assert!`-macro bodies (paren-matched, multi-line),
//!   and `if …armed() { … }` guard bodies;
//! * **scopes**: every `fn` and `impl` item with its body line range, so
//!   rules and allow-annotations can attach to a whole function;
//! * per-file **identifier censuses**: names declared `f64`/`f32`
//!   (feeds the float→int cast rule) and names declared
//!   `HashMap`/`HashSet` (feeds the iteration-order rule).
//!
//! Everything is heuristic but deterministic; the rules it feeds are
//! documented as lexical checks, not type-checked analyses.

/// One source line after cleaning.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Code with comments removed and literal contents stripped.
    pub code: String,
    /// Concatenated `//` comment text on this line (block-comment text
    /// is dropped; annotations must use plain `//` line comments — doc
    /// comments are prose and never parsed as annotations).
    pub comment: String,
    /// Brace depth at the start of the line.
    pub depth: usize,
    /// Inside a `#[cfg(test)]` item (including the attribute line).
    pub in_test: bool,
    /// Inside a `#[cfg(debug_assertions)]` item (including the
    /// attribute line) — compiled out of release builds.
    pub in_cfg_debug: bool,
    /// Inside the parenthesized body of a `debug_assert*!` macro.
    pub in_debug_assert: bool,
    /// Inside the braces of an `if …armed() { … }` guard (or on the
    /// line that opens one).
    pub in_armed_guard: bool,
    /// Innermost enclosing `fn` scope, as an index into
    /// [`LexedFile::scopes`].
    pub fn_scope: Option<usize>,
}

/// What kind of item a [`Scope`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScopeKind {
    Fn,
    Impl,
}

/// A `fn` or `impl` item with a resolved body range.
#[derive(Debug, Clone)]
pub struct Scope {
    pub kind: ScopeKind,
    /// `fn` name, or the `impl` header text (e.g. `impl RunSink for X`).
    pub name: String,
    /// 1-based line of the `fn`/`impl` keyword.
    pub header: usize,
    /// 1-based line of the opening brace.
    pub body_start: usize,
    /// 1-based line of the closing brace (inclusive).
    pub body_end: usize,
    /// Rules allowed for the whole scope by a fn-level annotation.
    pub allowed: Vec<String>,
}

/// Where an allow-annotation applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllowTarget {
    /// A single line (trailing annotation, or standalone above a plain
    /// statement).
    Line(usize),
    /// A whole `fn` body (standalone annotation directly above the
    /// header), as an index into [`LexedFile::scopes`].
    Scope(usize),
    /// The annotation could not be attached (e.g. at end of file).
    Dangling,
}

/// One parsed `// archlint: allow(<rules>) <reason>` annotation.
#[derive(Debug, Clone)]
pub struct Allow {
    /// 1-based line the annotation text sits on.
    pub line: usize,
    /// Rule names inside `allow(…)`, comma-separated in the source.
    pub rules: Vec<String>,
    /// Free-text justification after the closing paren.
    pub reason: String,
    pub target: AllowTarget,
}

/// A lexed source file: lines, scopes, annotations and name censuses.
#[derive(Debug, Clone, Default)]
pub struct LexedFile {
    /// Path as given to [`lex`] (used verbatim in diagnostics).
    pub path: String,
    pub lines: Vec<Line>,
    pub scopes: Vec<Scope>,
    pub allows: Vec<Allow>,
    /// Identifiers declared `f64`/`f32` anywhere in non-test code
    /// (fields, params, lets) — sorted, deduplicated.
    pub float_names: Vec<String>,
    /// Identifiers declared `HashMap`/`HashSet` in non-test code.
    pub hash_names: Vec<String>,
}

impl LexedFile {
    /// The top-level module this file belongs to: the first path segment
    /// under `src/` (`sim`, `online`, …), or the file stem for files
    /// directly in `src/` (`main`, `cli`, …).
    pub fn module(&self) -> &str {
        let norm = self.path.replace('\\', "/");
        let tail = match norm.rfind("/src/") {
            Some(i) => &norm[i + 5..],
            None => norm.as_str(),
        };
        // Borrow from self.path via offsets so the return ties to &self.
        let start = self.path.len() - tail.len();
        let tail = &self.path[start..];
        match tail.find('/') {
            Some(i) => &tail[..i],
            None => tail.strip_suffix(".rs").unwrap_or(tail),
        }
    }

    /// Does an annotation (line-level or fn-level) allow `rule` on
    /// 1-based `line`? Returns the allow's index so callers can track
    /// which annotations were actually used.
    pub fn allow_covering(&self, rule: &str, line: usize) -> Option<usize> {
        for (i, a) in self.allows.iter().enumerate() {
            let rule_match = a.rules.iter().any(|r| r == rule);
            if !rule_match {
                continue;
            }
            match a.target {
                AllowTarget::Line(l) if l == line => return Some(i),
                AllowTarget::Scope(s) => {
                    if let Some(sc) = self.scopes.get(s) {
                        if line >= sc.header && line <= sc.body_end {
                            return Some(i);
                        }
                    }
                }
                _ => {}
            }
        }
        None
    }

    /// The innermost `fn` scope covering 1-based `line`, if any.
    pub fn fn_at(&self, line: usize) -> Option<&Scope> {
        let idx = self.lines.get(line.wrapping_sub(1))?.fn_scope?;
        self.scopes.get(idx)
    }

    /// The innermost `impl` scope covering 1-based `line`, if any.
    pub fn impl_at(&self, line: usize) -> Option<&Scope> {
        let mut best: Option<&Scope> = None;
        for sc in &self.scopes {
            if sc.kind == ScopeKind::Impl && line >= sc.header && line <= sc.body_end {
                let better = match best {
                    Some(b) => sc.header > b.header,
                    None => true,
                };
                if better {
                    best = Some(sc);
                }
            }
        }
        best
    }
}

/// Is `c` part of an identifier?
fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Find `word` in `s` at an identifier boundary; returns the byte
/// offset of the first such occurrence.
pub fn find_word(s: &str, word: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = s.get(from..).and_then(|t| t.find(word)) {
        let at = from + rel;
        let before_ok = at == 0 || !is_ident(s[..at].chars().next_back().unwrap_or(' '));
        let after = at + word.len();
        let after_ok = !s.get(after..).and_then(|t| t.chars().next()).is_some_and(is_ident);
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + word.len().max(1);
    }
    None
}

/// Does `s` contain `word` at an identifier boundary?
pub fn has_word(s: &str, word: &str) -> bool {
    find_word(s, word).is_some()
}

// ---------------------------------------------------------------------
// pass 1: character machine — strip literals and comments
// ---------------------------------------------------------------------

/// Raw per-line output of the character pass.
struct RawLine {
    code: String,
    comment: String,
}

fn strip_pass(text: &str) -> Vec<RawLine> {
    #[derive(PartialEq)]
    enum Mode {
        Code,
        LineComment,
        BlockComment(usize),
        Str,
        RawStr(usize),
    }
    let chars: Vec<char> = text.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(RawLine { code: std::mem::take(&mut code), comment: std::mem::take(&mut comment) });
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied().unwrap_or(' ');
                if c == '/' && next == '/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == '*' {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if c == 'r' && (next == '"' || next == '#') && !ends_with_ident(&code) {
                    // raw string r"…" / r#"…"# (possibly after a `b`)
                    let mut j = i + 1;
                    let mut hashes = 0;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        code.push('"');
                        mode = Mode::RawStr(hashes);
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal vs lifetime
                    let n1 = chars.get(i + 1).copied();
                    let n2 = chars.get(i + 2).copied();
                    if n1 == Some('\\') {
                        // escaped char literal: consume to the closing quote
                        code.push_str("' '");
                        let mut j = i + 2;
                        while j < chars.len() && chars[j] != '\'' {
                            j += 1;
                        }
                        i = j + 1;
                    } else if n2 == Some('\'') && n1.is_some() {
                        code.push_str("' '");
                        i += 3;
                    } else {
                        // lifetime — keep the tick, it is inert for rules
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                let next = chars.get(i + 1).copied().unwrap_or(' ');
                if c == '/' && next == '*' {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == '/' {
                    mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // never swallow a newline: an escaped line break must
                    // still finalize the source line (line numbers!)
                    if chars.get(i + 1) == Some(&'\n') { i += 1 } else { i += 2 }
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes {
                        if chars.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        code.push('"');
                        mode = Mode::Code;
                        i += 1 + hashes;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(RawLine { code, comment });
    lines
}

/// Does the cleaned buffer end mid-identifier? (distinguishes the `r`
/// of `r"…"` from the `r` at the end of `for r` or `var`).
fn ends_with_ident(code: &str) -> bool {
    // The raw-string test looks at the char *before* the candidate `r`.
    code.chars().next_back().is_some_and(is_ident)
}

// ---------------------------------------------------------------------
// pass 2: regions, scopes, annotations
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum RegionKind {
    Test,
    CfgDebug,
    ArmedGuard,
    FnScope(usize),
    ImplScope(usize),
    Other,
}

/// Lex `text` (the contents of `path`) into a [`LexedFile`].
pub fn lex(path: &str, text: &str) -> LexedFile {
    let raw = strip_pass(text);
    let mut out = LexedFile { path: path.to_string(), ..LexedFile::default() };
    let mut scopes: Vec<Scope> = Vec::new();

    // region stack entries: (kind, depth before the opening brace)
    let mut stack: Vec<(RegionKind, usize)> = Vec::new();
    let mut depth = 0usize;
    let mut pending_test = false;
    let mut pending_debug = false;
    let mut pending_guard = false;
    // pending fn/impl header: (kind, name, header line)
    let mut pending_item: Option<(ScopeKind, String, usize)> = None;
    let mut dbg_assert_parens = 0usize;

    for (idx, rl) in raw.iter().enumerate() {
        let lineno = idx + 1;
        let code = rl.code.as_str();
        let mut line = Line {
            depth,
            in_test: pending_test || stack.iter().any(|(k, _)| *k == RegionKind::Test),
            in_cfg_debug: pending_debug
                || stack.iter().any(|(k, _)| *k == RegionKind::CfgDebug),
            in_armed_guard: stack.iter().any(|(k, _)| *k == RegionKind::ArmedGuard),
            in_debug_assert: dbg_assert_parens > 0,
            fn_scope: innermost_fn(&stack),
            ..Line::default()
        };

        // attribute detection (before walking braces: attrs precede items)
        if code.contains("#[cfg(") || code.contains("#[cfg_attr(") {
            if has_word(code, "test") {
                pending_test = true;
                line.in_test = true;
            }
            if has_word(code, "debug_assertions") && !code.contains("not(debug_assertions)") {
                pending_debug = true;
                line.in_cfg_debug = true;
            }
        }
        // armed-guard detection: `if … armed() … {`
        if has_word(code, "if") && code.contains("armed()") {
            pending_guard = true;
        }
        // item headers
        if pending_item.is_none() {
            if let Some(at) = find_word(code, "fn") {
                let rest = &code[at + 2..];
                if let Some(name) = leading_ident(rest) {
                    pending_item = Some((ScopeKind::Fn, name, lineno));
                }
            } else if code.trim_start().starts_with("impl")
                && !is_ident(code.trim_start().chars().nth(4).unwrap_or(' '))
            {
                let header = code.trim().trim_end_matches('{').trim().to_string();
                pending_item = Some((ScopeKind::Impl, header, lineno));
            }
        }
        // debug_assert body start (single region at a time is enough —
        // debug_asserts do not nest in practice)
        if dbg_assert_parens == 0 {
            if let Some(at) = code.find("debug_assert") {
                let tail = &code[at..];
                let mut bal = 0isize;
                let mut opened = false;
                for c in tail.chars() {
                    if c == '(' {
                        bal += 1;
                        opened = true;
                    } else if c == ')' {
                        bal -= 1;
                    }
                }
                line.in_debug_assert = true;
                if opened && bal > 0 {
                    dbg_assert_parens = bal as usize;
                }
            }
        } else {
            let mut bal = dbg_assert_parens as isize;
            for c in code.chars() {
                if c == '(' {
                    bal += 1;
                } else if c == ')' {
                    bal -= 1;
                    if bal == 0 {
                        break;
                    }
                }
            }
            dbg_assert_parens = bal.max(0) as usize;
        }

        // walk braces to maintain depth, open/close regions
        for c in code.chars() {
            if c == '{' {
                let kind = if pending_test {
                    pending_test = false;
                    RegionKind::Test
                } else if pending_debug {
                    pending_debug = false;
                    RegionKind::CfgDebug
                } else if let Some((kind, name, header)) = pending_item.take() {
                    // the item body also consumes any pending guard flag
                    pending_guard = false;
                    let si = scopes.len();
                    scopes.push(Scope {
                        kind,
                        name,
                        header,
                        body_start: lineno,
                        body_end: lineno,
                        allowed: Vec::new(),
                    });
                    match kind {
                        ScopeKind::Fn => RegionKind::FnScope(si),
                        ScopeKind::Impl => RegionKind::ImplScope(si),
                    }
                } else if pending_guard {
                    pending_guard = false;
                    line.in_armed_guard = true;
                    RegionKind::ArmedGuard
                } else {
                    RegionKind::Other
                };
                stack.push((kind, depth));
                depth += 1;
            } else if c == '}' {
                depth = depth.saturating_sub(1);
                while let Some((kind, d)) = stack.last().copied() {
                    if d >= depth {
                        stack.pop();
                        if let RegionKind::FnScope(si) | RegionKind::ImplScope(si) = kind {
                            if let Some(sc) = scopes.get_mut(si) {
                                sc.body_end = lineno;
                            }
                        }
                    } else {
                        break;
                    }
                }
            } else if c == ';' {
                // a `;` before any `{` ends a brace-less attributed item
                // or a trait-method signature
                if stack.last().map_or(true, |(_, d)| *d < depth) {
                    pending_test = false;
                    pending_debug = false;
                    pending_item = None;
                }
            }
        }

        line.code = rl.code.clone();
        line.comment = rl.comment.clone();
        out.lines.push(line);
    }
    // close any scope left open by unbalanced input
    for sc in &mut scopes {
        if sc.body_end < sc.body_start {
            sc.body_end = out.lines.len();
        }
    }
    out.scopes = scopes;
    resolve_allows(&mut out);
    collect_names(&mut out);
    out
}

fn innermost_fn(stack: &[(RegionKind, usize)]) -> Option<usize> {
    stack.iter().rev().find_map(|(k, _)| match k {
        RegionKind::FnScope(i) => Some(*i),
        _ => None,
    })
}

/// First identifier at the start of `s` (after whitespace).
fn leading_ident(s: &str) -> Option<String> {
    let t = s.trim_start();
    let end = t.find(|c: char| !is_ident(c)).unwrap_or(t.len());
    let name = &t[..end];
    let starts_ok = name.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_');
    if starts_ok {
        Some(name.to_string())
    } else {
        None
    }
}

/// Parse `archlint: allow(<rules>) <reason>` annotations out of the
/// comment text and attach each one to a line or fn scope.
fn resolve_allows(f: &mut LexedFile) {
    let marker = "archlint: allow(";
    let n = f.lines.len();
    let mut allows = Vec::new();
    for i in 0..n {
        let comment = f.lines[i].comment.clone();
        // Doc comments (`///` → leading `/`, `//!` → leading `!`) are
        // prose — only plain `//` comments carry annotations, so docs
        // can *describe* the grammar without triggering it.
        let t = comment.trim_start();
        if t.starts_with('/') || t.starts_with('!') {
            continue;
        }
        let at = match comment.find(marker) {
            Some(a) => a,
            None => continue,
        };
        let rest = &comment[at + marker.len()..];
        let (rules_txt, reason) = match rest.find(')') {
            Some(close) => (&rest[..close], rest[close + 1..].trim().to_string()),
            None => (rest, String::new()),
        };
        let rules: Vec<String> = rules_txt
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        let trailing = !f.lines[i].code.trim().is_empty();
        let target = if trailing {
            AllowTarget::Line(i + 1)
        } else {
            // standalone: attach to the next code line, skipping empty,
            // comment-only and attribute lines; fn headers take the
            // whole scope
            let mut t = None;
            for (j, line) in f.lines.iter().enumerate().skip(i + 1) {
                let code = line.code.trim();
                if code.is_empty() || code.starts_with("#[") {
                    continue;
                }
                t = Some(j + 1);
                break;
            }
            match t {
                None => AllowTarget::Dangling,
                Some(target_line) => {
                    let scope = f
                        .scopes
                        .iter()
                        .position(|s| s.kind == ScopeKind::Fn && s.header == target_line);
                    match scope {
                        Some(si) => AllowTarget::Scope(si),
                        None => AllowTarget::Line(target_line),
                    }
                }
            }
        };
        if let AllowTarget::Scope(si) = target {
            if let Some(sc) = f.scopes.get_mut(si) {
                for r in &rules {
                    if !sc.allowed.contains(r) {
                        sc.allowed.push(r.clone());
                    }
                }
            }
        }
        allows.push(Allow { line: i + 1, rules, reason, target });
    }
    f.allows = allows;
}

/// Collect identifiers declared as floats and as hash collections from
/// non-test code (declaration heuristics: `name: f64`, `name: &f64`,
/// `let name = HashMap::new()`, `name: HashMap<…>`).
fn collect_names(f: &mut LexedFile) {
    let mut floats = Vec::new();
    let mut hashes = Vec::new();
    for line in &f.lines {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        for ty in ["f64", "f32"] {
            let mut from = 0;
            while let Some(rel) = code.get(from..).and_then(|t| t.find(ty)) {
                let at = from + rel;
                from = at + ty.len();
                // word boundary on both sides
                let after_ok =
                    !code.get(at + ty.len()..).and_then(|t| t.chars().next()).is_some_and(is_ident);
                if !after_ok {
                    continue;
                }
                if let Some(name) = decl_name_before(code, at) {
                    push_unique(&mut floats, name);
                }
            }
        }
        for ty in ["HashMap", "HashSet"] {
            if let Some(at) = find_word(code, ty) {
                if let Some(name) = decl_name_before(code, at) {
                    push_unique(&mut hashes, name);
                } else if let Some(name) = let_binding_name(code) {
                    // `let [mut] name = HashMap::new()` / `… = HashSet…`
                    let eq = code.find('=');
                    if eq.is_some_and(|e| e < at) {
                        push_unique(&mut hashes, name);
                    }
                }
            }
        }
    }
    floats.sort();
    hashes.sort();
    f.float_names = floats;
    f.hash_names = hashes;
}

fn push_unique(v: &mut Vec<String>, s: String) {
    if !v.contains(&s) {
        v.push(s);
    }
}

/// For a type mention at byte `at`, recover the declared name from the
/// preceding `name: [&][mut] Type` shape, if present.
fn decl_name_before(code: &str, at: usize) -> Option<String> {
    let mut before = code[..at].trim_end();
    for sigil in ["&mut", "&", "mut"] {
        if let Some(stripped) = before.strip_suffix(sigil) {
            before = stripped.trim_end();
        }
    }
    let before = before.strip_suffix(':')?.trim_end();
    if before.ends_with(':') {
        return None; // `…::Type` path position, not a declaration
    }
    let start = before
        .rfind(|c: char| !is_ident(c))
        .map(|i| i + 1)
        .unwrap_or(0);
    let name = &before[start..];
    if name.is_empty() || name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    Some(name.to_string())
}

/// The variable a line assigns into: the `let [mut] name` binding, or
/// for a plain reassignment the last path segment before the `=`
/// (`self.field = …` → `field`).
pub fn binding_name(code: &str) -> Option<String> {
    if let Some(n) = let_binding_name(code) {
        return Some(n);
    }
    let eq = code.find('=')?;
    let before = code[..eq].trim_end();
    let start = before
        .rfind(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == '.'))
        .map(|i| i + 1)
        .unwrap_or(0);
    let path = &before[start..];
    let last = path.rsplit('.').next().unwrap_or(path);
    if last.is_empty() {
        None
    } else {
        Some(last.to_string())
    }
}

/// The name bound by a `let [mut] name = …` statement on this line.
fn let_binding_name(code: &str) -> Option<String> {
    let at = find_word(code, "let")?;
    let mut rest = code[at + 3..].trim_start();
    if let Some(stripped) = rest.strip_prefix("mut ") {
        rest = stripped.trim_start();
    }
    leading_ident(rest)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_stripped() {
        let f = lex(
            "x.rs",
            "let a = \"has // no comment and a brace {\"; // real comment\nlet b = 1;\n",
        );
        assert!(f.lines[0].code.contains("let a"));
        assert!(!f.lines[0].code.contains("brace"));
        assert!(!f.lines[0].code.contains('{'));
        assert!(f.lines[0].comment.contains("real comment"));
        assert_eq!(f.lines[1].depth, 0, "brace inside string must not change depth");
    }

    #[test]
    fn raw_strings_with_quotes_and_braces() {
        let src = "let re = r#\"quote \" and {{ braces \"#;\nfn after() {\n    1;\n}\n";
        let f = lex("x.rs", src);
        assert!(!f.lines[0].code.contains("quote"));
        assert_eq!(f.lines[1].depth, 0);
        assert_eq!(f.scopes.len(), 1);
        assert_eq!(f.scopes[0].name, "after");
        assert_eq!((f.scopes[0].body_start, f.scopes[0].body_end), (2, 4));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment { */\nlet x = 1;\n";
        let f = lex("x.rs", src);
        assert!(f.lines[0].code.trim().is_empty());
        assert_eq!(f.lines[1].depth, 0);
        assert!(f.lines[1].code.contains("let x"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "let c = '{';\nlet e = '\\u{41}';\nfn f<'a>(x: &'a str) {\n    1;\n}\n";
        let f = lex("x.rs", src);
        assert_eq!(f.lines[2].depth, 0, "brace chars must not affect depth");
        assert_eq!(f.scopes.len(), 1, "lifetimes must not be parsed as char literals");
        assert_eq!(f.scopes[0].name, "f");
    }

    #[test]
    fn cfg_test_region_covers_the_mod_and_ends() {
        let src = "fn live() {\n    1;\n}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        x.unwrap();\n    }\n}\nfn after() {\n    2;\n}\n";
        let f = lex("x.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[3].in_test, "attribute line is part of the region");
        assert!(f.lines[6].in_test, "nested fn body is in the region");
        assert!(!f.lines[9].in_test, "region must end with the mod brace");
    }

    #[test]
    fn cfg_debug_assertions_region_and_braceless_item() {
        let src = "#[cfg(debug_assertions)]\nfn check() {\n    deep();\n}\n#[cfg(debug_assertions)]\nuse std::fmt;\nfn rel() {\n    1;\n}\n";
        let f = lex("x.rs", src);
        assert!(f.lines[1].in_cfg_debug);
        assert!(f.lines[2].in_cfg_debug);
        assert!(f.lines[5].in_cfg_debug, "attributed brace-less item line is covered");
        assert!(!f.lines[6].in_cfg_debug, "the `;` must clear the pending attribute");
        assert!(!f.lines[7].in_cfg_debug);
    }

    #[test]
    fn not_debug_assertions_is_release_code() {
        let src = "#[cfg(not(debug_assertions))]\nfn rel() {\n    1;\n}\n";
        let f = lex("x.rs", src);
        assert!(!f.lines[1].in_cfg_debug);
    }

    #[test]
    fn debug_assert_bodies_span_lines() {
        let src = "fn f() {\n    debug_assert!(\n        a == b,\n        \"msg\"\n    );\n    real();\n}\n";
        let f = lex("x.rs", src);
        assert!(f.lines[1].in_debug_assert);
        assert!(f.lines[2].in_debug_assert);
        assert!(f.lines[4].in_debug_assert);
        assert!(!f.lines[5].in_debug_assert);
    }

    #[test]
    fn armed_guard_region() {
        let src = "fn f() {\n    if trace::armed() {\n        trace::instant(\"x\", \"y\", &[]);\n    }\n    trace::instant(\"naked\", \"y\", &[]);\n}\n";
        let f = lex("x.rs", src);
        assert!(f.lines[1].in_armed_guard, "opening line counts as guarded");
        assert!(f.lines[2].in_armed_guard);
        assert!(!f.lines[4].in_armed_guard);
    }

    #[test]
    fn fn_scopes_nest_and_attribute_lines() {
        let src = "impl Foo {\n    pub fn outer(&self) -> usize {\n        let inner = 1;\n        inner\n    }\n}\n";
        let f = lex("x.rs", src);
        assert_eq!(f.scopes.len(), 2);
        assert_eq!(f.scopes[0].kind, ScopeKind::Impl);
        assert_eq!(f.scopes[1].name, "outer");
        assert_eq!(f.lines[2].fn_scope, Some(1));
        assert!(f.lines[0].fn_scope.is_none());
        assert!(f.impl_at(3).is_some());
    }

    #[test]
    fn trailing_and_standalone_allows() {
        let src = "fn f() {\n    x.unwrap(); // archlint: allow(release-panic) guarded above\n    // archlint: allow(release-panic) next line only\n    y.unwrap();\n    z.unwrap();\n}\n";
        let f = lex("x.rs", src);
        assert_eq!(f.allows.len(), 2);
        assert_eq!(f.allows[0].target, AllowTarget::Line(2));
        assert!(f.allows[0].reason.contains("guarded"));
        assert_eq!(f.allows[1].target, AllowTarget::Line(4));
        assert!(f.allow_covering("release-panic", 2).is_some());
        assert!(f.allow_covering("release-panic", 4).is_some());
        assert!(f.allow_covering("release-panic", 5).is_none());
        assert!(f.allow_covering("nondeterminism", 2).is_none());
    }

    #[test]
    fn doc_comments_never_parse_as_annotations() {
        let src = "/// grammar: `// archlint: allow(<rule>) <reason>`\n//! also in module docs: archlint: allow(x) y\nfn f() {\n    1;\n}\n";
        let f = lex("x.rs", src);
        assert!(f.allows.is_empty(), "doc comments are prose, not annotations");
    }

    #[test]
    fn fn_level_allow_covers_the_whole_body() {
        let src = "// archlint: allow(release-panic) dense arrays sized at build\nfn f(v: &[u64], i: usize) -> u64 {\n    v[i]\n}\nfn g(v: &[u64]) -> u64 {\n    v[0]\n}\n";
        let f = lex("x.rs", src);
        assert_eq!(f.allows.len(), 1);
        assert_eq!(f.allows[0].target, AllowTarget::Scope(0));
        assert!(f.allow_covering("release-panic", 3).is_some());
        assert!(f.allow_covering("release-panic", 6).is_none(), "g is not covered");
    }

    #[test]
    fn float_and_hash_name_censuses() {
        let src = "struct S {\n    progress: f64,\n    done: u64,\n}\nfn f(tau: f64, n: usize) {\n    let mut seen = HashMap::new();\n    let ordered: BTreeMap<u32, u32> = BTreeMap::new();\n    let _ = (tau, n, seen.len(), ordered.len());\n}\n#[cfg(test)]\nmod tests {\n    fn t(secret: f64) {\n        let _ = secret;\n    }\n}\n";
        let f = lex("x.rs", src);
        assert_eq!(f.float_names, vec!["progress".to_string(), "tau".to_string()]);
        assert_eq!(f.hash_names, vec!["seen".to_string()]);
    }

    #[test]
    fn module_classification() {
        assert_eq!(lex("rust/src/sim/engine.rs", "").module(), "sim");
        assert_eq!(lex("rust/src/online/mod.rs", "").module(), "online");
        assert_eq!(lex("rust/src/main.rs", "").module(), "main");
        assert_eq!(lex("/abs/repo/rust/src/net/mod.rs", "").module(), "net");
    }
}
