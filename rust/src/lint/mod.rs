//! `archlint` — the repo's self-hosted static-analysis pass.
//!
//! Seven PRs of this codebase were landed under a review-only discipline
//! (no toolchain in the build container), with every load-bearing
//! guarantee — the `Topology::multiplier` choke point, passive obs
//! hooks, sentinel-not-panic hot paths, deterministic emission,
//! O(active) streaming memory — enforced by convention. `archlint`
//! mechanizes that review: a dependency-free lexer ([`lexer`]) and rule
//! engine ([`rules`]) that scan `rust/src` and emit `file:line`
//! diagnostics, as human text or JSON.
//!
//! * `rarsched archlint` (and the standalone `archlint` binary) exit
//!   non-zero on any unannotated finding; `scripts/verify.sh` runs it as
//!   a required stage and gates on the `LINT.json` artifact.
//! * Intentional exceptions carry `// archlint: allow(<rule>) <reason>`
//!   annotations — trailing (that line), standalone (next line), or
//!   directly above a `fn` header (the whole body). The `allow-audit`
//!   rule checks the annotations themselves; `LINT.json` censuses
//!   used vs stale ones.
//! * `scripts/lint.sh` mirrors the top rules in grep/awk so the gate
//!   runs even where cargo does not exist.

pub mod lexer;
pub mod rules;

pub use lexer::{lex, LexedFile};
pub use rules::{Finding, RuleInfo, RULES};

use crate::runtime::manifest::RunManifest;
use crate::util::Json;
use crate::Result;
use anyhow::{bail, Context};
use std::path::{Path, PathBuf};

/// Aggregated result of scanning a file set.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Surviving (unannotated) findings across all files, in scan order.
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub lines_scanned: usize,
    /// Finding count per rule, in [`RULES`] order (zeros included).
    pub rule_counts: Vec<(&'static str, usize)>,
    /// Allow-annotation census: total annotations seen.
    pub allows_total: usize,
    /// Annotations that suppressed at least one raw finding.
    pub allows_used: usize,
    /// Annotations per rule name (an annotation naming two rules counts
    /// toward both).
    pub allow_rule_counts: Vec<(String, usize)>,
}

impl LintReport {
    /// Scan one lexed file into the report.
    pub fn absorb(&mut self, file: &LexedFile) {
        let (findings, used) = rules::check_file(file);
        self.files_scanned += 1;
        self.lines_scanned += file.lines.len();
        self.allows_total += file.allows.len();
        self.allows_used += used.iter().filter(|u| **u).count();
        for a in &file.allows {
            for r in &a.rules {
                match self.allow_rule_counts.iter_mut().find(|(n, _)| n == r) {
                    Some((_, c)) => *c += 1,
                    None => self.allow_rule_counts.push((r.clone(), 1)),
                }
            }
        }
        self.findings.extend(findings);
    }

    /// Finalize per-rule totals (call once after the last `absorb`).
    pub fn finalize(&mut self) {
        self.rule_counts = RULES
            .iter()
            .map(|r| (r.name, self.findings.iter().filter(|f| f.rule == r.name).count()))
            .collect();
        self.allow_rule_counts.sort();
    }

    /// Human diagnostics: one `file:line: [rule] message` per finding,
    /// plus a summary line.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        out.push_str(&format!(
            "archlint: {} finding(s) across {} file(s) ({} lines); {} allow annotation(s), {} used\n",
            self.findings.len(),
            self.files_scanned,
            self.lines_scanned,
            self.allows_total,
            self.allows_used,
        ));
        out
    }

    /// JSON form of the report, stamped with a [`RunManifest`] so the
    /// `LINT.json` artifact carries provenance like every `BENCH_*.json`.
    pub fn to_json(&self, manifest: &RunManifest) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("rule", Json::Str(f.rule.to_string())),
                    ("message", Json::Str(f.message.clone())),
                ])
            })
            .collect();
        let rules = self
            .rule_counts
            .iter()
            .map(|(name, count)| (*name, Json::Num(*count as f64)))
            .collect();
        let allow_by_rule = self
            .allow_rule_counts
            .iter()
            .map(|(name, count)| (name.as_str(), Json::Num(*count as f64)))
            .collect();
        Json::obj(vec![
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("lines_scanned", Json::Num(self.lines_scanned as f64)),
            ("findings_total", Json::Num(self.findings.len() as f64)),
            ("rules", Json::obj(rules)),
            (
                "allows",
                Json::obj(vec![
                    ("total", Json::Num(self.allows_total as f64)),
                    ("used", Json::Num(self.allows_used as f64)),
                    (
                        "unused",
                        Json::Num((self.allows_total - self.allows_used) as f64),
                    ),
                    ("by_rule", Json::obj(allow_by_rule)),
                ]),
            ),
            ("findings", Json::arr(findings)),
            ("manifest", manifest.to_json()),
        ])
    }
}

/// Recursively collect `.rs` files under `root`, sorted for stable
/// reporting order.
fn rs_files(root: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)
        .with_context(|| format!("reading {root:?}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under the given roots (files are accepted too)
/// and return the finalized report.
pub fn scan_paths(roots: &[PathBuf]) -> Result<LintReport> {
    let mut files = Vec::new();
    for r in roots {
        rs_files(r, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut report = LintReport::default();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        let lexed = lex(&path.to_string_lossy(), &text);
        report.absorb(&lexed);
    }
    report.finalize();
    Ok(report)
}

/// Default scan root: `rust/src` from the repo root, or `src` when the
/// working directory is already the crate (`cargo run` sets cwd to the
/// package root).
pub fn default_root() -> PathBuf {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("rust/src")
}

/// Shared CLI driver for `rarsched archlint` and the `archlint` binary.
///
/// Flags: positional scan roots (default `rust/src`), `--json` (machine
/// report on stdout), `--out <path>` (write the `LINT.json` artifact),
/// `--list-rules`. Returns an error — and the process a non-zero exit —
/// when any finding survives its annotations.
pub fn cli_main(args: &crate::cli::Args) -> Result<()> {
    if args.get_bool("list-rules") {
        for r in RULES {
            println!("{:<14} {}", r.name, r.invariant);
        }
        args.reject_unknown()?;
        return Ok(());
    }
    let json_out = args.get_bool("json");
    let artifact = args.get("out").map(PathBuf::from);
    let roots: Vec<PathBuf> = if args.positional().is_empty() {
        vec![default_root()]
    } else {
        args.positional().iter().map(PathBuf::from).collect()
    };
    args.reject_unknown()?;

    let report = scan_paths(&roots)?;
    let flags: Vec<String> = std::iter::once("archlint".to_string())
        .chain(roots.iter().map(|r| r.to_string_lossy().into_owned()))
        .collect();
    let manifest = RunManifest::new(0, "", &flags);
    if let Some(path) = &artifact {
        std::fs::write(path, report.to_json(&manifest).to_pretty())
            .with_context(|| format!("writing {path:?}"))?;
    }
    if json_out {
        println!("{}", report.to_json(&manifest).to_pretty());
    } else {
        print!("{}", report.render_human());
    }
    if !report.findings.is_empty() {
        bail!("archlint: {} finding(s) — fix or annotate (see ROADMAP.md)", report.findings.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_aggregates_and_censuses() {
        let mut report = LintReport::default();
        let clean = lex(
            "rust/src/online/a.rs",
            "fn f(v: &[u64], i: usize) -> u64 {\n    v.get(i).copied().unwrap_or(0)\n}\n",
        );
        let dirty = lex(
            "rust/src/online/b.rs",
            "fn f(v: &[u64]) -> u64 {\n    v.first().copied().unwrap()\n}\nfn g(v: &[u64], i: usize) -> u64 {\n    v[i] // archlint: allow(release-panic) caller bounds i\n}\n",
        );
        report.absorb(&clean);
        report.absorb(&dirty);
        report.finalize();
        assert_eq!(report.files_scanned, 2);
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].rule, "release-panic");
        assert_eq!(report.allows_total, 1);
        assert_eq!(report.allows_used, 1);
        let rp = report.rule_counts.iter().find(|(n, _)| *n == "release-panic");
        assert_eq!(rp.map(|(_, c)| *c), Some(1));
        let human = report.render_human();
        assert!(human.contains("rust/src/online/b.rs:2: [release-panic]"));
        assert!(human.contains("1 finding(s)"));
    }

    #[test]
    fn json_report_carries_manifest_and_counts() {
        let mut report = LintReport::default();
        report.absorb(&lex("rust/src/sim/a.rs", "fn f() -> u64 {\n    0\n}\n"));
        report.finalize();
        let manifest = RunManifest::new(0, "", &["archlint".to_string()]);
        let json = report.to_json(&manifest);
        let parsed = Json::parse(&json.to_pretty()).unwrap();
        assert_eq!(parsed.req("findings_total").unwrap().as_u64().unwrap(), 0);
        assert_eq!(parsed.req("files_scanned").unwrap().as_u64().unwrap(), 1);
        assert!(parsed.req("manifest").unwrap().get("git_rev").is_some());
        assert!(parsed.req("rules").unwrap().get("release-panic").is_some());
    }
}
