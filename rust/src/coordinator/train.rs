//! Live data-parallel training of one or more RAR jobs.
//!
//! Each scheduled worker is a thread that owns its own PJRT client and
//! compiled executables (PJRT handles are not `Send`), computes gradients
//! on its corpus shard, all-reduces them with its ring neighbours through
//! the bandwidth-regulated RAR engine, and applies the averaged update —
//! exactly the synchronous SGD loop of the paper's §3.

use super::Corpus;
use crate::cluster::JobPlacement;
use crate::rar::{LinkBank, RingSpec, RingWorker};
use crate::runtime::PjRt;
use crate::Result;
use anyhow::Context;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What to train and how.
#[derive(Debug, Clone)]
pub struct TrainJobSpec {
    /// Model preset name from the artifact manifest ("tiny", "small"...).
    pub model: String,
    /// Training steps (a "few hundred" for the e2e demo).
    pub steps: u64,
    /// Corpus seed (per job, so concurrent jobs train on different text).
    pub corpus_seed: u64,
    /// Artifacts root.
    pub artifacts: PathBuf,
}

/// Per-job training outcome.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Mean cross-worker loss per step.
    pub losses: Vec<f32>,
    /// Wall time per step (max over workers).
    pub step_times: Vec<Duration>,
    pub total: Duration,
    pub workers: usize,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn initial_loss(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }

    pub fn mean_step_time(&self) -> Duration {
        if self.step_times.is_empty() {
            return Duration::ZERO;
        }
        self.step_times.iter().sum::<Duration>() / self.step_times.len() as u32
    }
}

/// Train one job on `placement` (one worker thread per scheduled GPU).
///
/// `links` regulates inter-server hops; pass the same bank to concurrent
/// jobs to make them contend (Eq. 6 live).
pub fn train_job(
    spec: &TrainJobSpec,
    placement: &JobPlacement,
    links: Option<Arc<LinkBank>>,
) -> Result<TrainReport> {
    let w = placement.num_workers();
    let ring_spec = RingSpec::from_placement(placement);
    let endpoints = RingWorker::ring(&ring_spec);
    let t0 = Instant::now();

    let mut per_worker: Vec<Option<(Vec<f32>, Vec<Duration>)>> =
        (0..w).map(|_| None).collect();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(w);
        for endpoint in endpoints {
            let spec = spec.clone();
            let links = links.clone();
            handles.push(scope.spawn(move || worker_loop(&spec, endpoint, w, links)));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let (losses, times) = h
                .join()
                .map_err(|_| anyhow::anyhow!("worker {i} panicked"))?
                .with_context(|| format!("worker {i}"))?;
            per_worker[i] = Some((losses, times));
        }
        Ok(())
    })?;

    let per_worker: Vec<(Vec<f32>, Vec<Duration>)> =
        per_worker.into_iter().map(|o| o.unwrap()).collect();
    let steps = spec.steps as usize;
    let mut losses = Vec::with_capacity(steps);
    let mut step_times = Vec::with_capacity(steps);
    for s in 0..steps {
        let mean =
            per_worker.iter().map(|(l, _)| l[s]).sum::<f32>() / per_worker.len() as f32;
        losses.push(mean);
        step_times.push(per_worker.iter().map(|(_, t)| t[s]).max().unwrap());
    }
    Ok(TrainReport { losses, step_times, total: t0.elapsed(), workers: w })
}

/// One worker's synchronous-SGD loop.
fn worker_loop(
    spec: &TrainJobSpec,
    ring: RingWorker,
    world: usize,
    links: Option<Arc<LinkBank>>,
) -> Result<(Vec<f32>, Vec<Duration>)> {
    // Each worker owns a PJRT client (handles are not Send) — this mirrors
    // one process per GPU in a real deployment.
    let pjrt = PjRt::cpu(&spec.artifacts)?;
    let model = pjrt.model(&spec.model)?;
    let cfg = model.entry().config.clone();
    let mut params = model.init_params(&pjrt)?;
    let mut corpus = Corpus::synthetic(spec.corpus_seed, 200_000).shard(ring.index, world);

    let mut losses = Vec::with_capacity(spec.steps as usize);
    let mut times = Vec::with_capacity(spec.steps as usize);
    let inv_world = 1.0 / world as f32;
    for _ in 0..spec.steps {
        let t0 = Instant::now();
        let (x, y) = corpus.next_batch(cfg.batch, cfg.seq_len);
        let (out, grads) = model.grad_step(&params, &x, &y)?;
        // all-reduce the flat gradient with ring neighbours, then average
        let mut flat = model.flatten_grads(&grads)?;
        ring.all_reduce(&mut flat, links.as_deref())?;
        if world > 1 {
            for v in flat.iter_mut() {
                *v *= inv_world;
            }
        }
        let reduced = model.unflatten_grads(&flat)?;
        params = model.apply_grads(&params, &reduced)?;
        losses.push(out.loss);
        times.push(t0.elapsed());
    }
    Ok((losses, times))
}

/// Run several jobs concurrently over one shared link bank (the
/// multi-tenant setting): returns one report per job, in input order.
pub fn train_jobs_concurrently(
    jobs: &[(TrainJobSpec, JobPlacement)],
    links: Arc<LinkBank>,
) -> Result<Vec<TrainReport>> {
    let mut out: Vec<Option<TrainReport>> = (0..jobs.len()).map(|_| None).collect();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(jobs.len());
        for (spec, placement) in jobs {
            let links = links.clone();
            handles.push(scope.spawn(move || train_job(spec, placement, Some(links))));
        }
        for (i, h) in handles.into_iter().enumerate() {
            out[i] =
                Some(h.join().map_err(|_| anyhow::anyhow!("job {i} panicked"))??);
        }
        Ok(())
    })?;
    Ok(out.into_iter().map(|o| o.unwrap()).collect())
}
