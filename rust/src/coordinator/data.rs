//! Synthetic byte-level training corpus.
//!
//! A deterministic "templated prose" generator: sentences are assembled
//! from fixed word lists with a seeded RNG. The text has real structure
//! (word boundaries, recurring n-grams, punctuation rhythm), so a
//! byte-level LM trained on it shows a genuine falling loss curve — while
//! remaining fully reproducible with no external dataset.

use crate::util::Rng;

const SUBJECTS: &[&str] = &[
    "the scheduler", "a worker", "the ring", "each gpu", "the cluster",
    "the gradient", "a tenant", "the link", "the server", "the job",
];
const VERBS: &[&str] = &[
    "reduces", "shares", "allocates", "contends for", "synchronizes",
    "exchanges", "packs", "spreads", "balances", "completes",
];
const OBJECTS: &[&str] = &[
    "the bandwidth", "a chunk", "the makespan", "its workers", "the ring",
    "the overhead", "a sub vector", "the batch", "its neighbours", "the queue",
];
const ADVERBS: &[&str] =
    &["quickly", "fairly", "in order", "without contention", "every slot", "again"];

/// A generated corpus plus a cursor for batch extraction.
#[derive(Debug, Clone)]
pub struct Corpus {
    bytes: Vec<u8>,
    cursor: usize,
}

impl Corpus {
    /// Generate ~`min_len` bytes of templated prose from `seed`.
    pub fn synthetic(seed: u64, min_len: usize) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut text = String::with_capacity(min_len + 64);
        while text.len() < min_len {
            let s = rng.choose(SUBJECTS);
            let v = rng.choose(VERBS);
            let o = rng.choose(OBJECTS);
            text.push_str(s);
            text.push(' ');
            text.push_str(v);
            text.push(' ');
            text.push_str(o);
            if rng.gen_f64() < 0.4 {
                text.push(' ');
                text.push_str(*rng.choose(ADVERBS));
            }
            text.push_str(if rng.gen_f64() < 0.2 { ".\n" } else { ". " });
        }
        Corpus { bytes: text.into_bytes(), cursor: 0 }
    }

    /// Load a corpus from a file (byte-level).
    pub fn from_file(path: &std::path::Path) -> crate::Result<Self> {
        Ok(Corpus { bytes: std::fs::read(path)?, cursor: 0 })
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Next (x, y) batch of `batch` sequences of length `seq`: y is x
    /// shifted by one byte. Wraps around the corpus.
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let need = seq + 1;
        assert!(self.bytes.len() > need, "corpus too small for seq_len {seq}");
        let mut x = Vec::with_capacity(batch * seq);
        let mut y = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            if self.cursor + need >= self.bytes.len() {
                self.cursor = 0;
            }
            let window = &self.bytes[self.cursor..self.cursor + need];
            x.extend(window[..seq].iter().map(|&b| b as i32));
            y.extend(window[1..].iter().map(|&b| b as i32));
            self.cursor += seq;
        }
        (x, y)
    }

    /// Split into `n` disjoint shards (data parallelism): shard `i` starts
    /// at a different offset so workers see different data.
    pub fn shard(&self, i: usize, n: usize) -> Corpus {
        assert!(i < n);
        let offset = (self.bytes.len() / n) * i;
        let mut c = self.clone();
        c.cursor = offset;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_textual() {
        let a = Corpus::synthetic(1, 10_000);
        let b = Corpus::synthetic(1, 10_000);
        assert_eq!(a.bytes, b.bytes);
        assert!(a.len() >= 10_000);
        let text = String::from_utf8(a.bytes.clone()).unwrap();
        assert!(text.contains("the scheduler"));
        assert!(text.contains(". "));
    }

    #[test]
    fn batches_shift_by_one() {
        let mut c = Corpus::synthetic(2, 5_000);
        let (x, y) = c.next_batch(4, 16);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 64);
        // y is x shifted within each row
        for row in 0..4 {
            for t in 0..15 {
                assert_eq!(x[row * 16 + t + 1], y[row * 16 + t]);
            }
        }
        // all tokens are bytes
        assert!(x.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn wraparound() {
        let mut c = Corpus::synthetic(3, 600);
        for _ in 0..100 {
            let (x, _) = c.next_batch(2, 64);
            assert_eq!(x.len(), 128);
        }
    }

    #[test]
    fn shards_start_at_different_offsets() {
        let c = Corpus::synthetic(4, 10_000);
        let mut s0 = c.shard(0, 2);
        let mut s1 = c.shard(1, 2);
        let (x0, _) = s0.next_batch(1, 32);
        let (x1, _) = s1.next_batch(1, 32);
        assert_ne!(x0, x1);
    }
}
