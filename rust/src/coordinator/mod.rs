//! The live coordinator: takes a schedule from the planner and *actually
//! trains* the jobs — one worker thread per scheduled GPU, each running
//! the AOT-compiled grad step via PJRT, exchanging gradients with its
//! ring neighbours through the RAR engine under the bandwidth regulator.
//!
//! This is the layer that closes the loop of the paper: the scheduler's
//! placement decisions (co-located vs spread, contended vs not) become
//! measurable wall-clock differences on a real training workload.

mod data;
mod train;

pub use data::Corpus;
pub use train::{train_job, train_jobs_concurrently, TrainJobSpec, TrainReport};
