//! Bandwidth regulation for shared links.
//!
//! Each server has one uplink. A transfer of `n` bytes sleeps for
//! `n * flows / bandwidth` seconds, where `flows` is the number of
//! transfers concurrently holding the link — a fair-share approximation
//! of the paper's `b^e / k_j` contention model that makes contention
//! *observable in wall-clock time* on the live path.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

/// Telemetry for one link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkStats {
    /// Total bytes transferred.
    pub bytes: u64,
    /// Total transfers.
    pub transfers: u64,
    /// Max concurrent flows observed.
    pub max_flows: u64,
}

struct Link {
    active: AtomicUsize,
    bytes: AtomicU64,
    transfers: AtomicU64,
    max_flows: AtomicU64,
}

/// One uplink per server plus a shared intra-server bandwidth.
pub struct LinkBank {
    links: Vec<Link>,
    /// Inter-server (uplink) bandwidth, bytes/sec.
    pub inter_bw: f64,
    /// Intra-server bandwidth, bytes/sec (`b^i >> b^e`).
    pub intra_bw: f64,
}

impl LinkBank {
    pub fn new(num_servers: usize, inter_bw: f64, intra_bw: f64) -> Self {
        assert!(inter_bw > 0.0 && intra_bw > 0.0);
        LinkBank {
            links: (0..num_servers)
                .map(|_| Link {
                    active: AtomicUsize::new(0),
                    bytes: AtomicU64::new(0),
                    transfers: AtomicU64::new(0),
                    max_flows: AtomicU64::new(0),
                })
                .collect(),
            inter_bw,
            intra_bw,
        }
    }

    pub fn num_servers(&self) -> usize {
        self.links.len()
    }

    /// Transmit `bytes` across the uplink of `server` (inter-server hop):
    /// sleeps for the fair-share duration under current contention.
    pub fn transmit_inter(&self, server: usize, bytes: usize) {
        let link = &self.links[server];
        let flows = link.active.fetch_add(1, Ordering::SeqCst) + 1;
        link.max_flows.fetch_max(flows as u64, Ordering::Relaxed);
        link.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        link.transfers.fetch_add(1, Ordering::Relaxed);
        let secs = bytes as f64 * flows as f64 / self.inter_bw;
        spin_sleep(Duration::from_secs_f64(secs));
        link.active.fetch_sub(1, Ordering::SeqCst);
    }

    /// Transmit `bytes` inside a server (NVLink-class; uncontended model).
    pub fn transmit_intra(&self, bytes: usize) {
        let secs = bytes as f64 / self.intra_bw;
        spin_sleep(Duration::from_secs_f64(secs));
    }

    /// Telemetry snapshot for a server's uplink.
    pub fn stats(&self, server: usize) -> LinkStats {
        let l = &self.links[server];
        LinkStats {
            bytes: l.bytes.load(Ordering::Relaxed),
            transfers: l.transfers.load(Ordering::Relaxed),
            max_flows: l.max_flows.load(Ordering::Relaxed),
        }
    }
}

/// Sleep that stays accurate for sub-millisecond durations (thread::sleep
/// granularity is too coarse for small chunk transfers).
fn spin_sleep(d: Duration) {
    if d >= Duration::from_millis(2) {
        std::thread::sleep(d);
    } else {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < d {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn transfer_duration_scales_with_bytes() {
        let bank = LinkBank::new(1, 10.0e6, 1.0e9); // 10 MB/s
        let t0 = Instant::now();
        bank.transmit_inter(0, 100_000); // 10 ms at fair share 1
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(9), "{dt:?}");
        assert!(dt < Duration::from_millis(100), "{dt:?}");
        let s = bank.stats(0);
        assert_eq!(s.bytes, 100_000);
        assert_eq!(s.transfers, 1);
        assert_eq!(s.max_flows, 1);
    }

    #[test]
    fn concurrent_flows_share_bandwidth() {
        let bank = LinkBank::new(1, 50.0e6, 1.0e9);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| bank.transmit_inter(0, 250_000));
            }
        });
        let dt = t0.elapsed();
        // 4 flows x 250 kB at 50 MB/s fair-shared: >= 4x the solo 5 ms
        assert!(dt >= Duration::from_millis(15), "{dt:?}");
        assert!(bank.stats(0).max_flows >= 2);
    }

    #[test]
    fn intra_is_fast() {
        let bank = LinkBank::new(1, 1.0, 1.0e9);
        let t0 = Instant::now();
        bank.transmit_intra(1_000_000); // 1 ms at 1 GB/s
        assert!(t0.elapsed() < Duration::from_millis(50));
        assert_eq!(bank.stats(0).bytes, 0);
    }
}
