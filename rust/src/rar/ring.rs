//! The chunked ring schedule over worker threads.

use super::LinkBank;
use crate::Result;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Static shape of a ring: which server hosts each worker, in ring order
/// (same-server workers contiguous — see `JobPlacement::new`).
#[derive(Debug, Clone)]
pub struct RingSpec {
    pub server_of: Vec<usize>,
}

impl RingSpec {
    /// All workers on one server (no uplink traffic).
    pub fn colocated(w: usize) -> Self {
        RingSpec { server_of: vec![0; w] }
    }

    /// Build from a placement (ring order == placement GPU order).
    pub fn from_placement(p: &crate::cluster::JobPlacement) -> Self {
        RingSpec { server_of: p.gpus().iter().map(|g| g.server.0).collect() }
    }

    pub fn width(&self) -> usize {
        self.server_of.len()
    }

    /// Does the hop from worker `i` to its downstream cross servers?
    pub fn hop_crosses(&self, i: usize) -> bool {
        let w = self.width();
        self.server_of[i] != self.server_of[(i + 1) % w]
    }
}

/// Contiguous chunk boundaries: `w` chunks over a `d`-vector, sizes
/// differing by at most one (mirrors `kernels/ring_reduce.py`).
pub fn chunk_bounds(d: usize, w: usize) -> Vec<(usize, usize)> {
    let (base, rem) = (d / w, d % w);
    let mut out = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let size = base + usize::from(i < rem);
        out.push((start, start + size));
        start += size;
    }
    out
}

/// One worker's endpoints in a ring: a sender to its downstream neighbour
/// and a receiver from its upstream neighbour.
pub struct RingWorker {
    pub index: usize,
    spec: RingSpec,
    tx_down: Sender<Vec<f32>>,
    rx_up: Receiver<Vec<f32>>,
}

impl RingWorker {
    /// Wire up a `w`-worker ring; returns one endpoint set per worker
    /// (move each into its thread).
    pub fn ring(spec: &RingSpec) -> Vec<RingWorker> {
        let w = spec.width();
        let mut txs = Vec::with_capacity(w);
        let mut rxs = Vec::with_capacity(w);
        for _ in 0..w {
            let (tx, rx) = channel::<Vec<f32>>();
            txs.push(tx);
            rxs.push(rx);
        }
        // worker i sends to (i+1) % w, so worker i receives on rx[i] and
        // worker i's tx targets channel (i+1) % w
        let mut workers: Vec<RingWorker> = Vec::with_capacity(w);
        let mut rx_iter = rxs.into_iter();
        for i in 0..w {
            let tx_down = txs[(i + 1) % w].clone();
            let rx_up = rx_iter.next().unwrap();
            workers.push(RingWorker { index: i, spec: spec.clone(), tx_down, rx_up });
        }
        workers
    }

    fn send(&self, payload: Vec<f32>, links: Option<&LinkBank>) -> Result<()> {
        if let Some(bank) = links {
            let bytes = payload.len() * std::mem::size_of::<f32>();
            if bytes > 0 {
                if self.spec.hop_crosses(self.index) {
                    bank.transmit_inter(self.spec.server_of[self.index], bytes);
                } else {
                    bank.transmit_intra(bytes);
                }
            }
        }
        self.tx_down
            .send(payload)
            .map_err(|_| anyhow::anyhow!("ring neighbour hung up"))
    }

    fn recv(&self) -> Result<Vec<f32>> {
        self.rx_up.recv().map_err(|_| anyhow::anyhow!("ring upstream hung up"))
    }

    /// Execute one all-reduce over `buf` in place: after return, `buf`
    /// holds the elementwise sum over all workers (paper §3: steps
    /// 1..w−1 Share-Reduce, w..2w−2 Share-Only).
    pub fn all_reduce(&self, buf: &mut [f32], links: Option<&LinkBank>) -> Result<()> {
        let w = self.spec.width();
        if w == 1 {
            return Ok(());
        }
        let bounds = chunk_bounds(buf.len(), w);
        let i = self.index;

        // Share-Reduce: in step s, send chunk (i - s) mod w downstream,
        // receive chunk (i - 1 - s) mod w from upstream, accumulate.
        for s in 0..w - 1 {
            let send_c = (i + w - s % w) % w;
            let (lo, hi) = bounds[send_c];
            self.send(buf[lo..hi].to_vec(), links)?;
            let recv_c = (i + w - 1 - s % w) % w;
            let payload = self.recv()?;
            let (lo, hi) = bounds[recv_c];
            debug_assert_eq!(payload.len(), hi - lo);
            for (dst, src) in buf[lo..hi].iter_mut().zip(&payload) {
                *dst += *src;
            }
        }

        // Share-Only: worker i now owns fully-reduced chunk (i + 1) mod w.
        for s in 0..w - 1 {
            let send_c = (i + 1 + w - s % w) % w;
            let (lo, hi) = bounds[send_c];
            self.send(buf[lo..hi].to_vec(), links)?;
            let recv_c = (i + w - s % w) % w;
            let payload = self.recv()?;
            let (lo, hi) = bounds[recv_c];
            for (dst, src) in buf[lo..hi].iter_mut().zip(&payload) {
                *dst = *src;
            }
        }
        Ok(())
    }
}

/// Convenience: all-reduce a set of per-worker buffers on scoped threads;
/// returns every worker's final buffer (all equal to the sum).
pub fn ring_all_reduce(
    buffers: Vec<Vec<f32>>,
    spec: &RingSpec,
    links: Option<&LinkBank>,
) -> Vec<Vec<f32>> {
    assert_eq!(buffers.len(), spec.width(), "one buffer per ring worker");
    let workers = RingWorker::ring(spec);
    let mut out: Vec<Option<Vec<f32>>> = (0..spec.width()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = workers
            .into_iter()
            .zip(buffers)
            .map(|(worker, mut buf)| {
                scope.spawn(move || {
                    worker.all_reduce(&mut buf, links).expect("ring failure");
                    (worker.index, buf)
                })
            })
            .collect();
        for h in handles {
            let (i, buf) = h.join().expect("ring worker panicked");
            out[i] = Some(buf);
        }
    });
    out.into_iter().map(|b| b.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_bounds_partition() {
        for d in [0usize, 1, 7, 100, 101] {
            for w in [1usize, 2, 3, 8] {
                let b = chunk_bounds(d, w);
                assert_eq!(b.len(), w);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[w - 1].1, d);
                let sizes: Vec<_> = b.iter().map(|(lo, hi)| hi - lo).collect();
                assert_eq!(sizes.iter().sum::<usize>(), d);
                assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
            }
        }
    }

    #[test]
    fn hop_crossing_detection() {
        let spec = RingSpec { server_of: vec![0, 0, 1, 1] };
        assert!(!spec.hop_crosses(0)); // 0 -> 0
        assert!(spec.hop_crosses(1)); // 0 -> 1
        assert!(!spec.hop_crosses(2)); // 1 -> 1
        assert!(spec.hop_crosses(3)); // 1 -> 0 (wrap)
    }

    #[test]
    fn two_worker_ring() {
        let got = ring_all_reduce(
            vec![vec![1.0, 2.0, 3.0], vec![10.0, 20.0, 30.0]],
            &RingSpec::colocated(2),
            None,
        );
        assert_eq!(got[0], vec![11.0, 22.0, 33.0]);
        assert_eq!(got[1], vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn empty_buffer_ok() {
        let got = ring_all_reduce(vec![vec![], vec![]], &RingSpec::colocated(2), None);
        assert!(got[0].is_empty() && got[1].is_empty());
    }
}
