//! A real, multi-threaded ring-all-reduce engine.
//!
//! This is the live counterpart of the analytical model: worker threads
//! form a ring (one thread per scheduled GPU) and execute the exact
//! 2(w−1)-step RAR schedule of the paper's §3 — a Share-Reduce phase
//! (chunked reduce-scatter) followed by a Share-Only phase (all-gather) —
//! over in-process channels.
//!
//! Link sharing is enforced by a [`LinkBank`] bandwidth regulator: every
//! inter-server hop charges its payload against the sender's server
//! uplink, and concurrent flows on the same uplink share it (the
//! contention effect of Eq. 6–7, observable in wall-clock time). Workers
//! co-located on a server exchange at intra-server bandwidth.

mod link;
mod ring;

pub use link::{LinkBank, LinkStats};
pub use ring::{ring_all_reduce, RingSpec, RingWorker};

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn bufs(w: usize, d: usize) -> Vec<Vec<f32>> {
        (0..w)
            .map(|i| (0..d).map(|j| ((i * d + j) % 97) as f32 * 0.25 - 3.0).collect())
            .collect()
    }

    fn expected_sum(bufs: &[Vec<f32>]) -> Vec<f32> {
        let d = bufs[0].len();
        (0..d).map(|j| bufs.iter().map(|b| b[j]).sum()).collect()
    }

    #[test]
    fn all_reduce_equals_sum_various_widths() {
        for w in [1usize, 2, 3, 4, 7, 8] {
            for d in [1usize, 5, 128, 1000, 1003] {
                let input = bufs(w, d);
                let want = expected_sum(&input);
                let spec = RingSpec::colocated(w);
                let got = ring_all_reduce(input, &spec, None);
                for (wi, g) in got.iter().enumerate() {
                    for (a, b) in g.iter().zip(&want) {
                        assert!(
                            (a - b).abs() <= 1e-3,
                            "w={w} d={d} worker {wi}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn d_smaller_than_ring_still_works() {
        // w=8 workers reducing a 3-element vector: some chunks are empty
        let input = bufs(8, 3);
        let want = expected_sum(&input);
        let got = ring_all_reduce(input, &RingSpec::colocated(8), None);
        for g in &got {
            for (a, b) in g.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4);
            }
        }
    }

    #[test]
    fn contention_slows_wallclock() {
        // Two rings share one uplink pair vs running alone: the shared run
        // must be measurably slower per ring under the regulator.
        // large enough that regulator sleeps dominate scheduler noise
        let d = 1_500_000;
        let bank = LinkBank::new(2, 400.0e6, 8.0e9); // 400 MB/s uplinks
        let spec = RingSpec {
            // 2 workers on server 0, 2 on server 1 -> 2 inter-server hops
            server_of: vec![0, 0, 1, 1],
        };

        let t0 = Instant::now();
        let _ = ring_all_reduce(bufs(4, d), &spec, Some(&bank));
        let solo = t0.elapsed();

        // two rings concurrently over the same servers
        let bank2 = LinkBank::new(2, 400.0e6, 8.0e9);
        let t1 = Instant::now();
        std::thread::scope(|s| {
            let b = &bank2;
            let spec_ref = &spec;
            let h1 = s.spawn(move || ring_all_reduce(bufs(4, d), spec_ref, Some(b)));
            let h2 = s.spawn(move || ring_all_reduce(bufs(4, d), spec_ref, Some(b)));
            h1.join().unwrap();
            h2.join().unwrap();
        });
        let shared = t1.elapsed();
        assert!(
            shared.as_secs_f64() > solo.as_secs_f64() * 1.2,
            "contention not visible: solo={solo:?} shared={shared:?}"
        );
        assert!(bank2.stats(0).bytes > 0);
    }

    #[test]
    fn colocated_ring_bypasses_uplinks() {
        let bank = LinkBank::new(2, 1.0, 1e12); // absurdly slow uplinks
        let spec = RingSpec { server_of: vec![0, 0, 0] };
        let t0 = Instant::now();
        let got = ring_all_reduce(bufs(3, 50_000), &spec, Some(&bank));
        assert!(t0.elapsed().as_secs_f64() < 5.0, "intra-server must not hit uplink");
        assert_eq!(got.len(), 3);
        assert_eq!(bank.stats(0).bytes, 0, "no uplink traffic for colocated ring");
    }
}
